"""Sharding helpers: axis filtering, divisibility degradation, spec
stacking, and cell construction on a multi-device mesh (subprocess)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import filter_spec, stack_spec, constrain


def test_filter_spec_no_mesh():
    # without a mesh every axis drops
    assert filter_spec(P("data", "model")) == P(None, None)


def test_constrain_identity_off_mesh():
    x = jnp.ones((4, 4))
    y = constrain(x, P("data", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_stack_spec():
    t = {"a": P("data", "model"), "b": {"c": P(None)}}
    s = stack_spec(t)
    assert s["a"] == P(None, "data", "model")
    assert s["b"]["c"] == P(None, None)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, set_mesh
from repro.distributed.sharding import filter_spec, constrain
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
with set_mesh(mesh):
    # divisibility: dim 3 cannot shard 2-ways -> axis dropped
    assert filter_spec(P(("pod", "data"), "model"), (8, 3)) == \
        P(("pod", "data"), None), filter_spec(P(("pod","data"), "model"), (8, 3))
    # hybrid FSDP: bare 'data' expands over the pod axis on multi-pod meshes
    assert filter_spec(P("data", "model"), (8, 4)) == \
        P(("pod", "data"), "model"), filter_spec(P("data", "model"), (8, 4))
    # ...unless the dim doesn't divide the larger product (8 % 4 == 0, 2 % 4 != 0)
    assert filter_spec(P("data", None), (2, 4)) == P(None, None)
    # batch=1 decode cell: everything degrades to replication
    assert filter_spec(P(("pod", "data"),), (1,)) == P(None)
    # constrain under jit
    y = jax.jit(lambda x: constrain(x * 2, P(("pod", "data"), "model")))(
        jnp.ones((8, 4)))
    assert "model" in str(y.sharding.spec) or y.sharding.is_fully_replicated is False
print("OK")
"""


def test_filter_spec_divisibility_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"},
                       cwd="/root/repo", timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


_SUBPROC_MOE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, set_mesh
from repro.nn.ffn import MoEConfig, moe_init, moe_apply_dense, moe_apply_shard_map
mesh = make_mesh((2, 4), ("data", "model"))
cfg = MoEConfig(d_model=16, d_expert=8, num_experts=8, top_k=2,
                capacity_factor=8.0, sharding="ep")
p, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
with set_mesh(mesh):
    y_ref, _ = moe_apply_dense(p, cfg, x)
    y_ep, _ = jax.jit(lambda pp, xx: moe_apply_shard_map(
        pp, cfg, xx, mesh, ep_axis="model", sp_axis=("data",)))(p, x)
err = float(jnp.abs(y_ref - y_ep).max())
assert err < 1e-4, err
print("OK", err)
"""


def test_moe_shard_map_matches_dense_subprocess():
    """EP all-to-all MoE == dense dispatch (8 experts over 4-way EP)."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC_MOE],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"},
                       cwd="/root/repo", timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
