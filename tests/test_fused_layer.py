"""Fused mid-layer kernel (kernels/fused_layer.py, DESIGN.md §7/§9):
projection + bias + per-segment activation in one Pallas pass, with the
ONE-PASS backward (dy·act'(z) formed in-register inside a two-level
param-tile × batch-tile grid that emits dx AND dw from a single launch at
any batch size).  Interpret-mode equivalence vs the einsum reference —
values AND gradients — across every paper activation, ragged segment
layouts, multi-batch-tile shapes (B > block_b), the shard_pad
filler-member case, and the bf16 mixed-precision policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.activations import ACTIVATION_ORDER
from repro.core.deep import (BD_IMPLS, FUSED_BD_IMPLS, block_diag_matmul,
                             forward, fused_loss, init_params, pad_params,
                             sgd_step)
from repro.core.population import LayeredPopulation

# one member per paper activation, ragged widths AND ragged depths: every
# bucket shape (odd fan-ins, duplicate shapes, pass-throughs) in one layout
_WIDTHS = ((5, 3), (12, 9), (7,), (17, 9, 5), (8, 8),
           (5, 3), (3, 11, 2), (24, 16), (4,), (9, 9, 9))
LP_ALL = LayeredPopulation(6, 3, _WIDTHS, ACTIVATION_ORDER, block=8)


def _params_and_batch(lp, b=9, seed=0):
    params = init_params(jax.random.PRNGKey(seed), lp)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, lp.in_features))
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (b,), 0,
                           lp.out_features)
    return params, x, y


def test_registry_has_fused():
    assert "fused" in BD_IMPLS
    assert "fused" in FUSED_BD_IMPLS


def test_forward_matches_einsum_every_activation():
    params, x, _ = _params_and_batch(LP_ALL)
    ye = forward(params, x, LP_ALL, bd_impl="einsum")
    yf = forward(params, x, LP_ALL, bd_impl="fused")
    np.testing.assert_allclose(np.asarray(ye), np.asarray(yf),
                               rtol=1e-5, atol=1e-6)


def test_grad_matches_einsum_every_activation():
    params, x, y = _params_and_batch(LP_ALL)

    def loss(impl):
        return lambda p: fused_loss(p, x, y, LP_ALL, "bucketed", impl)[0]

    ge = jax.grad(loss("einsum"))(params)
    gf = jax.grad(loss("fused"))(params)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6), ge, gf)


def test_grad_matches_multi_batch_tile_one_pass():
    """Batch > 128 pads to several batch tiles → the TWO-LEVEL-GRID
    one-pass backward (param-tile outer × batch-tile inner, dx and dw
    accumulated in-register across the inner dimension, DESIGN.md §9) —
    every paper activation, still a single dx+dw launch."""
    params, x, y = _params_and_batch(LP_ALL, b=160, seed=5)

    def loss(impl):
        return lambda p: fused_loss(p, x, y, LP_ALL, "bucketed", impl)[0]

    ge = jax.grad(loss("einsum"))(params)
    gf = jax.grad(loss("fused"))(params)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6), ge, gf)


def test_grad_matches_large_batch_direct_kernel():
    """B=1024 with block_b=128 (8 inner batch tiles) straight through the
    mid-layer custom-VJP primitive on a ragged MULTI-BUCKET layout: the
    §9 acceptance shape for the two-level grid, per-operand grads vs the
    einsum+activation reference."""
    lp = LayeredPopulation(5, 2, ((11, 3, 5), (4,), (24, 16), (9, 9)),
                           ("gelu", "sigmoid", "tanh", "relu"), block=8)
    params = init_params(jax.random.PRNGKey(7), lp)
    w, bia = params["mid"][0]["w"], params["mid"][0]["b"]
    h = jax.random.normal(jax.random.PRNGKey(8),
                          (1024, lp.layer_pop(0).total_hidden))
    from repro.core.deep import _act

    def ref(hh, ww, bb):
        z = block_diag_matmul(hh, ww, lp, 0, impl="einsum")
        z = z + bb * jnp.asarray(lp.active_unit_mask(1), jnp.float32)
        return _act(lp, 1, z, "sliced")

    def fus(hh, ww, bb):
        return block_diag_matmul(hh, ww, lp, 0, impl="fused", bias=bb,
                                 block_b=128)

    np.testing.assert_allclose(np.asarray(ref(h, w, bia)),
                               np.asarray(fus(h, w, bia)),
                               rtol=1e-5, atol=1e-6)
    ge = jax.grad(lambda *a: (ref(*a) ** 2).sum(), argnums=(0, 1, 2))(
        h, w, bia)
    gf = jax.grad(lambda *a: (fus(*a) ** 2).sum(), argnums=(0, 1, 2))(
        h, w, bia)
    jax.tree.map(
        lambda a_, b_: np.testing.assert_allclose(
            np.asarray(a_), np.asarray(b_), rtol=1e-4, atol=1e-4),
        ge, gf)


def test_bf16_grad_multi_batch_tile():
    """The two-level-grid backward under the bf16 policy at B > block_b:
    bf16 operands, f32 accumulators/grads — fused tracks einsum within
    bf16 tolerance across the batch-tile loop (accumulator dtype bugs
    amplify with more inner steps, so this is where they'd show)."""
    params, x, y = _params_and_batch(LP_ALL, b=160, seed=11)
    ge = jax.grad(lambda p: fused_loss(p, x, y, LP_ALL, "bucketed",
                                       "einsum", "sliced",
                                       "bfloat16")[0])(params)
    gf = jax.grad(lambda p: fused_loss(p, x, y, LP_ALL, "bucketed",
                                       "fused", "pallas",
                                       "bfloat16")[0])(params)
    for a, b in zip(jax.tree.leaves(ge), jax.tree.leaves(gf)):
        assert b.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-1, atol=5e-2)


@pytest.mark.parametrize("widths,acts,block", [
    (((3,), (5, 2), (9, 7, 4)), ("relu", "hardshrink", "gelu"), 4),
    (((1, 1), (2, 3), (2, 3), (6, 6)), ("selu", "elu", "tanh", "mish"), 8),
    (((11, 3, 5), (4,), (11, 3, 5)), ("gelu", "sigmoid", "leaky_relu"), 2),
])
def test_fused_matches_einsum_ragged_layouts(widths, acts, block):
    """Odd widths / bucket patterns / block sizes, per-mid-layer direct
    call (bias + activation composed manually for the reference)."""
    lp = LayeredPopulation(5, 2, widths, acts, block=block)
    params = init_params(jax.random.PRNGKey(3), lp)
    from repro.core.deep import _act
    for l in range(lp.depth - 1):
        w = params["mid"][l]["w"]
        b = params["mid"][l]["b"]
        h = jax.random.normal(jax.random.PRNGKey(10 + l),
                              (7, lp.layer_pop(l).total_hidden))

        def ref(hh, ww, bb):
            z = block_diag_matmul(hh, ww, lp, l, impl="einsum")
            z = z + bb * jnp.asarray(lp.active_unit_mask(l + 1), jnp.float32)
            return _act(lp, l + 1, z, "sliced")

        def fus(hh, ww, bb):
            return block_diag_matmul(hh, ww, lp, l, impl="fused", bias=bb)

        ye, yf = ref(h, w, b), fus(h, w, b)
        np.testing.assert_allclose(np.asarray(ye), np.asarray(yf),
                                   rtol=1e-5, atol=1e-6)
        ge = jax.grad(lambda *a: (ref(*a) ** 2).sum(), argnums=(0, 1, 2))(
            h, w, b)
        gf = jax.grad(lambda *a: (fus(*a) ** 2).sum(), argnums=(0, 1, 2))(
            h, w, b)
        jax.tree.map(
            lambda a_, b_: np.testing.assert_allclose(
                np.asarray(a_), np.asarray(b_), rtol=1e-4, atol=1e-5),
            ge, gf)


def test_fused_with_shard_pad_fillers():
    """Filler members (identity activation, trained but excluded from
    selection) ride through the fused kernel exactly like einsum."""
    lp = LayeredPopulation(6, 3, ((5, 3), (12, 9), (7,)),
                           ("relu", "mish", "tanh"), block=8)
    lp_pad = lp.shard_pad(4)
    assert lp_pad.n_pad > 0
    params = pad_params(init_params(jax.random.PRNGKey(0), lp), lp, lp_pad,
                        jax.random.PRNGKey(9))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 6))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 3)
    ye = forward(params, x, lp_pad, bd_impl="einsum")
    yf = forward(params, x, lp_pad, bd_impl="fused")
    np.testing.assert_allclose(np.asarray(ye), np.asarray(yf),
                               rtol=1e-5, atol=1e-6)
    ge = jax.grad(lambda p: fused_loss(p, x, y, lp_pad, "bucketed",
                                       "einsum")[0])(params)
    gf = jax.grad(lambda p: fused_loss(p, x, y, lp_pad, "bucketed",
                                       "fused")[0])(params)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6), ge, gf)


@pytest.mark.parametrize("bd_impl", sorted(BD_IMPLS))
def test_bf16_policy_tracks_f32(bd_impl):
    """bf16 operands / f32 accumulators: every impl's bf16 loss and
    gradients stay within bf16 tolerance of its f32 run, and the f32
    master-parameter update keeps its dtype."""
    params, x, y = _params_and_batch(LP_ALL)
    l32, _ = fused_loss(params, x, y, LP_ALL, "bucketed", bd_impl)
    l16, _ = fused_loss(params, x, y, LP_ALL, "bucketed", bd_impl,
                        compute_dtype="bfloat16")
    assert l16.dtype == jnp.float32          # fp32 loss under the policy
    np.testing.assert_allclose(float(l32), float(l16), rtol=5e-2)

    g32 = jax.grad(lambda p: fused_loss(p, x, y, LP_ALL, "bucketed",
                                        bd_impl)[0])(params)
    g16 = jax.grad(lambda p: fused_loss(p, x, y, LP_ALL, "bucketed",
                                        bd_impl, "sliced",
                                        "bfloat16")[0])(params)
    for a, b in zip(jax.tree.leaves(g32), jax.tree.leaves(g16)):
        assert b.dtype == jnp.float32        # grads land f32 on f32 masters
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-1, atol=5e-2)

    new, _, _ = sgd_step(params, x, y, 0.05, LP_ALL, "bucketed", bd_impl,
                         "sliced", "bfloat16")
    assert all(p.dtype == jnp.float32 for p in jax.tree.leaves(new))


def test_bf16_fused_matches_bf16_einsum():
    """The fused kernel's bf16 path agrees with the einsum bf16 path far
    tighter than either agrees with f32 — the epilogue itself adds no
    precision loss beyond the operand cast."""
    params, x, _ = _params_and_batch(LP_ALL)
    ye = forward(params, x, LP_ALL, bd_impl="einsum",
                 compute_dtype="bfloat16")
    yf = forward(params, x, LP_ALL, bd_impl="fused",
                 compute_dtype="bfloat16")
    np.testing.assert_allclose(np.asarray(ye), np.asarray(yf),
                               rtol=2e-2, atol=2e-2)


def test_bench_refuses_unknown_impl():
    """Bench hygiene: a typo'd / backend-missing impl aborts loudly instead
    of silently falling back to another implementation."""
    import pathlib
    import sys
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root / "benchmarks"))
    try:
        import bench_m3_variants
        with pytest.raises(SystemExit, match="not available"):
            bench_m3_variants._require_impl("cutlass")
    finally:
        sys.path.remove(str(root / "benchmarks"))
