"""Data pipeline: determinism in (seed, step) — the restart-safety
contract — plus learnability structure."""
import numpy as np

from repro.data import TabularTask, TokenTask


def test_tabular_deterministic():
    a = TabularTask(200, 10, seed=3)
    b = TabularTask(200, 10, seed=3)
    np.testing.assert_array_equal(a.x, b.x)
    xa, ya = a.batch(17, 32)
    xb, yb = b.batch(17, 32)
    np.testing.assert_array_equal(xa, xb)
    np.testing.assert_array_equal(ya, yb)
    assert xa.shape == (32, 10) and ya.shape == (32,)


def test_tabular_epoch_covers_dataset():
    t = TabularTask(128, 5, seed=0)
    seen = set()
    for k in range(4):                      # one epoch = 4 batches of 32
        x, _ = t.batch(k, 32)
        seen.update(map(tuple, np.round(x, 5)))
    assert len(seen) == 128


def test_tabular_classes_separable():
    """A linear probe beats chance comfortably — MLPs have signal to learn."""
    t = TabularTask(2000, 10, n_classes=2, seed=1)
    (xtr, ytr), (xte, yte) = t.split()
    # least squares on ±1 targets
    w = np.linalg.lstsq(xtr, 2.0 * ytr - 1.0, rcond=None)[0]
    acc = ((xte @ w > 0) == yte).mean()
    assert acc > 0.7, acc


def test_token_task_deterministic_and_learnable():
    t = TokenTask(vocab=512, seed=5)
    b1 = t.batch(9, 4, 64)
    b2 = TokenTask(vocab=512, seed=5).batch(9, 4, 64)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # deterministic transition hit-rate ≈ 85% → predictable structure
    toks, labs = b1["tokens"], b1["labels"]
    jump = t._jump
    pred = (toks + jump[toks % t._v]) % t._v
    assert (pred == labs).mean() > 0.7


def test_different_steps_differ():
    t = TabularTask(100, 5, seed=0)
    x1, _ = t.batch(0, 32)
    x2, _ = t.batch(1, 32)
    assert not np.array_equal(x1, x2)
