"""MoE: the capacity-padded dispatch must equal an explicit per-token loop
(up to capacity drops, which we disable by over-provisioning), and both
expert-sharding layouts must agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.common import FFN_ACTS
from repro.nn.ffn import FFNConfig, MoEConfig, ffn_apply, moe_apply_dense, moe_init


def _reference_moe(p, cfg, x):
    """Per-token python loop: route, run top-k experts, weighted-sum."""
    b, s, d = x.shape
    xf = np.asarray(x.reshape(-1, d), np.float32)
    router = np.asarray(p["router"], np.float32)
    probs = jax.nn.softmax(jnp.asarray(xf @ router), axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.renorm_topk:
        gate_vals = gate_vals / gate_vals.sum(-1, keepdims=True)
    act = FFN_ACTS[cfg.act]
    wg = np.asarray(p["experts"]["w_gate"], np.float32)
    wu = np.asarray(p["experts"]["w_up"], np.float32)
    wd = np.asarray(p["experts"]["w_down"], np.float32)
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(cfg.top_k):
            e = int(eidx[t, j])
            h = np.asarray(act(jnp.asarray(xf[t] @ wg[e]))) * (xf[t] @ wu[e])
            out[t] += float(gate_vals[t, j]) * (h @ wd[e])
    if cfg.num_shared:
        shared_cfg = FFNConfig(d, cfg.d_expert * cfg.num_shared, act=cfg.act)
        out += np.asarray(ffn_apply(p["shared"], shared_cfg,
                                    jnp.asarray(xf)), np.float32)
    return out.reshape(b, s, d)


@pytest.mark.parametrize("renorm,shared", [(True, 0), (False, 2)])
def test_moe_dense_matches_reference(renorm, shared):
    cfg = MoEConfig(d_model=16, d_expert=8, num_experts=4, top_k=2,
                    num_shared=shared, renorm_topk=renorm,
                    capacity_factor=8.0)      # no drops
    p, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    got, aux = moe_apply_dense(p, cfg, x)
    want = _reference_moe(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0.0


def test_moe_tp_spec_same_math():
    """'tp' sharding changes specs only — values identical on one device."""
    kw = dict(d_model=16, d_expert=8, num_experts=4, top_k=2,
              capacity_factor=8.0)
    p_ep, s_ep = moe_init(jax.random.PRNGKey(0),
                          MoEConfig(sharding="ep", **kw), jnp.float32)
    p_tp, s_tp = moe_init(jax.random.PRNGKey(0),
                          MoEConfig(sharding="tp", **kw), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 5, 16))
    y1, _ = moe_apply_dense(p_ep, MoEConfig(sharding="ep", **kw), x)
    y2, _ = moe_apply_dense(p_tp, MoEConfig(sharding="tp", **kw), x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
    assert s_ep["experts"]["w_gate"] != s_tp["experts"]["w_gate"]


def test_capacity_drops_are_bounded():
    """With capacity 1.0 some tokens may drop but output stays finite and
    dropped slots contribute zero (not garbage)."""
    cfg = MoEConfig(d_model=8, d_expert=4, num_experts=2, top_k=2,
                    capacity_factor=0.25)
    p, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 8))
    y, aux = moe_apply_dense(p, cfg, x)
    assert np.isfinite(np.asarray(y)).all()


def test_moe_gradients_flow_only_to_used_experts():
    cfg = MoEConfig(d_model=8, d_expert=4, num_experts=4, top_k=1,
                    capacity_factor=8.0, aux_loss_coef=0.0)
    p, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, 8))

    def loss(pp):
        y, _ = moe_apply_dense(pp, cfg, x)
        return (y ** 2).sum()

    g = jax.grad(loss)(p)
    probs = jax.nn.softmax(
        jnp.asarray(np.asarray(x.reshape(-1, 8)) @ np.asarray(p["router"])), -1)
    used = set(np.asarray(jnp.argmax(probs, -1)).tolist())
    gnorm = np.asarray(jnp.stack(
        [jnp.abs(g["experts"]["w_gate"][e]).sum() for e in range(4)]))
    for e in range(4):
        assert (gnorm[e] > 0) == (e in used), (e, used, gnorm)
