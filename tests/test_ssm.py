"""Mamba2 SSD: the chunked dual must equal the naive sequential recurrence
(the definition of the SSM), streaming decode must match full-sequence, and
chunk size must not change results."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.ssm import (SSMConfig, init_ssm_cache, ssd_scan, ssm_apply,
                          ssm_decode_step, ssm_init)


def naive_recurrence(x, dt, a, b, c):
    """h_t = exp(a·dt_t)·h_{t-1} + dt_t·x_t·b_tᵀ ; y_t = h_t·c_t."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=2)
    ch = jnp.repeat(c, rep, axis=2)
    state = jnp.zeros((bs, h, p, n))
    ys = []
    for t in range(s):
        decay = jnp.exp(a[None] * dt[:, t])                      # (B,H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", x[:, t] * dt[:, t, :, None], bh[:, t])
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, ch[:, t]))
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_equals_recurrence(chunk):
    key = jax.random.PRNGKey(0)
    bs, s, h, p, g, n = 2, 16, 4, 8, 2, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bs, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    b = jax.random.normal(ks[3], (bs, s, g, n))
    c = jax.random.normal(ks[4], (bs, s, g, n))
    y, st = ssd_scan(x, dt, a, b, c, chunk)
    y_ref, st_ref = naive_recurrence(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_initial_state_chaining():
    """Running two halves with state carry == one full pass (the prefill
    invariant for long_500k streaming)."""
    key = jax.random.PRNGKey(1)
    bs, s, h, p, g, n = 1, 32, 2, 4, 1, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bs, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    b = jax.random.normal(ks[3], (bs, s, g, n))
    c = jax.random.normal(ks[4], (bs, s, g, n))
    y_full, st_full = ssd_scan(x, dt, a, b, c, 8)
    y1, st1 = ssd_scan(x[:, :16], dt[:, :16], a, b[:, :16], c[:, :16], 8)
    y2, st2 = ssd_scan(x[:, 16:], dt[:, 16:], a, b[:, 16:], c[:, 16:], 8,
                       initial_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-4, atol=1e-4)


def test_decode_matches_full_layer():
    cfg = SSMConfig(d_model=32, d_state=16, head_dim=16, chunk=8)
    p, _ = ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    full = ssm_apply(p, cfg, x)
    cache = init_ssm_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(24):
        o, cache = ssm_decode_step(p, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_prefill_cache_matches_decode_path():
    """ssm_apply(return_cache) then decode == decoding all the way."""
    cfg = SSMConfig(d_model=32, d_state=16, head_dim=16, chunk=8)
    p, _ = ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 21, 32))  # non-multiple
    _, cache_pre = ssm_apply(p, cfg, x[:, :20], return_cache=True)
    cache_seq = init_ssm_cache(cfg, 1, jnp.float32)
    for t in range(20):
        _, cache_seq = ssm_decode_step(p, cfg, x[:, t:t + 1], cache_seq)
    o1, _ = ssm_decode_step(p, cfg, x[:, 20:21], cache_pre)
    o2, _ = ssm_decode_step(p, cfg, x[:, 20:21], cache_seq)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
