"""Flash attention kernel vs the dense oracle: shape/dtype/mask sweeps in
interpret mode, GQA head-group index mapping, gradients through the
custom_vjp fallback, and agreement with the model's attention path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention
from repro.kernels.ref import flash_attn_ref


def _qkv(rng, b, h, hkv, sq, sk, dh, dtype):
    q = jnp.asarray(rng.normal(0, 1, (b, h, sq, dh)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, hkv, sk, dh)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, hkv, sk, dh)), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,sq,sk,dh,causal,window,bq,bk", [
    (2, 4, 2, 64, 64, 16, True, 0, 32, 32),      # GQA causal
    (1, 2, 2, 48, 80, 8, True, 0, 32, 32),        # Sq != Sk, padding
    (2, 4, 1, 64, 64, 16, True, 24, 32, 32),      # MQA + sliding window
    (1, 3, 3, 33, 65, 16, False, 0, 16, 32),      # non-causal, ragged pad
    (1, 8, 2, 128, 128, 32, True, 0, 128, 64),    # bigger blocks
])
def test_flash_matches_dense(b, h, hkv, sq, sk, dh, causal, window, bq, bk,
                             dtype, rng):
    q, k, v = _qkv(rng, b, h, hkv, sq, sk, dh, dtype)
    scale = dh ** -0.5
    got = flash_attention(q, k, v, scale, causal, window, bq, bk, True)
    want = flash_attn_ref(q, k, v, scale=scale, causal=causal, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_gradients(rng):
    q, k, v = _qkv(rng, 1, 2, 1, 32, 32, 8, jnp.float32)
    scale = 8 ** -0.5

    def loss_k(qq, kk, vv):
        return (flash_attention(qq, kk, vv, scale, True, 0, 16, 16, True)
                ** 2).sum()

    def loss_r(qq, kk, vv):
        return (flash_attn_ref(qq, kk, vv, scale=scale, causal=True,
                               window=0) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)


def test_flash_matches_model_attention(rng):
    """The kernel agrees with nn.attention's dense path on the model's
    (B,S,Hkv,G,dh) layout."""
    from repro.nn.attention import attend_dense
    b, hkv, g, s, dh = 2, 2, 3, 40, 16
    q5 = jnp.asarray(rng.normal(0, 1, (b, s, hkv, g, dh)), jnp.float32)
    k4 = jnp.asarray(rng.normal(0, 1, (b, s, hkv, dh)), jnp.float32)
    v4 = jnp.asarray(rng.normal(0, 1, (b, s, hkv, dh)), jnp.float32)
    pos = jnp.arange(s)
    scale = dh ** -0.5
    want = attend_dense(q5, k4, v4, pos, pos, causal=True, window=7,
                        scale=scale)
    # model layout → kernel layout
    qf = q5.reshape(b, s, hkv * g, dh).transpose(0, 2, 1, 3)
    kf = k4.transpose(0, 2, 1, 3)
    vf = v4.transpose(0, 2, 1, 3)
    got = flash_attention(qf, kf, vf, scale, True, 7, 16, 16, True)
    got = got.transpose(0, 2, 1, 3).reshape(b, s, hkv, g, dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
