"""Training-policy features added in §Perf: bf16 gradient accumulation,
microbatch-count invariance, and the hymba mixed global/SWA window pattern
under one scanned stack."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.cells import build_optimizer
from repro.models import lm
from repro.optim import constant_lr


def _setup(arch_id="qwen3-1.7b"):
    arch = get_arch(arch_id, reduced=True)
    cfg = arch.model
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    k = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(k, (8, 32), 0, cfg.vocab),
             "labels": jax.random.randint(k, (8, 32), 0, cfg.vocab)}
    return arch, cfg, params, batch


def test_microbatch_count_invariance():
    """num_micro=1 vs 4 give the same update (f32 accumulation)."""
    arch, cfg, params, batch = _setup()
    opt = build_optimizer(arch)
    outs = {}
    for n in (1, 4):
        step = lm.make_train_step(cfg, opt, constant_lr(1e-3), num_micro=n)
        p, _, m = jax.jit(step)(params, opt.init(params), batch,
                                jnp.zeros((), jnp.int32))
        outs[n] = (p, float(m["loss"]))
    assert abs(outs[1][1] - outs[4][1]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)


def test_bf16_accumulation_close_to_f32():
    """§Perf iter 5: bf16 accumulation tracks f32 within bf16 resolution."""
    arch, cfg, params, batch = _setup()
    opt = build_optimizer(arch)
    ps = {}
    for dt in (jnp.float32, jnp.bfloat16):
        step = lm.make_train_step(cfg, opt, constant_lr(1e-3), num_micro=4,
                                  accum_dtype=dt)
        p, _, _ = jax.jit(step)(params, opt.init(params), batch,
                                jnp.zeros((), jnp.int32))
        ps[dt] = p
    deltas = []
    for a, b, p0 in zip(jax.tree.leaves(ps[jnp.float32]),
                        jax.tree.leaves(ps[jnp.bfloat16]),
                        jax.tree.leaves(params)):
        step_size = np.abs(np.asarray(a, np.float32)
                           - np.asarray(p0, np.float32)).mean()
        diff = np.abs(np.asarray(a, np.float32)
                      - np.asarray(b, np.float32)).mean()
        if step_size > 0:
            deltas.append(diff / step_size)
    # bf16 accumulation error stays a small fraction of the actual update
    assert np.mean(deltas) < 0.15, np.mean(deltas)


def test_hymba_window_pattern_is_heterogeneous():
    """Global layers (window=0) must see past the SWA window while windowed
    layers must not — all under ONE scanned stack with traced windows."""
    arch = get_arch("hymba-1.5b", reduced=True)
    cfg = arch.model
    assert {ls.window for ls in cfg.layers} == {0, 16}
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    S = 40
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab)
    base, _ = lm.forward(params, cfg, {"tokens": toks})
    # perturb token 0; with a global layer present, the LAST position (far
    # beyond every 16-token window) must still change
    toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % cfg.vocab)
    out2, _ = lm.forward(params, cfg, {"tokens": toks2})
    assert not np.allclose(np.asarray(base[0, -1], np.float32),
                           np.asarray(out2[0, -1], np.float32), atol=1e-5)

    # with ONLY windowed layers (and no SSM path) the influence would die;
    # verify the mask logic via a pure-SWA attn-only variant
    swa_cfg = dataclasses.replace(
        cfg, layers=tuple(lm.LayerSpec("attn", "dense", 16)
                          for _ in range(3)))
    p2, _ = lm.init_params(jax.random.PRNGKey(0), swa_cfg)
    b1, _ = lm.forward(p2, swa_cfg, {"tokens": toks})
    b2, _ = lm.forward(p2, swa_cfg, {"tokens": toks2})
    # 3 layers × window 16 → receptive field ≤ 48 ≥ S… use last pos vs
    # a LONGER gap: perturbation at 0 cannot reach position 39 through
    # 2 windowed attn hops of 15 (max reach 30) — wait 3 hops reach 45.
    # Use 2 layers to bound reach at 30 < 39:
    swa2 = dataclasses.replace(swa_cfg, layers=swa_cfg.layers[:2])
    p3, _ = lm.init_params(jax.random.PRNGKey(0), swa2)
    c1, _ = lm.forward(p3, swa2, {"tokens": toks})
    c2, _ = lm.forward(p3, swa2, {"tokens": toks2})
    np.testing.assert_allclose(np.asarray(c1[0, -1], np.float32),
                               np.asarray(c2[0, -1], np.float32), atol=1e-4)
