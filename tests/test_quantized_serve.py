"""Int8 weight-only serving path (DESIGN.md §12): the quantized serve
copy (``quant.quantize_population``) through the fused-dequant forward
kernels, plus the serving-engine semantics that ride on it.

  numerics — the int8 forward is BIT-EXACT against the dequantized-
             reference tree run through the committed f32 path (the
             kernels' in-loop ``q·scale`` must equal the host-side
             dequant), and bounded-error against the f32 masters;
  budget   — ``forward(infer=True, weights_dtype="int8")`` keeps the
             depth+1 single-output launch contract;
  routing  — the int8 path is reachable ONLY via ``weights_dtype`` at
             serving time; every wrong spelling fails loudly;
  shared scale math — ``distributed.compression.quantize_int8`` now
             composes the ``repro.quant`` helpers: op sequence (and so
             the compressed all-reduce) bit-identical to the original
             inline formula;
  engine   — ``PopulationServer`` quantizes ONCE (masters released),
             and ``run``'s accounting: partial-slab max-latency,
             warmup excluded from p50/p99, members_served under a
             published subset and a filler-padded layout.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deep
from repro.core.activations import ACTIVATION_ORDER
from repro.core.ensemble import real_slots
from repro.core.population import LayeredPopulation
from repro.launch.launch_count import (count_pallas_launches,
                                       fused_infer_budget, max_eqn_outputs)
from repro.launch.serve_population import PopulationServer
from repro.quant import (dequantize_population, quantize_population,
                         serve_copy_bytes)

_WIDTHS = ((5, 3), (12, 9), (7,), (17, 9, 5), (8, 8),
           (5, 3), (3, 11, 2), (24, 16), (4,), (9, 9, 9))
LP = LayeredPopulation(6, 3, _WIDTHS, ACTIVATION_ORDER, block=8)
B = 9


def _params(lp=LP, seed=0):
    return deep.init_params(jax.random.PRNGKey(seed), lp)


def _x(b=B, lp=LP):
    return jax.random.normal(jax.random.PRNGKey(1), (b, lp.in_features))


def _infer_int8(qp, x, lp=LP, **kw):
    return deep.forward(qp, x, lp, bd_impl="fused", act_impl="pallas",
                        infer=True, weights_dtype="int8", **kw)


def _ref(params, x, lp=LP):
    return deep.forward(params, x, lp, bd_impl="einsum", act_impl="sliced")


# --------------------------------------------------------------------- #
# packer: tree layout + round-trip error bound                          #
# --------------------------------------------------------------------- #


def test_quantize_population_tree_layout():
    qp = quantize_population(_params(), LP)
    blk = LP.block
    h0 = LP.layer_pop(0).total_hidden
    assert qp["w_in"].dtype == jnp.int8
    assert qp["w_in"].shape[0] == h0
    assert qp["w_in"].shape[1] % 8 == 0          # pre-padded feature axis
    assert qp["w_in_scale"].shape == (h0 // blk,)
    for l, layer in enumerate(qp["mid"]):
        n = LP.bd_layout(l).n_param_blocks
        assert layer["wb"].dtype == jnp.int8
        # identity tile pre-augmented at quantize time, scale 1.0
        assert layer["wb"].shape == (n + 1, blk, blk)
        assert layer["scale"].shape == (n + 1,)
        np.testing.assert_array_equal(np.asarray(layer["wb"][-1]),
                                      np.eye(blk, dtype=np.int8))
        assert float(layer["scale"][-1]) == 1.0
    hl = LP.layer_pop(LP.depth - 1).total_hidden
    assert qp["w_out"].dtype == jnp.int8
    assert qp["w_out"].shape == (LP.out_features, hl)
    assert qp["w_out_scale"].shape == (hl // blk,)
    # weight-only: every bias stays full-precision
    for b in (qp["b_in"], qp["b_out"], *(m["b"] for m in qp["mid"])):
        assert b.dtype == jnp.float32
    # the weight bytes shrink 4x; on this tiny layout biases/scales eat
    # into the ratio, so assert the conservative half bound here (the
    # --quant bench records the real ratio on the bench population)
    assert serve_copy_bytes(qp) < serve_copy_bytes(_params()) / 2


def test_dequantize_round_trip_error_bound():
    """Symmetric per-tile int8: |x - dq(q(x))| <= scale/2, and scale is
    the tile max over 127 — so the global bound is max|leaf| / 254."""
    params = _params()
    dq = dequantize_population(quantize_population(params, LP), LP)
    flat_p, _ = jax.tree.flatten(params)
    flat_d, _ = jax.tree.flatten(dq)
    for a, b in zip(flat_p, flat_d):
        bound = float(jnp.max(jnp.abs(a))) / 254.0 + 1e-6
        assert float(jnp.max(jnp.abs(a - b))) <= bound


# --------------------------------------------------------------------- #
# numerics: fused dequant == host dequant, bit for tolerance            #
# --------------------------------------------------------------------- #


def test_int8_forward_matches_dequant_reference():
    """The kernels' in-loop q·scale must reproduce the host-side
    dequantized tree exactly (same f32 ops, same order) — compared
    through the independent einsum reference path."""
    params, x = _params(), _x()
    qp = quantize_population(params, LP)
    got = _infer_int8(qp, x)
    want = _ref(dequantize_population(qp, LP), x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_int8_forward_bounded_error_vs_f32_masters():
    params, x = _params(), _x()
    y_f32 = deep.forward(params, x, LP, bd_impl="fused",
                         act_impl="pallas", infer=True)
    y_q = _infer_int8(quantize_population(params, LP), x)
    np.testing.assert_allclose(y_q, y_f32, rtol=0.1, atol=0.5)


def test_int8_log_probs_in_kernel():
    params, x = _params(), _x()
    qp = quantize_population(params, LP)
    got = _infer_int8(qp, x, log_probs=True)
    want = jax.nn.log_softmax(_ref(dequantize_population(qp, LP), x),
                              axis=-1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.exp(got).sum(-1), 1.0, rtol=1e-5)


def test_int8_on_shard_padded_layout():
    lpp = LP.shard_pad(4)
    assert lpp.num_members > real_slots(lpp)
    params = _params(lpp)
    x = _x(lp=lpp)
    qp = quantize_population(params, lpp)
    np.testing.assert_allclose(
        _infer_int8(qp, x, lpp), _ref(dequantize_population(qp, lpp), x, lpp),
        rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# launch budget under int8                                              #
# --------------------------------------------------------------------- #


def test_int8_keeps_infer_launch_budget():
    params, x = _params(), _x()
    qp = quantize_population(params, LP)

    def fwd(p):
        return _infer_int8(p, x)

    budget = fused_infer_budget(LP.depth)
    assert count_pallas_launches(fwd, qp) == budget["total"]
    assert max_eqn_outputs(fwd, qp) == 1


# --------------------------------------------------------------------- #
# routing: the int8 path only via weights_dtype, loud-fail otherwise    #
# --------------------------------------------------------------------- #


def test_int8_requires_infer():
    qp = quantize_population(_params(), LP)
    with pytest.raises(ValueError, match="serving-only"):
        deep.forward(qp, _x(), LP, bd_impl="fused", act_impl="pallas",
                     weights_dtype="int8")


def test_int8_not_selectable_as_bd_impl():
    with pytest.raises(ValueError, match="weights_dtype"):
        deep.forward(_params(), _x(), LP, bd_impl="fused_int8",
                     act_impl="pallas", infer=True)


def test_int8_head_impl_must_match():
    qp = quantize_population(_params(), LP)
    with pytest.raises(ValueError, match="head_impl"):
        _infer_int8(qp, _x(), head_impl="fused")
    with pytest.raises(ValueError, match="head_impl"):
        deep.forward(_params(), _x(), LP, bd_impl="fused",
                     act_impl="pallas", infer=True, head_impl="fused_int8")


def test_unknown_weights_dtype_rejected():
    with pytest.raises(ValueError, match="weights_dtype"):
        deep.forward(_params(), _x(), LP, bd_impl="fused",
                     act_impl="pallas", infer=True, weights_dtype="int4")


# --------------------------------------------------------------------- #
# shared scale math: compression.quantize_int8 regression               #
# --------------------------------------------------------------------- #


def test_quantize_int8_bit_identical_to_inline_formula():
    """The gradient compressor now composes ``repro.quant`` helpers; the
    result (q, scale, error-feedback residual) must be BIT-identical to the
    pre-refactor inline formula — so the compressed all-reduce stream is
    unchanged."""
    from repro.distributed.compression import quantize_int8
    g = jax.random.normal(jax.random.PRNGKey(2), (513,)) * 3.7
    err = jax.random.normal(jax.random.PRNGKey(3), (513,)) * 0.01
    # the original inline op sequence, verbatim
    gf = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q_ref = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    err_ref = gf - q_ref.astype(jnp.float32) * scale
    q, s, e = quantize_int8(g, err)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    assert float(s) == float(scale)
    np.testing.assert_array_equal(np.asarray(e), np.asarray(err_ref))


# --------------------------------------------------------------------- #
# serving engine: quantize-once + run() accounting                      #
# --------------------------------------------------------------------- #


def _calib(lp, n=32, seed=4):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, lp.in_features))
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0,
                           lp.out_features)
    return x, y


def test_server_quantizes_once_and_serves_int8():
    server = PopulationServer(_params(), LP, weights_dtype="int8",
                              batch=8, topk=2, max_latency_ms=5.0)
    assert server.check_budget()["launches"] == LP.depth + 1
    # after the first consumer touched params, ONLY the int8 copy remains
    assert server.params["w_in"].dtype == jnp.int8
    qp = server.params
    xc, yc = _calib(LP)
    board = server.publish(xc, yc)
    assert server.params is qp                   # no re-quantization
    assert len(server.published["topk"]) == 2
    r = server.run(np.asarray(_x(16)), "topk")
    assert r["members_served"] == 2
    assert r["pred"].shape == (16,)
    assert set(np.unique(r["pred"])) <= set(range(LP.out_features))
    assert board[0]["rank"] == 1


def test_server_refresh_requantizes():
    server = PopulationServer(_params(), LP, weights_dtype="int8",
                              batch=8, topk=2)
    server.check_budget()
    assert server.params["w_in"].dtype == jnp.int8
    server.refresh(_params(seed=5), LP)
    assert server.params["w_in"].dtype == jnp.float32   # new masters
    server.check_budget()
    assert server.params["w_in"].dtype == jnp.int8      # re-quantized


def _fake_server(lp, *, batch, max_latency_ms, first_call_sleep=0.0):
    """A server whose per-mode steps are instant host functions — isolates
    ``run``'s batching/latency accounting from kernel wall-clock."""
    server = PopulationServer(_params(lp), lp, batch=batch,
                              max_latency_ms=max_latency_ms)
    state = {"calls": 0}

    def fake_step(params, xb):
        state["calls"] += 1
        if state["calls"] == 1 and first_call_sleep:
            time.sleep(first_call_sleep)     # stands in for jit compile
        b = xb.shape[0]
        return {"pred": jnp.zeros(b, jnp.int32),
                "mutual_information": jnp.zeros(b, jnp.float32)}

    for m in ("all", "topk", "best1"):
        server._steps[m] = fake_step
    return server, state


def test_run_partial_slab_pays_max_latency():
    """A timer-fired partial slab's requests record the max-latency wait;
    a full slab's do not."""
    server, _ = _fake_server(LP, batch=8, max_latency_ms=200.0)
    xs = np.zeros((4, LP.in_features), np.float32)     # one partial slab
    r = server.run(xs, "all", warmup=False)
    assert r["p50_ms"] >= 200.0 and r["p99_ms"] >= 200.0
    server, _ = _fake_server(LP, batch=8, max_latency_ms=200.0)
    r_full = server.run(np.zeros((8, LP.in_features), np.float32), "all",
                        warmup=False)
    assert r_full["p99_ms"] < 200.0                    # flushed on fill


def test_run_warmup_excluded_from_percentiles():
    """The warmup slab runs before the clock starts, so first-call cost
    (compilation) never lands in p50/p99."""
    server, state = _fake_server(LP, batch=4, max_latency_ms=1.0,
                                 first_call_sleep=0.25)
    r = server.run(np.zeros((8, LP.in_features), np.float32), "all",
                   warmup=True)
    assert state["calls"] == 3                         # warmup + 2 slabs
    assert r["p99_ms"] < 200.0
    server, _ = _fake_server(LP, batch=4, max_latency_ms=1.0,
                             first_call_sleep=0.25)
    r = server.run(np.zeros((8, LP.in_features), np.float32), "all",
                   warmup=False)
    assert r["p99_ms"] >= 200.0                        # cost hit a request


def test_run_members_served_accounting():
    """members_served: the published subset's size per mode; 'all' counts
    REAL members only on a filler-padded layout."""
    lpp = LP.shard_pad(4)
    assert lpp.num_members > real_slots(lpp)
    server, _ = _fake_server(lpp, batch=4, max_latency_ms=1.0)
    server.published = {"all": None, "topk": [0, 3, 5], "best1": [2]}
    xs = np.zeros((4, lpp.in_features), np.float32)
    assert server.run(xs, "all", warmup=False)["members_served"] \
        == real_slots(lpp)
    assert server.run(xs, "topk", warmup=False)["members_served"] == 3
    assert server.run(xs, "best1", warmup=False)["members_served"] == 1
