"""Per-architecture smoke tests (assignment deliverable f): for every
assigned arch, instantiate the REDUCED same-family config and run one
forward + one train step on CPU asserting output shapes and no NaNs;
decoder archs additionally verify prefill→decode == full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCH_IDS, get_arch
from repro.launch.cells import build_optimizer
from repro.models import encdec, lm
from repro.optim import constant_lr

LM_ARCHS = [a for a in ALL_ARCH_IDS
            if get_arch(a, reduced=True).kind == "lm"]


def _lm_batch(cfg, b=2, s=16, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(k, (b, s), 0, cfg.vocab)}
    if cfg.frontend == "embeds":
        batch["embeds"] = jax.random.normal(k, (b, s, cfg.d_model))
        del batch["tokens"]
    return batch


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke(arch_id):
    arch = get_arch(arch_id, reduced=True)
    cfg = arch.model
    params, specs = lm.init_params(jax.random.PRNGKey(0), cfg)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: not isinstance(x, dict))
    batch = _lm_batch(cfg)
    logits, aux = lm.forward(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    opt = build_optimizer(arch)
    state = opt.init(params)
    step = lm.make_train_step(cfg, opt, constant_lr(arch.lr), num_micro=2)
    p2, s2, m = jax.jit(step)(params, state, batch,
                              jnp.zeros((), jnp.int32))
    assert np.isfinite(float(m["loss"]))
    # parameters actually moved
    moved = any(not np.allclose(np.asarray(a, np.float32),
                                np.asarray(b, np.float32))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_decode_consistency(arch_id):
    arch = get_arch(arch_id, reduced=True)
    cfg = arch.model
    if cfg.frontend == "embeds":
        pytest.skip("embeds frontend covered in test_vlm_embeds_decode")
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits, _ = lm.forward(params, cfg, {"tokens": toks})
    V = cfg.vocab
    lg_pre, caches = lm.prefill(params, cfg, {"tokens": toks[:, :-1]},
                                max_len=32)
    np.testing.assert_allclose(
        np.asarray(lg_pre[:, 0, :V], np.float32),
        np.asarray(logits[:, -2, :V], np.float32), rtol=1e-3, atol=1e-3)
    serve = lm.make_serve_step(cfg)
    lg_dec, _ = serve(params, caches, {"tokens": toks[:, -1:]},
                      jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, 0, :V], np.float32),
        np.asarray(logits[:, -1, :V], np.float32), rtol=1e-3, atol=1e-3)


def test_vlm_embeds_decode():
    arch = get_arch("qwen2-vl-72b", reduced=True)
    cfg = arch.model
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    emb = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    logits, _ = lm.forward(params, cfg, {"embeds": emb})
    lg_pre, caches = lm.prefill(params, cfg, {"embeds": emb[:, :-1]},
                                max_len=16)
    serve = lm.make_serve_step(cfg)
    lg_dec, _ = serve(params, caches, {"embeds": emb[:, -1:]},
                      jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, 0, :cfg.vocab], np.float32),
        np.asarray(logits[:, -1, :cfg.vocab], np.float32),
        rtol=1e-3, atol=1e-3)


def test_whisper_smoke_and_decode():
    arch = get_arch("whisper-small", reduced=True)
    cfg = arch.model
    params, _ = encdec.init_params(jax.random.PRNGKey(0), cfg)
    B, Se, St = 2, 12, 8
    k = jax.random.PRNGKey(1)
    frames = jax.random.normal(k, (B, Se, cfg.d_model))
    toks = jax.random.randint(k, (B, St), 0, cfg.vocab)
    batch = {"frames": frames, "tokens": toks, "labels": toks}
    logits, _ = encdec.forward(params, cfg, batch)
    assert logits.shape == (B, St, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    opt = build_optimizer(arch)
    step = encdec.make_train_step(cfg, opt, constant_lr(1e-3))
    p2, _, m = jax.jit(step)(params, opt.init(params), batch,
                             jnp.zeros((), jnp.int32))
    assert np.isfinite(float(m["loss"]))
    caches = encdec.prepare_serve_caches(params, cfg, frames, max_len=St)
    serve = encdec.make_serve_step(cfg)
    errs = []
    for t in range(St):
        lg, caches = serve(params, caches, {"tokens": toks[:, t:t + 1]},
                           jnp.full((B,), t, jnp.int32))
        errs.append(float(jnp.abs(
            lg[:, 0, :cfg.vocab] - logits[:, t, :cfg.vocab]).max()))
    assert max(errs) < 1e-3, errs


def test_population_smoke():
    from repro.core import Population, init_params, sgd_step
    arch = get_arch("parallelmlp-10k", reduced=True)
    pop = arch.model
    params = init_params(jax.random.PRNGKey(0), pop)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, pop.in_features))
    y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, pop.out_features)
    p2, loss, per = sgd_step(params, x, y, 0.05, pop)
    assert per.shape == (pop.num_members,)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch_id", sorted(ALL_ARCH_IDS))
def test_full_configs_build_abstractly(arch_id):
    """FULL configs are exercised abstractly (eval_shape; no allocation)."""
    arch = get_arch(arch_id)
    if arch.kind == "population":
        assert arch.model.num_members == 10_000
        return
    mod = encdec if arch.kind == "encdec" else lm
    abs_p, specs = mod.abstract_params(arch.model)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abs_p))
    assert n > 1e8   # every assigned arch is ≥100M params
    assert jax.tree.structure(abs_p) == jax.tree.structure(
        specs, is_leaf=lambda x: not isinstance(x, dict))
