"""Feature selection via per-member input masks (paper §7): masked members
never use masked features, and importance attribution finds the features
that actually carry signal."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Population, init_params
from repro.core.feature_selection import (apply_masks, feature_importance,
                                          masked_sgd_step, random_masks,
                                          unit_masks)
from repro.core.parallel_mlp import forward, member_losses


def test_masked_features_are_inert():
    pop = Population(6, 2, (4, 7, 3), ("relu", "tanh", "gelu"), block=4)
    params = init_params(jax.random.PRNGKey(0), pop)
    masks = jnp.asarray([[1, 1, 0, 0, 1, 1],
                         [1, 0, 1, 0, 1, 0],
                         [0, 1, 1, 1, 0, 0]], jnp.float32)
    mp = apply_masks(params, pop, masks)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 6))
    base = forward(mp, x, pop)
    # perturb masked features wildly → member outputs must not move
    for m in range(3):
        x2 = x + 100.0 * (1 - masks[m])[None, :]
        out2 = forward(mp, x2, pop)
        np.testing.assert_allclose(np.asarray(out2[:, m]),
                                   np.asarray(base[:, m]), atol=1e-4,
                                   err_msg=f"member {m} saw a masked feature")


def test_masks_survive_training():
    pop = Population(6, 2, (4, 7, 3), ("relu", "tanh", "gelu"), block=4)
    params = init_params(jax.random.PRNGKey(0), pop)
    masks = random_masks(jax.random.PRNGKey(1), 3, 6, keep_prob=0.5)
    key = jax.random.PRNGKey(2)
    for _ in range(5):
        key, k1, k2 = jax.random.split(key, 3)
        x = jax.random.normal(k1, (16, 6))
        y = jax.random.randint(k2, (16,), 0, 2)
        params, _, _ = masked_sgd_step(params, x, y, 0.1, pop, masks)
    um = np.asarray(unit_masks(pop, masks))
    w1 = np.asarray(params["w1"])
    assert np.abs(w1 * (1 - um)).max() == 0.0, "masked weights reappeared"


def test_importance_finds_signal_features():
    """Labels depend ONLY on features 0 and 1; importance must rank them on
    top after training a masked population."""
    rng = np.random.default_rng(0)
    F, N = 8, 1024
    x = rng.normal(0, 1, (N, F)).astype(np.float32)
    y = ((x[:, 0] + x[:, 1]) > 0).astype(np.int32)
    pop = Population(F, 2, tuple([6] * 24), ("relu",) * 24, block=4)
    params = init_params(jax.random.PRNGKey(0), pop)
    masks = random_masks(jax.random.PRNGKey(3), 24, F, keep_prob=0.5)
    xb, yb = jnp.asarray(x), jnp.asarray(y)
    for step in range(60):
        i = (step * 128) % (N - 128)
        params, _, _ = masked_sgd_step(params, xb[i:i + 128], yb[i:i + 128],
                                       0.2, pop, masks)
    logits = forward(apply_masks(params, pop, masks), xb, pop)
    per = member_losses(logits, yb, "classification")
    imp = feature_importance(pop, masks, per)
    top2 = set(np.argsort(imp)[-2:].tolist())
    assert top2 == {0, 1}, (top2, imp)
