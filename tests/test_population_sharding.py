"""Distribution-native layered populations: spec emission, member-count
shard padding, the scanned/donated train chunk, and (in a forced 4-device
subprocess) sharded-vs-single-device training equality with mid-layer
bucket params actually sharded over the model axis."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import deep
from repro.core.population import LayeredPopulation

LP = LayeredPopulation(
    6, 3,
    widths=((7,), (13, 5), (64, 32, 16), (13, 5)),
    activations=("relu", ("tanh", "gelu"), ("mish", "sigmoid", "tanh"),
                 ("tanh", "gelu")),
    block=8).sorted()


def test_param_specs_structure_matches_params():
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    specs = LP.param_specs()
    assert (jax.tree_util.tree_structure(
        specs, is_leaf=lambda s: isinstance(s, P))
        == jax.tree_util.tree_structure(jax.tree.map(lambda x: 0, params)))
    # member-major axes carry the population axis
    assert specs["w_in"] == P("model", None)
    assert specs["w_out"] == P(None, "model")
    assert specs["b_out"] == P("model", None)
    for lay in specs["mid"]:
        assert lay["b"] == P("model")
        for s in lay["w"]:
            assert s == P("model", None, None)


def test_opt_specs_structure_matches_state():
    from repro.optim import sgd
    opt = sgd(momentum=0.9)
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    state = opt.init(params)
    specs = LP.opt_specs(opt)
    assert (jax.tree_util.tree_structure(
        specs, is_leaf=lambda s: isinstance(s, P))
        == jax.tree_util.tree_structure(jax.tree.map(lambda x: 0, state)))


@pytest.mark.parametrize("n_shards", [2, 3, 4, 6])
def test_shard_pad_divisibility(n_shards):
    lp = LP.shard_pad(n_shards)
    assert lp.num_members % n_shards == 0
    for l in range(lp.depth):
        assert lp.layer_pop(l).total_hidden % (n_shards * lp.block) == 0
    # pads are trailing, identity-activated, full-depth
    assert lp.num_real == LP.num_members
    assert lp.widths[:lp.num_real] == LP.widths
    for m in range(lp.num_real, lp.num_members):
        assert lp.activations[m] == ("identity",) * lp.depth
    # idempotent once aligned
    assert lp.shard_pad(n_shards) == lp
    # no-op cases
    assert LP.shard_pad(1) == LP


def test_shard_pad_sorted_keeps_pads_trailing():
    lp = LP.shard_pad(4).sorted()
    assert lp.num_real == LP.num_members
    for m in range(lp.num_real, lp.num_members):
        assert lp.activations[m] == ("identity",) * lp.depth


def test_pad_params_real_region_bit_identical():
    lp = LP.shard_pad(3)
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    padded = deep.pad_params(params, LP, lp,
                             jax.random.fold_in(jax.random.PRNGKey(0), 1))
    p0 = LP.layer_pop(0)
    h0 = p0.total_hidden
    np.testing.assert_array_equal(np.asarray(padded["w_in"][:h0]),
                                  np.asarray(params["w_in"]))
    np.testing.assert_array_equal(np.asarray(padded["b_out"][:LP.num_members]),
                                  np.asarray(params["b_out"]))
    for l in range(LP.depth - 1):
        for bi, w in enumerate(params["mid"][l]["w"]):
            np.testing.assert_array_equal(
                np.asarray(padded["mid"][l]["w"][bi]), np.asarray(w))
    np.testing.assert_array_equal(
        np.asarray(padded["w_out"][:, :LP.layer_pop(LP.depth - 1).total_hidden]),
        np.asarray(params["w_out"]))


def test_pad_members_train_like_fillers_dont_leak():
    """Training the padded population leaves the real members' trajectory
    identical to the unpadded one (the pads are just more independent
    members)."""
    lp = LP.shard_pad(3)
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    padded = deep.pad_params(params, LP, lp,
                             jax.random.fold_in(jax.random.PRNGKey(0), 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 6))
    y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, 3)
    for _ in range(3):
        params, _, per_u = deep.sgd_step(params, x, y, 0.05, LP)
        padded, _, per_p = deep.sgd_step(padded, x, y, 0.05, lp)
    np.testing.assert_allclose(np.asarray(per_p[:LP.num_members]),
                               np.asarray(per_u), rtol=1e-5, atol=1e-6)


def test_scanned_chunk_equals_per_step_loop():
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 12, 6))
    ys = jax.random.randint(jax.random.PRNGKey(2), (5, 12), 0, 3)
    lrs = jnp.array([0.05, 0.1, 0.02, 0.07])

    p_loop = params
    loop_losses = []
    for i in range(5):
        p_loop, loss, _ = deep.sgd_step(p_loop, xs[i], ys[i], lrs, LP)
        loop_losses.append(float(loss))

    chunk = deep.make_population_train_step(LP, scan_steps=5, donate=False)
    p_scan, losses, pers = chunk(params, xs, ys, lrs)
    assert pers.shape == (5, LP.num_members)
    np.testing.assert_allclose(np.asarray(losses), np.asarray(loop_losses),
                               rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), p_loop, p_scan)


def test_make_population_train_step_donates():
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 6))
    ys = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 3)
    chunk = deep.make_population_train_step(LP, scan_steps=2)
    _ = chunk(params, xs, ys, 0.05)
    assert params["w_in"].is_deleted()  # the donated tree was consumed
    with pytest.raises(ValueError):
        deep.make_population_train_step(LP, scan_steps=0)


@pytest.mark.parametrize("act_impl", ["masked", "pallas"])
def test_act_impl_matches_sliced(act_impl):
    """seg_act Pallas dispatch (and the masked oracle) agree with the
    sliced default — forward AND gradients, through the whole deep net."""
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    x = jax.random.normal(jax.random.PRNGKey(1), (9, 6))
    y = jax.random.randint(jax.random.PRNGKey(2), (9,), 0, 3)
    ya = deep.forward(params, x, LP, act_impl=act_impl)
    yb = deep.forward(params, x, LP)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=1e-5, atol=1e-5)
    ga = jax.grad(lambda p: deep.fused_loss(
        p, x, y, LP, "bucketed", "einsum", act_impl)[0])(params)
    gb = jax.grad(lambda p: deep.fused_loss(p, x, y, LP)[0])(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5), ga, gb)


def test_population_shardings_single_device():
    """population_shardings degrades to replication on the 1-device CPU
    (no mesh axes to shard over) but returns a full NamedSharding tree."""
    from repro.compat import make_mesh
    from repro.distributed.sharding import population_shardings
    mesh = make_mesh((1, 1), ("data", "model"))
    sh = population_shardings(LP, mesh)
    leaves = jax.tree.leaves(sh)
    assert leaves and all(hasattr(s, "spec") for s in leaves)


_BATCH_SHARDING = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.distributed.sharding import population_batch_shardings
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(model=2)          # (data=2, model=2)
assert dict(mesh.shape) == {"data": 2, "model": 2}

# dividing batch: the batch axis actually shards over 'data'
sh_x, sh_y = population_batch_shardings(mesh, 8)
xs = jax.device_put(np.zeros((3, 8, 6), np.float32), sh_x)
ys = jax.device_put(np.zeros((3, 8), np.int32), sh_y)
assert not xs.sharding.is_fully_replicated, str(xs.sharding)
assert "data" in str(xs.sharding.spec) and "data" in str(ys.sharding.spec)
# ...and the leading scan axis stays whole on every device
assert xs.addressable_shards[0].data.shape == (3, 4, 6)

# non-dividing batch: documented fallback to replication
sh_x7, _ = population_batch_shardings(mesh, 7)
x7 = jax.device_put(np.zeros((3, 7, 6), np.float32), sh_x7)
assert x7.sharding.is_fully_replicated, str(x7.sharding)
print("OK")
"""


@pytest.mark.slow
def test_population_batch_shardings_data_axis(tmp_path):
    """Train batches shard over the mesh 'data' axis (scan axis whole,
    batch axis split), degrading to replication when the batch size
    doesn't divide the axis."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    r = subprocess.run([sys.executable, "-c", _BATCH_SHARDING],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


_SHARDED_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.launch.train import main

params, lp = main([
    "--arch", "parallelmlp-10k", "--reduced", "--steps", "10",
    "--population-depths", "16,8;16,8;12,4;12,4;7;9", "--population-acts",
    "relu,tanh", "--scan-steps", "5", "--ckpt-every", "0",
    "--ckpt-dir", sys.argv[1] + "/ck"])
assert len(jax.devices()) == 4
# mid-layer bucket params must ACTUALLY shard over the model axis
sharded = [w for w in params["mid"][0]["w"]
           if not w.sharding.is_fully_replicated
           and "model" in str(w.sharding.spec)]
assert sharded, [str(w.sharding) for w in params["mid"][0]["w"]]
from repro.core.selection import evaluate_population
from repro.data import TabularTask
task = TabularTask(2048, lp.in_features, n_classes=lp.out_features, seed=0)
(_, _), (xte, yte) = task.split()
losses, _ = evaluate_population(params, lp, jnp.asarray(xte),
                                jnp.asarray(yte))
with open(sys.argv[1] + "/losses.json", "w") as f:
    json.dump({"losses": np.asarray(losses)[:lp.num_real].tolist(),
               "num_real": lp.num_real, "n_pad": lp.n_pad}, f)
print("OK")
"""


@pytest.mark.slow
def test_sharded_equals_single_device_training(tmp_path):
    """Acceptance: on a 4-fake-device host mesh, sharded run_population
    training produces per-member losses equal (to float tolerance) to the
    single-device run, with mid-layer buckets sharded over 'model'."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    r = subprocess.run([sys.executable, "-c", _SHARDED_DRIVER,
                        str(tmp_path)],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    with open(tmp_path / "losses.json") as f:
        sharded = json.load(f)
    assert sharded["n_pad"] > 0  # 6 members on 4 shards: padding exercised

    # identical run, single device, in-process
    from repro.core.selection import evaluate_population
    from repro.data import TabularTask
    from repro.launch.train import main
    params, lp = main([
        "--arch", "parallelmlp-10k", "--reduced", "--steps", "10",
        "--population-depths", "16,8;16,8;12,4;12,4;7;9",
        "--population-acts", "relu,tanh", "--scan-steps", "5",
        "--ckpt-every", "0", "--ckpt-dir", str(tmp_path / "ck1")])
    assert lp.n_pad == 0
    task = TabularTask(2048, lp.in_features, n_classes=lp.out_features,
                       seed=0)
    (_, _), (xte, yte) = task.split()
    losses, _ = evaluate_population(params, lp, jnp.asarray(xte),
                                    jnp.asarray(yte))
    np.testing.assert_allclose(
        np.asarray(sharded["losses"]),
        np.asarray(losses)[:sharded["num_real"]], rtol=2e-5, atol=2e-6)
