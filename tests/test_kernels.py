"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
oracles in kernels/ref.py, swept over shapes and dtypes, values + grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import m3_matmul, moe_gemm, seg_act
from repro.kernels import ref


def _seg_layout(rng, n_members, blocks_per=3, block_h=8):
    """Random contiguous per-block member ids (sorted)."""
    counts = rng.integers(1, blocks_per + 1, n_members)
    ids = np.repeat(np.arange(n_members, dtype=np.int32), counts)
    return ids, int(ids.size * block_h)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,o,members,block_h", [
    (4, 3, 2, 8), (16, 2, 5, 8), (7, 9, 3, 16), (1, 1, 1, 8), (32, 4, 7, 8),
])
def test_m3_matmul_kernel(b, o, members, block_h, dtype, rng):
    ids, hh = _seg_layout(rng, members, block_h=block_h)
    h = jnp.asarray(rng.normal(0, 1, (b, hh)), dtype)
    w2 = jnp.asarray(rng.normal(0, 1, (o, hh)), dtype)
    got = m3_matmul(h, w2, ids, members, block_h=block_h, interpret=True)
    want = ref.m3_matmul_ref(h, w2, ids, members, block_h)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_m3_matmul_kernel_grads(rng):
    ids, hh = _seg_layout(rng, 4, block_h=8)
    h = jnp.asarray(rng.normal(0, 1, (8, hh)), jnp.float32)
    w2 = jnp.asarray(rng.normal(0, 1, (3, hh)), jnp.float32)

    def loss_k(hh_, ww):
        return (m3_matmul(hh_, ww, ids, 4, block_h=8, interpret=True) ** 2) \
            .sum()

    def loss_r(hh_, ww):
        return (ref.m3_matmul_ref_f32out(hh_, ww, ids, 4, 8) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1))(h, w2)
    gr = jax.grad(loss_r, argnums=(0, 1))(h, w2)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,blocks,block_h", [(4, 3, 8), (9, 10, 8), (2, 4, 16)])
def test_seg_act_kernel(b, blocks, block_h, dtype, rng):
    ids = jnp.asarray(rng.integers(0, 10, blocks), jnp.int32)
    hh = blocks * block_h
    mask = (rng.random(hh) > 0.2).astype(np.float32)
    h = jnp.asarray(rng.normal(0, 1, (b, hh)), dtype)
    got = seg_act(h, np.asarray(ids), mask, block_h=block_h, interpret=True)
    want = ref.seg_act_ref(h, np.asarray(ids), block_h, mask)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,d,f,block_t", [(2, 16, 24, 8), (4, 32, 16, 8),
                                           (1, 8, 8, 8)])
def test_moe_gemm_kernel(e, d, f, block_t, dtype, rng):
    # tokens sorted by expert, each expert's run a multiple of block_t
    runs = rng.integers(1, 4, e)
    eids = np.repeat(np.arange(e, dtype=np.int32), runs)
    t = int(eids.size) * block_t
    x = jnp.asarray(rng.normal(0, 1, (t, d)), dtype)
    w = jnp.asarray(rng.normal(0, 1, (e, d, f)), dtype)
    got = moe_gemm(x, w, eids, block_t=block_t, block_d=max(d // 2, 8),
                   block_f=max(f // 2, 8), interpret=True)
    want = ref.moe_gemm_ref(x, w, eids, block_t)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_m3_kernel_used_by_population():
    """End-to-end: the Pallas path through the ParallelMLP forward."""
    from repro.core import Population, forward, init_params
    pop = Population(5, 3, (3, 9, 17), ("relu", "tanh", "gelu"), block=8)
    params = init_params(jax.random.PRNGKey(0), pop)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 5))
    y_pallas = forward(params, x, pop, m3_impl="pallas")
    y_ref = forward(params, x, pop, m3_impl="scatter")
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
