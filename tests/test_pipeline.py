"""Streaming data plane (DESIGN.md §11): Prefetcher semantics (ordering,
backpressure, seek/retarget, producer-failure surfacing, clean shutdown),
DeferredMetrics laziness, slab-build value parity, and the driver-level
bit-identity contract — a pipelined run must reproduce the synchronous
run's params AND optimizer state exactly, with and without --halving."""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.data import DeferredMetrics, PrefetchError, Prefetcher, TabularTask

# --------------------------------------------------------------------- #
# Prefetcher unit semantics                                             #
# --------------------------------------------------------------------- #


def test_prefetcher_orders_and_matches_sync():
    made = []

    def produce(c, staging):
        made.append(c)
        return c * 10

    with Prefetcher(produce, 8) as pf:
        got = [pf.get(c) for c in range(8)]
    assert got == [c * 10 for c in range(8)]
    assert made == list(range(8))


def test_prefetcher_get_past_end_raises():
    with Prefetcher(lambda c, s: c, 3) as pf:
        for c in range(3):
            pf.get(c)
        with pytest.raises(PrefetchError, match="past the end"):
            pf.get(3)


def test_prefetcher_backpressure_bounded():
    """The producer runs at most ``depth`` chunks ahead of the consumer
    before blocking on the bounded queue (+1 build may be in flight)."""
    made = []

    def produce(c, staging):
        made.append(c)
        return c

    with Prefetcher(produce, 100, depth=2) as pf:
        deadline = time.monotonic() + 5.0
        while len(made) < 3 and time.monotonic() < deadline:
            time.sleep(0.01)
        time.sleep(0.1)          # would run away here if unbounded
        assert max(made) <= 3    # depth slabs queued + 1 build in flight
        pf.get(0)
        pf.get(1)
        deadline = time.monotonic() + 5.0
        while len(made) < 5 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert max(made) <= 5


def test_prefetcher_staging_alternates():
    """Consecutive chunks see the two distinct staging buffers
    alternately — chunk k+1 never builds into the buffer chunk k staged."""
    seen = []

    def produce(c, staging):
        seen.append(id(staging))
        return c

    with Prefetcher(produce, 6, make_staging=lambda: [0]) as pf:
        for c in range(6):
            pf.get(c)
    assert len(set(seen)) == 2
    assert all(a != b for a, b in zip(seen, seen[1:]))


def test_prefetcher_out_of_order_get_seeks():
    """A crash replay re-enters at an earlier chunk: get() re-syncs the
    producer instead of delivering stale slabs."""
    with Prefetcher(lambda c, s: c * 10, 10) as pf:
        assert pf.get(0) == 0
        assert pf.get(1) == 10
        assert pf.get(0) == 0       # replay from 0
        assert pf.get(1) == 10
        assert pf.get(5) == 50      # skip ahead
        assert pf.get(6) == 60


def test_prefetcher_producer_exception_surfaces_and_close_never_hangs():
    def produce(c, staging):
        if c == 2:
            raise RuntimeError("disk on fire")
        return c

    pf = Prefetcher(produce, 8)
    assert pf.get(0) == 0
    assert pf.get(1) == 1
    with pytest.raises(PrefetchError, match="disk on fire") as ei:
        pf.get(2)
    assert isinstance(ei.value.__cause__, RuntimeError)
    t0 = time.monotonic()
    pf.close()                      # dead producer: close must not hang
    pf.close()                      # idempotent
    assert time.monotonic() - t0 < 5.0


def test_prefetcher_close_unblocks_full_queue():
    """close() while the producer is blocked mid-put (queue full, consumer
    gone) joins the thread instead of hanging — the shutdown contract."""
    pf = Prefetcher(lambda c, s: np.zeros(4), 1000, depth=1)
    time.sleep(0.1)                 # let the producer fill + block
    t0 = time.monotonic()
    pf.close()
    assert time.monotonic() - t0 < 5.0
    assert threading.active_count() >= 1  # and no leaked thread hangs join


def test_prefetcher_blocked_put_wakes_fast_after_get():
    """The bounded put is a condition-variable hand-off, not a poll: a
    producer blocked on the full queue resumes producing within 10 ms of
    the consumer's get (a polling put — the pre-§12 implementation slept
    50 ms between stop-flag checks — fails this by construction)."""
    produced = {}

    def produce(c, staging):
        produced[c] = time.perf_counter()
        return c

    pf = Prefetcher(produce, 8, depth=1)
    try:
        # depth=1: chunk 0 fills the queue, chunk 1 is produced (and
        # timestamped) then blocks in put — so the hand-off we time is
        # chunk 2's production after the get drains a slot
        deadline = time.monotonic() + 5.0
        while 1 not in produced and time.monotonic() < deadline:
            time.sleep(0.001)
        assert 1 in produced, "producer never reached the blocking put"
        time.sleep(0.05)            # let it park on the full queue
        assert 2 not in produced, "producer was not actually blocked"
        t_get = time.perf_counter()
        assert pf.get(0) == 0
        deadline = time.monotonic() + 5.0
        while 2 not in produced and time.monotonic() < deadline:
            time.sleep(0.001)
        assert 2 in produced
        assert produced[2] - t_get < 0.010, (
            f"blocked put took {(produced[2] - t_get) * 1e3:.1f} ms to "
            "wake after the consumer get — backpressure is polling, not "
            "a condition hand-off")
    finally:
        pf.close()


def test_prefetcher_retarget_switches_source():
    """The rung-boundary protocol: retarget drops in-flight slabs and
    re-aims the producer at the new segment's builder/staging."""
    pf = Prefetcher(lambda c, s: ("old", c), 100)
    assert pf.get(0) == ("old", 0)
    pf.retarget(lambda c, s: ("new", c), 4, start=0)
    assert [pf.get(c) for c in range(4)] == [("new", c) for c in range(4)]
    with pytest.raises(PrefetchError):
        pf.get(4)
    pf.close()


def test_prefetcher_rejects_bad_depth():
    with pytest.raises(ValueError, match="depth"):
        Prefetcher(lambda c, s: c, 4, depth=0)


# --------------------------------------------------------------------- #
# DeferredMetrics                                                       #
# --------------------------------------------------------------------- #


def test_deferred_metrics_lazy_and_cached():
    calls = []

    def resolve():
        calls.append(1)
        return {"loss": 0.5, "step": 7}

    m = DeferredMetrics(resolve)
    assert not m.resolved and not calls   # storing costs nothing
    assert m["loss"] == 0.5               # first access resolves
    assert m.resolved and len(calls) == 1
    assert dict(m) == {"loss": 0.5, "step": 7}
    assert len(m) == 2 and "step" in m
    assert len(calls) == 1                # cached, not re-resolved
    assert "0.5" in repr(m)


# --------------------------------------------------------------------- #
# slab builds: value parity with per-step batch()                       #
# --------------------------------------------------------------------- #


def test_batch_slab_value_identical_to_per_step_batches():
    """batch_slab (the §11 producer build, epoch permutation amortized)
    must produce byte-identical values to stacking batch(step) — across
    epoch boundaries, wrap-around tails, and via caller staging."""
    for n, b in [(1000, 128), (256, 128), (300, 100)]:
        t = TabularTask(n, 7, n_classes=3, seed=5)
        per_epoch = max(n // b, 1)
        start, steps = max(per_epoch - 2, 0), 3 * per_epoch + 4
        ref_x = np.stack([t.batch(start + j, b)[0] for j in range(steps)])
        ref_y = np.stack([t.batch(start + j, b)[1] for j in range(steps)])
        sx, sy = t.batch_slab(start, steps, b)
        np.testing.assert_array_equal(sx, ref_x)
        np.testing.assert_array_equal(sy, ref_y)
        ox = np.empty_like(sx)
        oy = np.empty_like(sy)
        rx, _ = t.batch_slab(start, steps, b, out=(ox, oy))
        assert rx is ox
        np.testing.assert_array_equal(ox, ref_x)
        np.testing.assert_array_equal(oy, ref_y)


# --------------------------------------------------------------------- #
# driver bit-identity: --pipeline on == off                             #
# --------------------------------------------------------------------- #


def _drive(tmp_path, tag, pipeline, extra=()):
    from repro.launch.train import main
    return main([
        "--arch", "parallelmlp-10k", "--reduced", "--steps", "8",
        "--ckpt-every", "4", "--ckpt-dir", str(tmp_path / tag),
        "--population-depths", "8,4;8,4;6;5", "--population-acts",
        "relu,tanh", "--scan-steps", "2", "--samples", "256",
        "--pipeline", "on" if pipeline else "off", *extra])


def _final_ckpt_arrays(tmp_path, tag):
    import repro.checkpoint as ckpt_mod
    step = ckpt_mod.latest_steps(str(tmp_path / tag))[-1]
    return np.load(os.path.join(str(tmp_path / tag),
                                f"step_{step:08d}", "arrays.npz"))


def _assert_bit_identical(pa, pb):
    import jax
    leaves_a, leaves_b = jax.tree.leaves(pa), jax.tree.leaves(pb)
    assert len(leaves_a) == len(leaves_b)
    for a, b in zip(leaves_a, leaves_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_pipeline_bit_identical_plain(tmp_path):
    pa, lpa = _drive(tmp_path, "on", True)
    pb, lpb = _drive(tmp_path, "off", False)
    assert lpa == lpb
    _assert_bit_identical(pa, pb)


@pytest.mark.slow
def test_pipeline_bit_identical_halving_with_opt_state(tmp_path):
    """Across halving rung boundaries (prefetcher retarget + re-jit) the
    pipelined trajectory still matches synchronous exactly — params AND
    the momentum optimizer state in the final checkpoint."""
    extra = ["--optimizer", "momentum", "--halving", "2:0.5,4:0.5"]
    pa, lpa = _drive(tmp_path, "on", True, extra)
    pb, lpb = _drive(tmp_path, "off", False, extra)
    assert lpa == lpb and lpa.num_real == 1
    _assert_bit_identical(pa, pb)
    za = _final_ckpt_arrays(tmp_path, "on")
    zb = _final_ckpt_arrays(tmp_path, "off")
    assert sorted(za.files) == sorted(zb.files)
    extras = [k for k in za.files if k.startswith("extra/")]
    assert any(k.startswith("extra/mu/") for k in extras)
    for k in za.files:
        np.testing.assert_array_equal(za[k], zb[k], err_msg=k)


@pytest.mark.slow
def test_pipeline_bit_identical_adafactor_halving(tmp_path):
    """Adafactor + --halving now composes (factored stats re-initialized
    per rung, momentum carried): pipelined == synchronous, and the ladder
    prunes to one member."""
    extra = ["--optimizer", "adafactor", "--weight-decay", "0.001",
             "--halving", "2:0.5,4:0.5"]
    pa, lpa = _drive(tmp_path, "on", True, extra)
    pb, lpb = _drive(tmp_path, "off", False, extra)
    assert lpa == lpb and lpa.num_real == 1
    _assert_bit_identical(pa, pb)
    za = _final_ckpt_arrays(tmp_path, "on")
    zb = _final_ckpt_arrays(tmp_path, "off")
    for k in za.files:
        np.testing.assert_array_equal(za[k], zb[k], err_msg=k)


# --------------------------------------------------------------------- #
# 4-fake-device: slabs land with population_batch_shardings             #
# --------------------------------------------------------------------- #

_SHARDED_PIPELINE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
import jax, numpy as np
import repro.data.pipeline as pl

seen = []
orig_get = pl.Prefetcher.get

def spy(self, c, timeout=600.0):
    slab = orig_get(self, c, timeout)
    seen.append(tuple(a.sharding for a in slab))
    return slab

pl.Prefetcher.get = spy

from repro.launch.train import main
params, lp = main([
    "--arch", "parallelmlp-10k", "--reduced", "--steps", "6",
    "--population-depths", "16,8;12,4;7;9", "--population-acts",
    "relu,tanh", "--scan-steps", "3", "--ckpt-every", "0",
    "--pipeline", "on", "--ckpt-dir", sys.argv[1] + "/ck"])
assert len(jax.devices()) == 4
assert seen, "prefetcher never delivered a slab"

from repro.distributed.sharding import population_batch_shardings
from repro.launch.mesh import make_host_mesh
sh_x, sh_y = population_batch_shardings(make_host_mesh(), 8)
for shx, shy in seen:
    assert shx == sh_x, (shx, sh_x)
    assert shy == sh_y, (shy, sh_y)
print("OK", len(seen))
"""


@pytest.mark.slow
def test_pipeline_slabs_carry_population_batch_shardings(tmp_path):
    """On a 4-fake-device mesh the prefetcher's device slabs arrive with
    exactly the shardings population_batch_shardings prescribes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    r = subprocess.run([sys.executable, "-c", _SHARDED_PIPELINE,
                        str(tmp_path)],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
