"""Fused input layer (kernels/fused_input.py, DESIGN.md §9): dense
input-feature matmul + bias + per-segment activation + padding mask in ONE
Pallas pass, replacing the XLA dot + standalone seg_act epilogue for
layer 0 of the fused population path.  Interpret-mode equivalence vs the
XLA reference (``input_xla``) — values and per-operand gradients — on
ragged layouts, the wide-feature (F > 128, tiled reduction) path, and the
registry's default routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deep import (IN_IMPLS, FUSED_IN_IMPLS, _resolve_in_impl,
                             init_params, input_fused, input_xla)
from repro.core.population import LayeredPopulation

LP = LayeredPopulation(20, 3, ((5, 3), (12, 9), (7,), (17, 9, 5)),
                       ("relu", "gelu", "tanh", "mish"), block=8)
# in_features > 128 exercises the tiled (block_f=128) reduction grid and
# the feature-axis padding (177 → 256) whose pad VJP must slice cotangents
LP_WIDE = LayeredPopulation(177, 3, ((9, 4), (24, 16), (6,)),
                            ("selu", "hardshrink", "sigmoid"), block=8)


def _inputs(lp, b=9, seed=0):
    params = init_params(jax.random.PRNGKey(seed), lp)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, lp.in_features))
    return x, params["w_in"], params["b_in"]


def test_registry_has_fused():
    assert set(IN_IMPLS) == {"xla", "fused"}
    assert "fused" in FUSED_IN_IMPLS


def test_default_routing_follows_bd_impl():
    assert _resolve_in_impl(None, "fused") == "fused"
    assert _resolve_in_impl(None, "einsum") == "xla"
    assert _resolve_in_impl(None, "pallas") == "xla"
    assert _resolve_in_impl("xla", "fused") == "xla"   # explicit override
    with pytest.raises(ValueError, match="in_impl"):
        _resolve_in_impl("cutlass", "fused")


@pytest.mark.parametrize("lp", [LP, LP_WIDE], ids=["narrow", "wide_f"])
def test_forward_matches_xla(lp):
    x, w, b = _inputs(lp)
    ye = input_xla(x, w, b, lp)
    yf = input_fused(x, w, b, lp)
    np.testing.assert_allclose(np.asarray(ye), np.asarray(yf),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("lp", [LP, LP_WIDE], ids=["narrow", "wide_f"])
def test_grads_match_xla(lp):
    """dx, dW_in, db_in from the one-pass fused backward vs XLA autodiff —
    the feature-axis pad cotangent must slice back to the caller's F."""
    x, w, b = _inputs(lp, seed=3)
    ge = jax.grad(lambda *a: (input_xla(*a, lp) ** 2).sum(),
                  argnums=(0, 1, 2))(x, w, b)
    gf = jax.grad(lambda *a: (input_fused(*a, lp) ** 2).sum(),
                  argnums=(0, 1, 2))(x, w, b)
    for a, f in zip(ge, gf):
        assert f.shape == a.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(f),
                                   rtol=1e-4, atol=1e-5)


def test_grads_match_multi_batch_tile():
    """B > block_b → several inner batch tiles: dW_in accumulates across
    them (the stale-overwrite flush pattern), dx stays per-tile direct."""
    x, w, b = _inputs(LP, b=300, seed=5)
    ge = jax.grad(lambda *a: (input_xla(*a, LP) ** 2).sum(),
                  argnums=(0, 1, 2))(x, w, b)
    gf = jax.grad(lambda *a: (input_fused(*a, LP, block_b=128) ** 2).sum(),
                  argnums=(0, 1, 2))(x, w, b)
    for a, f in zip(ge, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(f),
                                   rtol=1e-4, atol=1e-4)


def test_bf16_operands_f32_epilogue():
    """bf16 x/W_in tiles, f32 accumulator + f32 bias/activation epilogue:
    tracks the XLA bf16 reference within bf16 tolerance."""
    x, w, b = _inputs(LP, seed=7)
    x16, w16 = x.astype(jnp.bfloat16), w.astype(jnp.bfloat16)
    ye = input_xla(x16, w16, b, LP)
    yf = input_fused(x16, w16, b, LP)
    np.testing.assert_allclose(np.asarray(ye, dtype=np.float32),
                               np.asarray(yf, dtype=np.float32),
                               rtol=2e-2, atol=2e-2)
