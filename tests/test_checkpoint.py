"""Checkpointing: roundtrip fidelity, atomic commit, GC, async path,
shape-mismatch detection."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_steps, restore, save


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,), jnp.bfloat16)},
            "opt": {"count": jnp.asarray(3, jnp.int32),
                    "m": {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    assert latest_steps(str(tmp_path)) == [7]
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    got, step = restore(str(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(t)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_uncommitted_checkpoint_ignored(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    # fake a torn write: step dir without META.ok
    torn = tmp_path / "step_00000002"
    shutil.copytree(tmp_path / "step_00000001", torn)
    os.remove(torn / "META.ok")
    assert latest_steps(str(tmp_path)) == [1]
    _, step = restore(str(tmp_path), t)
    assert step == 1


def test_keep_last_gc(tmp_path):
    t = _tree()
    for s in range(5):
        save(str(tmp_path), s, t, keep_last=2)
    assert latest_steps(str(tmp_path)) == [3, 4]


def test_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 0, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"w": jnp.zeros((8, 4))})


def test_missing_leaf_raises(tmp_path):
    save(str(tmp_path), 0, {"w": jnp.zeros((4,))})
    with pytest.raises(KeyError):
        restore(str(tmp_path), {"w": jnp.zeros((4,)), "extra": jnp.zeros(1)})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), every=2, keep_last=10)
    t = _tree()
    saved = [s for s in range(6) if ck.maybe_save(s, t)]
    ck.wait()
    assert saved == [0, 2, 4]
    assert latest_steps(str(tmp_path)) == [0, 2, 4]
    got, step = restore(str(tmp_path), t)
    assert step == 4
