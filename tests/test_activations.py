"""Per-member activation application: all three strategies agree, and each
activation matches its torch-default definition on known points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.activations import (ACTIVATIONS, ACTIVATION_ORDER, PAPER_TEN,
                                    apply_activations_masked,
                                    apply_activations_sliced)
from repro.core.population import Population
from repro.kernels import seg_act
from repro.kernels.ref import seg_act_ref


def test_paper_has_ten():
    assert len(PAPER_TEN) == 10
    assert set(PAPER_TEN) == set(ACTIVATIONS)


def test_known_values():
    x = jnp.asarray([-1.0, 0.0, 2.0])
    np.testing.assert_allclose(ACTIVATIONS["identity"](x), [-1, 0, 2])
    np.testing.assert_allclose(ACTIVATIONS["relu"](x), [0, 0, 2])
    np.testing.assert_allclose(ACTIVATIONS["hardshrink"](x), [-1, 0, 2])
    np.testing.assert_allclose(ACTIVATIONS["hardshrink"](
        jnp.asarray([0.4, -0.5, 0.6])), [0, 0, 0.6])
    np.testing.assert_allclose(ACTIVATIONS["leaky_relu"](x),
                               [-0.01, 0, 2], rtol=1e-6)
    np.testing.assert_allclose(ACTIVATIONS["sigmoid"](jnp.zeros(1)), [0.5])
    # mish(0)=0, gelu(0)=0, tanh(0)=0
    for n in ("mish", "gelu", "tanh", "elu", "selu"):
        np.testing.assert_allclose(float(ACTIVATIONS[n](jnp.zeros(1))[0]),
                                   0.0, atol=1e-7)


@st.composite
def pops(draw):
    n = draw(st.integers(1, 8))
    sizes = draw(st.lists(st.integers(1, 20), min_size=n, max_size=n))
    acts = draw(st.lists(st.sampled_from(sorted(PAPER_TEN)),
                         min_size=n, max_size=n))
    return Population(4, 2, tuple(sizes), tuple(acts), block=8)


@given(pops(), st.booleans())
@settings(max_examples=30, deadline=None)
def test_strategies_agree(pop, sort):
    if sort:
        pop = pop.sorted()
    h = jax.random.normal(jax.random.PRNGKey(pop.num_members),
                          (5, pop.total_hidden))
    a = apply_activations_sliced(h, pop.act_runs)
    b = apply_activations_masked(h, pop.act_ids)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)
    # Pallas kernel (interpret) with fused padding mask
    c = seg_act(h, pop.block_act_ids, pop.hidden_mask, block_h=pop.block,
                interpret=True)
    want = np.asarray(b) * np.asarray(pop.hidden_mask)
    np.testing.assert_allclose(np.asarray(c), want, rtol=1e-6, atol=1e-6)


def test_activation_order_is_canonical():
    assert list(ACTIVATION_ORDER) == sorted(ACTIVATIONS)
