"""Deep ParallelMLPs (paper §7 / Figure 3): the block-diagonal fusion keeps
MULTI-hidden-layer members independent — fused training equals standalone
training, the paper's open conjecture verified.  ``DeepPopulation`` is now an
alias of the unified ``LayeredPopulation`` engine (uniform depth is just the
degenerate case); heterogeneous-depth coverage lives in test_layered.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.activations import ACTIVATIONS
from repro.core.deep import (DeepPopulation, extract_member, forward,
                             fused_loss, init_params, member_forward,
                             sgd_step)

DP = DeepPopulation(
    in_features=6, out_features=3,
    widths=((4, 2), (1, 3), (9, 5), (9, 5), (2, 7)),
    activations=("relu", "tanh", "gelu", "relu", "mish"),
    block=8)


def test_forward_matches_members():
    params = init_params(jax.random.PRNGKey(0), DP)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 6))
    fused = forward(params, x, DP)
    for m in range(DP.num_members):
        mem = extract_member(params, DP, m)
        want = member_forward(mem, x)
        np.testing.assert_allclose(np.asarray(fused[:, m]), np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"member {m}")


def standalone_step(member, x, y, lr):
    act_name = member["activation"]

    def loss(flat):
        w_in, b_in, mids, w_out, b_out = flat
        act = ACTIVATIONS[act_name]
        h = act(x @ w_in.T + b_in)
        for (w, b) in mids:
            h = act(h @ w.T + b)
        logits = h @ w_out.T + b_out
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    flat = (member["w_in"], member["b_in"],
            tuple((l["w"], l["b"]) for l in member["mid"]),
            member["w_out"], member["b_out"])
    g = jax.grad(loss)(flat)
    new_flat = jax.tree.map(lambda p, gg: p - lr * gg, flat, g)
    return {"w_in": new_flat[0], "b_in": new_flat[1],
            "mid": [{"w": w, "b": b} for w, b in new_flat[2]],
            "w_out": new_flat[3], "b_out": new_flat[4],
            "activation": act_name}


def test_deep_fused_training_is_independent():
    """Paper §7 conjecture: M3 + block-diagonal mid layers keep multi-layer
    members exactly independent under fused SGD."""
    params = init_params(jax.random.PRNGKey(42), DP)
    members = [extract_member(params, DP, m) for m in range(DP.num_members)]
    key = jax.random.PRNGKey(7)
    lr = 0.05
    for _ in range(4):
        key, k1, k2 = jax.random.split(key, 3)
        x = jax.random.normal(k1, (16, 6))
        y = jax.random.randint(k2, (16,), 0, 3)
        params, _, _ = sgd_step(params, x, y, lr, DP)
        members = [standalone_step(m, x, y, lr) for m in members]
    for m in range(DP.num_members):
        got = extract_member(params, DP, m)
        want = members[m]
        np.testing.assert_allclose(
            np.asarray(got["w_in"]), np.asarray(want["w_in"]),
            rtol=2e-4, atol=2e-5, err_msg=f"member {m} w_in")
        for l in range(DP.depth - 1):
            np.testing.assert_allclose(
                np.asarray(got["mid"][l]["w"]),
                np.asarray(want["mid"][l]["w"]),
                rtol=2e-4, atol=2e-5,
                err_msg=f"member {m} mid layer {l} — cross-member leak!")
        np.testing.assert_allclose(
            np.asarray(got["w_out"]), np.asarray(want["w_out"]),
            rtol=2e-4, atol=2e-5, err_msg=f"member {m} w_out")


def test_mixed_depths_now_supported():
    """Mixed depths are no longer rejected — they are the unified engine's
    headline feature (shallow members pass through identity-padded layers)."""
    dp = DeepPopulation(4, 2, ((3, 4), (3,)), ("relu", "relu"))
    params = init_params(jax.random.PRNGKey(0), dp)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 4))
    fused = forward(params, x, dp)
    for m in range(2):
        want = member_forward(extract_member(params, dp, m), x)
        np.testing.assert_allclose(np.asarray(fused[:, m]), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_invalid_activation_rejected():
    with pytest.raises(ValueError):
        DeepPopulation(4, 2, ((3, 4),), ("nope",))


def test_three_hidden_layers():
    dp = DeepPopulation(5, 2, ((3, 4, 2), (6, 1, 5)), ("relu", "tanh"),
                        block=4)
    params = init_params(jax.random.PRNGKey(0), dp)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 5))
    fused = forward(params, x, dp)
    assert fused.shape == (4, 2, 2)
    for m in range(2):
        want = member_forward(extract_member(params, dp, m), x)
        np.testing.assert_allclose(np.asarray(fused[:, m]), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
