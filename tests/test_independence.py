"""THE paper's correctness property: members of a fused ParallelMLP train
EXACTLY as they would standalone — gradients never mix across members.

Method: init a fused population; extract each member; train the fused
network with SGD for several steps; train each extracted member standalone
on the same batches; the fused member slices must equal the standalone
parameters to float tolerance.  Also covers per-member learning rates
(paper §7) and loss equality."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Population, extract_member, forward, init_params,
                        member_forward, sgd_step)
from repro.core.activations import ACTIVATIONS
from repro.core.parallel_mlp import member_losses

POP = Population(6, 3, (3, 9, 1, 20, 9),
                 ("relu", "tanh", "identity", "mish", "sigmoid"), block=8)


def standalone_step(member, x, y, lr):
    """Plain SGD on one extracted MLP (classification NLL, mean over batch)."""
    def loss(m):
        logits = member_forward_dict(m, x, member["activation"])
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    grads = jax.grad(loss)({k: member[k] for k in ("w1", "b1", "w2", "b2")})
    return {k: member[k] - lr * grads[k] if k in grads else member[k]
            for k in member}


def member_forward_dict(m, x, act):
    h = ACTIVATIONS[act](x @ m["w1"].T + m["b1"])
    return h @ m["w2"].T + m["b2"]


@pytest.mark.parametrize("m3_impl", ["scatter", "bucketed", "onehot"])
def test_fused_equals_standalone(m3_impl):
    key = jax.random.PRNGKey(42)
    params = init_params(key, POP)
    members = [extract_member(params, POP, m) for m in range(POP.num_members)]

    kx = jax.random.PRNGKey(7)
    lr = 0.05
    fused = params
    for step in range(5):
        kx, k1, k2 = jax.random.split(kx, 3)
        x = jax.random.normal(k1, (16, 6))
        y = jax.random.randint(k2, (16,), 0, 3)
        fused, _, _ = sgd_step(fused, x, y, lr, POP, m3_impl=m3_impl)
        members = [standalone_step(m, x, y, lr) for m in members]

    for m in range(POP.num_members):
        got = extract_member(fused, POP, m)
        want = members[m]
        for k in ("w1", "b1", "w2", "b2"):
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(want[k]), rtol=2e-4, atol=2e-5,
                err_msg=f"member {m} param {k} diverged — gradients mixed!")


def test_padding_units_never_update():
    key = jax.random.PRNGKey(0)
    params = init_params(key, POP)
    pad = 1.0 - np.asarray(POP.hidden_mask)
    w1_pad_before = np.asarray(params["w1"]) * pad[:, None]
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 6))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 3)
    new, _, _ = sgd_step(params, x, y, 0.1, POP)
    # w2 columns of padding units get zero gradient (h is masked there);
    # w1 rows of padding units receive zero gradient through M3
    np.testing.assert_allclose(
        np.asarray(new["w1"]) * pad[:, None], w1_pad_before, atol=1e-7)


def test_per_member_lr():
    """lr vector: member m trains with its own step size (paper §7)."""
    key = jax.random.PRNGKey(3)
    params = init_params(key, POP)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 6))
    y = jax.random.randint(jax.random.PRNGKey(5), (8,), 0, 3)
    lrs = jnp.asarray([0.0, 0.1, 0.0, 0.2, 0.05])
    new, _, _ = sgd_step(params, x, y, lrs, POP)
    for m, lr in enumerate(np.asarray(lrs)):
        sl = POP.member_slice(m)
        same = np.allclose(np.asarray(new["w1"][sl]),
                           np.asarray(params["w1"][sl]))
        assert same == (lr == 0.0), (m, lr)


def test_fused_loss_equals_member_losses():
    key = jax.random.PRNGKey(9)
    params = init_params(key, POP)
    x = jax.random.normal(jax.random.PRNGKey(10), (12, 6))
    y = jax.random.randint(jax.random.PRNGKey(11), (12,), 0, 3)
    logits = forward(params, x, POP)
    per = member_losses(logits, y, "classification")
    for m in range(POP.num_members):
        mem = extract_member(params, POP, m)
        lg = member_forward(mem, x)
        logp = jax.nn.log_softmax(lg)
        want = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
        np.testing.assert_allclose(float(per[m]), float(want), rtol=1e-5)
