"""M3 semantics: all four implementations agree (values AND gradients) with
a brute-force per-member loop, across hypothesis-driven layouts/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.m3 import M3_IMPLS
from repro.core.population import Population

ACTS = st.sampled_from(["relu", "tanh", "gelu"])


def brute_force(h, w2, pop):
    """y[b,m,o] = member-m slice matmul — the obvious loop."""
    outs = []
    for m in range(pop.num_members):
        sl = pop.member_slice(m)
        outs.append(h[:, sl] @ w2[:, sl].T)
    return jnp.stack(outs, axis=1)


@st.composite
def layouts(draw):
    n = draw(st.integers(1, 6))
    sizes = draw(st.lists(st.integers(1, 33), min_size=n, max_size=n))
    block = draw(st.sampled_from([1, 8]))
    b = draw(st.sampled_from([1, 3, 8]))
    o = draw(st.sampled_from([1, 2, 5]))
    return sizes, block, b, o


@given(layouts(), st.sampled_from(sorted(M3_IMPLS)))
@settings(max_examples=40, deadline=None)
def test_m3_matches_brute_force(layout, impl):
    sizes, block, b, o = layout
    pop = Population(4, o, tuple(sizes), ("relu",) * len(sizes), block=block)
    key = jax.random.PRNGKey(hash((tuple(sizes), block, b, o)) % 2**31)
    k1, k2 = jax.random.split(key)
    h = jax.random.normal(k1, (b, pop.total_hidden))
    h = h * jnp.asarray(pop.hidden_mask)        # padding units are zero
    w2 = jax.random.normal(k2, (o, pop.total_hidden))
    want = brute_force(h, w2, pop)
    got = M3_IMPLS[impl](h, w2, pop)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("impl", sorted(M3_IMPLS))
def test_m3_gradients_match(impl):
    pop = Population(4, 3, (5, 17, 2, 8), ("relu",) * 4, block=8)
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (6, pop.total_hidden)) \
        * jnp.asarray(pop.hidden_mask)
    w2 = jax.random.normal(jax.random.PRNGKey(1), (3, pop.total_hidden))

    # the model masks padded hidden units (h·mask), so gradients there are
    # killed downstream — compose the mask into the loss like forward() does
    mask = jnp.asarray(pop.hidden_mask)

    def loss(fn):
        return lambda hh, ww: (fn(hh * mask, ww, pop) ** 2).sum()

    want = jax.grad(loss(brute_force), argnums=(0, 1))(h, w2)
    got = jax.grad(loss(M3_IMPLS[impl]), argnums=(0, 1))(h, w2)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-4, atol=2e-4)


def test_m3_bf16():
    pop = Population(4, 2, (8, 16), ("relu", "relu"), block=8)
    h = jax.random.normal(jax.random.PRNGKey(0), (4, pop.total_hidden),
                          jnp.bfloat16)
    w2 = jax.random.normal(jax.random.PRNGKey(1), (2, pop.total_hidden),
                           jnp.bfloat16)
    ys = {n: np.asarray(f(h, w2, pop), np.float32)
          for n, f in M3_IMPLS.items()}
    for n, y in ys.items():
        np.testing.assert_allclose(y, ys["scatter"], rtol=5e-2, atol=5e-2)
