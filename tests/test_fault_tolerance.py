"""Fault tolerance: a job killed mid-run resumes from the last committed
checkpoint and produces the SAME final state as an uninterrupted run
(data is step-indexed → replay is bitwise)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import StragglerPolicy, TrainRunner
from repro.distributed.compression import (compressed_psum_tree,
                                           init_error_feedback, quantize_int8)


def _step_fn(state, step):
    # toy deterministic "training": params += f(step)
    g = jnp.asarray(np.sin(step + 1), jnp.float32)
    new = {"w": state["w"] + g, "n": state["n"] + 1}
    return new, {"loss": float(jnp.abs(g))}


def test_restart_reproduces_uninterrupted_run(tmp_path):
    init = {"w": jnp.zeros((4,)), "n": jnp.zeros((), jnp.int32)}
    # reference: no failure
    ref = TrainRunner(_step_fn, jax.tree.map(jnp.copy, init),
                      ckpt_dir=str(tmp_path / "ref"), ckpt_every=3)
    ref.run(10)

    # failing run: dies at steps 5 and 8 (after ckpts at 0,3 / 6)
    boom = {5: True, 8: True}

    def failure_hook(step):
        if boom.pop(step, False):
            raise RuntimeError(f"simulated chip failure at {step}")

    r = TrainRunner(_step_fn, jax.tree.map(jnp.copy, init),
                    ckpt_dir=str(tmp_path / "ft"), ckpt_every=3,
                    failure_hook=failure_hook)
    r.run(10)
    assert r.restarts == 2
    np.testing.assert_allclose(np.asarray(r.state["w"]),
                               np.asarray(ref.state["w"]), rtol=1e-6)
    assert int(r.state["n"]) == int(ref.state["n"])


def test_restart_without_checkpoint_replays_from_initial(tmp_path):
    """A failure BEFORE the first committed checkpoint replays from the
    runner's initial-state snapshot — completed steps are not applied twice
    (and a donation-deleted live state cannot poison the retry)."""
    boom = {1: True}

    def step_fn(state, step):
        if boom.pop(step, False):
            raise RuntimeError("transient failure, nothing on disk yet")
        return {"w": state["w"] + 1.0}, {"loss": 0.0}

    r = TrainRunner(step_fn, {"w": jnp.zeros(2)},
                    ckpt_dir=str(tmp_path / "none"), ckpt_every=0)
    r.run(3)
    np.testing.assert_allclose(np.asarray(r.state["w"]), 3.0)
    assert r.restarts == 1


def test_too_many_restarts_raises(tmp_path):
    def always_fail(step):
        raise RuntimeError("dead host")

    r = TrainRunner(_step_fn, {"w": jnp.zeros(1), "n": jnp.zeros((), jnp.int32)},
                    ckpt_dir=str(tmp_path), ckpt_every=100,
                    failure_hook=always_fail, max_restarts=2)
    with pytest.raises(RuntimeError, match="exceeded"):
        r.run(5)


def test_straggler_policy():
    pol = StragglerPolicy(timeout_s=0.5, max_strikes=2)
    pol.observe(0, 0.1)
    pol.observe(1, 0.9)            # strike 1
    pol.observe(2, 0.2)            # reset
    pol.observe(3, 0.9)
    with pytest.raises(TimeoutError):
        pol.observe(4, 0.9)
    assert len(pol.events) == 3


def test_quantize_roundtrip_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)
    err = jnp.zeros_like(g)
    q, scale, err2 = quantize_int8(g, err)
    rec = q.astype(jnp.float32) * scale
    # per-element error bounded by one quantisation step…
    assert float(jnp.abs(rec - g).max()) <= float(scale) + 1e-7
    # …and exactly captured by the feedback residual
    np.testing.assert_allclose(np.asarray(rec + err2), np.asarray(g),
                               atol=1e-6)


def test_compressed_psum_single_axis():
    """On a 1-sized axis the compressed reduce must be a near-identity
    (quantisation only) and converge via error feedback."""
    from repro.compat import make_mesh, shard_map
    mesh = make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(0, 1, (64,)),
                          jnp.float32)}
    err = init_error_feedback(g)

    def f(gg, ee):
        return compressed_psum_tree(gg, ee, "pod")

    from jax.sharding import PartitionSpec as P
    spec = jax.tree.map(lambda _: P(), g)
    out, err2 = jax.jit(
        shard_map(f, mesh=mesh, in_specs=(spec, spec),
                  out_specs=(spec, spec), check=False))(g, err)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=2e-2)
    # feeding the error back makes the two-step average exact-ish
    total = np.asarray(out["w"] + err2["w"])
    np.testing.assert_allclose(total, np.asarray(g["w"]), atol=1e-6)


_SHARDED_REPLAY = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro.core import deep
from repro.core.population import LayeredPopulation
from repro.distributed import TrainRunner
from repro.distributed.sharding import pop_axis_size, population_shardings
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh()
assert pop_axis_size(mesh) == 4
lp0 = LayeredPopulation(
    6, 3, widths=((7,), (13, 5), (16, 8), (13, 5), (9,), (12, 4)),
    activations=("relu", ("tanh", "gelu"), ("relu", "tanh"),
                 ("tanh", "gelu"), "relu", ("relu", "tanh")),
    block=8).sorted()
lp = lp0.shard_pad(pop_axis_size(mesh))

with set_mesh(mesh):
    p_sh = population_shardings(lp, mesh)
    params = jax.jit(
        lambda k: deep.pad_params(deep.init_params(k, lp0), lp0, lp,
                                  jax.random.fold_in(k, 1)),
        out_shardings=p_sh)(jax.random.PRNGKey(0))
    chunk = deep.make_population_train_step(lp, scan_steps=2)
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(0, 1, (8, 8, 6)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, 3, (8, 8)).astype(np.int32))

    def make_step_fn():
        def step_fn(state, c):
            p, _, _ = chunk(state["params"], xs[2*c:2*c+2], ys[2*c:2*c+2],
                            0.05)
            return {"params": p}, {"loss": 0.0}
        return step_fn

    def run(ckpt_dir, failure_hook=None):
        # fresh copy per run: the donated chunk consumes its input tree
        state = {"params": jax.device_put(jax.tree.map(jnp.copy, params),
                                          p_sh)}
        runner = TrainRunner(
            make_step_fn(), state,
            ckpt_dir=ckpt_dir, ckpt_every=1, failure_hook=failure_hook,
            mesh=mesh, state_specs={"params": lp.param_specs()})
        runner.run(4)
        return runner

    ref = run(sys.argv[1] + "/ref")
    boom = {2: True}
    def hook(step):
        if boom.pop(step, False):
            raise RuntimeError("simulated chip failure")
    ft = run(sys.argv[1] + "/ft", failure_hook=hook)
    assert ft.restarts == 1

    # REGRESSION (ROADMAP PR-2 follow-up): the crash-restored state must
    # come back SHARDED over the population axis, not replicated
    w_in = ft.state["params"]["w_in"]
    assert not w_in.sharding.is_fully_replicated, str(w_in.sharding)
    assert "model" in str(w_in.sharding.spec), str(w_in.sharding)
    sharded_mid = [w for w in ft.state["params"]["mid"][0]["w"]
                   if not w.sharding.is_fully_replicated
                   and "model" in str(w.sharding.spec)]
    assert sharded_mid, [str(w.sharding) for w in
                         ft.state["params"]["mid"][0]["w"]]
    # and replay is bitwise (step-indexed data, committed checkpoint)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ft.state, ref.state)
print("OK")
"""


@pytest.mark.slow
def test_sharded_crash_replay_stays_sharded(tmp_path):
    """On a 4-fake-device mesh, a mid-run failure replayed through
    ``TrainRunner(mesh=..., state_specs=...)`` restores the population
    state SHARDED (device_put through the layout's spec tree) and
    bitwise-equal to the uninterrupted run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    r = subprocess.run([sys.executable, "-c", _SHARDED_REPLAY,
                        str(tmp_path)],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_runner_derives_restore_shardings_from_specs(tmp_path):
    """The mesh + spec-tree wiring builds the same NamedSharding tree a
    caller would hand-build (single-device degenerate case)."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    state = {"w": jnp.zeros((8, 2))}
    r = TrainRunner(_step_fn, state, ckpt_dir=str(tmp_path), ckpt_every=0,
                    mesh=mesh, state_specs={"w": P("model", None)})
    assert r.restore_shardings is not None
    assert r.restore_shardings["w"].mesh.shape == dict(mesh.shape)


def test_elastic_remesh_preserves_values():
    from repro.distributed import elastic_remesh
    from jax.sharding import PartitionSpec as P
    state = {"w": jnp.arange(16.0).reshape(8, 2)}
    spec = {"w": P("data", None)}
    mesh, resharded = elastic_remesh(state, spec)
    np.testing.assert_array_equal(np.asarray(resharded["w"]),
                                  np.asarray(state["w"]))
