"""Fault tolerance: a job killed mid-run resumes from the last committed
checkpoint and produces the SAME final state as an uninterrupted run
(data is step-indexed → replay is bitwise)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import StragglerPolicy, TrainRunner
from repro.distributed.compression import (compressed_psum_tree,
                                           init_error_feedback, quantize_int8)


def _step_fn(state, step):
    # toy deterministic "training": params += f(step)
    g = jnp.asarray(np.sin(step + 1), jnp.float32)
    new = {"w": state["w"] + g, "n": state["n"] + 1}
    return new, {"loss": float(jnp.abs(g))}


def test_restart_reproduces_uninterrupted_run(tmp_path):
    init = {"w": jnp.zeros((4,)), "n": jnp.zeros((), jnp.int32)}
    # reference: no failure
    ref = TrainRunner(_step_fn, jax.tree.map(jnp.copy, init),
                      ckpt_dir=str(tmp_path / "ref"), ckpt_every=3)
    ref.run(10)

    # failing run: dies at steps 5 and 8 (after ckpts at 0,3 / 6)
    boom = {5: True, 8: True}

    def failure_hook(step):
        if boom.pop(step, False):
            raise RuntimeError(f"simulated chip failure at {step}")

    r = TrainRunner(_step_fn, jax.tree.map(jnp.copy, init),
                    ckpt_dir=str(tmp_path / "ft"), ckpt_every=3,
                    failure_hook=failure_hook)
    r.run(10)
    assert r.restarts == 2
    np.testing.assert_allclose(np.asarray(r.state["w"]),
                               np.asarray(ref.state["w"]), rtol=1e-6)
    assert int(r.state["n"]) == int(ref.state["n"])


def test_restart_without_checkpoint_replays_from_initial(tmp_path):
    """A failure BEFORE the first committed checkpoint replays from the
    runner's initial-state snapshot — completed steps are not applied twice
    (and a donation-deleted live state cannot poison the retry)."""
    boom = {1: True}

    def step_fn(state, step):
        if boom.pop(step, False):
            raise RuntimeError("transient failure, nothing on disk yet")
        return {"w": state["w"] + 1.0}, {"loss": 0.0}

    r = TrainRunner(step_fn, {"w": jnp.zeros(2)},
                    ckpt_dir=str(tmp_path / "none"), ckpt_every=0)
    r.run(3)
    np.testing.assert_allclose(np.asarray(r.state["w"]), 3.0)
    assert r.restarts == 1


def test_too_many_restarts_raises(tmp_path):
    def always_fail(step):
        raise RuntimeError("dead host")

    r = TrainRunner(_step_fn, {"w": jnp.zeros(1), "n": jnp.zeros((), jnp.int32)},
                    ckpt_dir=str(tmp_path), ckpt_every=100,
                    failure_hook=always_fail, max_restarts=2)
    with pytest.raises(RuntimeError, match="exceeded"):
        r.run(5)


def test_straggler_policy():
    pol = StragglerPolicy(timeout_s=0.5, max_strikes=2)
    pol.observe(0, 0.1)
    pol.observe(1, 0.9)            # strike 1
    pol.observe(2, 0.2)            # reset
    pol.observe(3, 0.9)
    with pytest.raises(TimeoutError):
        pol.observe(4, 0.9)
    assert len(pol.events) == 3


def test_quantize_roundtrip_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)
    err = jnp.zeros_like(g)
    q, scale, err2 = quantize_int8(g, err)
    rec = q.astype(jnp.float32) * scale
    # per-element error bounded by one quantisation step…
    assert float(jnp.abs(rec - g).max()) <= float(scale) + 1e-7
    # …and exactly captured by the feedback residual
    np.testing.assert_allclose(np.asarray(rec + err2), np.asarray(g),
                               atol=1e-6)


def test_compressed_psum_single_axis():
    """On a 1-sized axis the compressed reduce must be a near-identity
    (quantisation only) and converge via error feedback."""
    from repro.compat import make_mesh, shard_map
    mesh = make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(0, 1, (64,)),
                          jnp.float32)}
    err = init_error_feedback(g)

    def f(gg, ee):
        return compressed_psum_tree(gg, ee, "pod")

    from jax.sharding import PartitionSpec as P
    spec = jax.tree.map(lambda _: P(), g)
    out, err2 = jax.jit(
        shard_map(f, mesh=mesh, in_specs=(spec, spec),
                  out_specs=(spec, spec), check=False))(g, err)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=2e-2)
    # feeding the error back makes the two-step average exact-ish
    total = np.asarray(out["w"] + err2["w"])
    np.testing.assert_allclose(total, np.asarray(g["w"]), atol=1e-6)


def test_elastic_remesh_preserves_values():
    from repro.distributed import elastic_remesh
    from jax.sharding import PartitionSpec as P
    state = {"w": jnp.arange(16.0).reshape(8, 2)}
    spec = {"w": P("data", None)}
    mesh, resharded = elastic_remesh(state, spec)
    np.testing.assert_array_equal(np.asarray(resharded["w"]),
                                  np.asarray(state["w"]))
