"""The optimizer-generic population engine (core.deep.opt_step +
make_population_train_step(optimizer=...)): plain-SGD BIT-exactness against
the historical stateless step, momentum/AdamW trajectories through the
scanned chunk, per-member hyperparameter scale trees, global-norm grad
clipping, zero-moment shard padding, and opt-state sharding plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deep
from repro.core.population import LayeredPopulation
from repro.optim import (adamw, apply_updates, clip_by_global_norm,
                         global_norm, sgd)

LP = LayeredPopulation(
    6, 3,
    widths=((7,), (13, 5), (16, 8), (13, 5)),
    activations=("relu", ("tanh", "gelu"), ("relu", "tanh"),
                 ("tanh", "gelu")),
    block=8).sorted()


def _params():
    return deep.init_params(jax.random.PRNGKey(0), LP)


def _batch(b=9):
    return (jax.random.normal(jax.random.PRNGKey(1), (b, 6)),
            jax.random.randint(jax.random.PRNGKey(2), (b,), 0, 3))


def _tree_bit_eq(a, b, msg="bit drift"):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb)), msg


# --------------------------------------------------------------------- #
# THE acceptance regression: plain SGD through the engine is bit-exact  #
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("lr", ["scalar", "vector"])
def test_opt_step_plain_sgd_bit_exact_vs_sgd_step(lr):
    """The optimizer-generic engine with sgd() (momentum 0) must reproduce
    the historical ``_sgd_update`` parameter trajectory BIT-for-bit —
    scalar and per-member-vector learning rates alike — so swapping the
    driver onto the engine perturbs no committed baseline."""
    x, y = _batch()
    lrv = 0.05 if lr == "scalar" else jnp.linspace(0.02, 0.08,
                                                   LP.num_members)
    opt = sgd()
    st = opt.init(_params())
    a = b = _params()
    for _ in range(4):
        a, la, pa = deep.sgd_step(a, x, y, lrv, LP)
        b, st, lb, pb, gn = deep.opt_step(b, st, x, y, lrv, opt, LP)
        assert gn is None
        assert np.array_equal(np.asarray(la), np.asarray(lb))
    _tree_bit_eq(a, b)
    assert int(st["count"]) == 4


def test_engine_chunk_plain_sgd_bit_exact_vs_legacy_chunk():
    """Same regression through the scanned chunk: the (params, opt_state)
    carry must not change a single bit of the plain-SGD params."""
    params = _params()
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 12, 6))
    ys = jax.random.randint(jax.random.PRNGKey(2), (5, 12), 0, 3)
    lrs = jnp.linspace(0.02, 0.08, LP.num_members)
    legacy = deep.make_population_train_step(LP, scan_steps=5, donate=False)
    engine = deep.make_population_train_step(LP, optimizer=sgd(),
                                             scan_steps=5, donate=False)
    p1, l1, pe1 = legacy(params, xs, ys, lrs)
    p2, st, l2, pe2, gn = engine(params, sgd().init(params), xs, ys, lrs)
    assert gn is None and int(st["count"]) == 5
    _tree_bit_eq(p1, p2)
    assert np.array_equal(np.asarray(l1), np.asarray(l2))
    assert np.array_equal(np.asarray(pe1), np.asarray(pe2))


# --------------------------------------------------------------------- #
# stateful trajectories through the chunk                               #
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("make_opt", [
    lambda: sgd(momentum=0.9),
    lambda: adamw(weight_decay=0.01),
], ids=["momentum", "adamw"])
def test_chunk_matches_unscanned_reference_loop(make_opt):
    """The scanned chunk's stateful trajectory equals the hand-rolled
    opt.update/apply_updates loop (the same step math, no scan)."""
    params = _params()
    xs = jax.random.normal(jax.random.PRNGKey(1), (4, 12, 6))
    ys = jax.random.randint(jax.random.PRNGKey(2), (4, 12), 0, 3)
    opt = make_opt()

    p_ref, st_ref = params, opt.init(params)
    for t in range(4):
        (_, _), grads = jax.value_and_grad(deep.fused_loss, has_aux=True)(
            p_ref, xs[t], ys[t], LP)
        upd, st_ref = opt.update(grads, st_ref, p_ref, 0.05)
        p_ref = apply_updates(p_ref, upd)

    chunk = deep.make_population_train_step(LP, optimizer=make_opt(),
                                            scan_steps=4, donate=False)
    p_scan, st_scan, _, _, _ = chunk(params, opt.init(params), xs, ys, 0.05)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=1e-5, atol=1e-6), p_ref, p_scan)
    assert int(st_scan["count"]) == 4


def test_per_member_momentum_tree_equals_scalar_runs():
    """Members are independent, so a per-member momentum TREE must give
    each member exactly the trajectory of a whole-population run with that
    member's scalar momentum (values chosen exactly representable)."""
    params = _params()
    x, y = _batch()
    moms = [0.5, 0.875, 0.5, 0.875]
    tree_opt = sgd(momentum=deep.member_lr_tree(LP, jnp.asarray(moms)))
    p_tree, st = params, tree_opt.init(params)
    for _ in range(3):
        p_tree, st, *_ = deep.opt_step(p_tree, st, x, y, 0.05, tree_opt, LP)

    for mom in sorted(set(moms)):
        opt = sgd(momentum=mom)
        p_s, st_s = params, opt.init(params)
        for _ in range(3):
            p_s, st_s, *_ = deep.opt_step(p_s, st_s, x, y, 0.05, opt, LP)
        for m in range(LP.num_members):
            if moms[m] != mom:
                continue
            _tree_bit_eq(
                {k: v for k, v in
                 deep.extract_member(p_tree, LP, m).items()
                 if not isinstance(v, (str, tuple))},
                {k: v for k, v in deep.extract_member(p_s, LP, m).items()
                 if not isinstance(v, (str, tuple))},
                f"member {m} drifted under the momentum tree")


def test_grad_clip_applied_and_norm_reported():
    """--grad-clip semantics: the reported norm is the PRE-clip global
    norm and the update uses the clipped gradients."""
    params = _params()
    x, y = _batch()
    clip = 1e-2
    opt = sgd()
    p2, _, _, _, gnorm = deep.opt_step(params, opt.init(params), x, y,
                                       0.05, opt, LP, grad_clip=clip)
    grads = jax.grad(lambda p: deep.fused_loss(p, x, y, LP)[0])(params)
    np.testing.assert_allclose(float(gnorm), float(global_norm(grads)),
                               rtol=1e-6)
    assert float(gnorm) > clip  # the clip actually engaged
    clipped, _ = clip_by_global_norm(grads, clip)
    expect = jax.tree.map(lambda p, g: p - 0.05 * g, params, clipped)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7), p2, expect)


def test_engine_chunk_donates_params_and_state():
    params = _params()
    opt = sgd(momentum=0.9)
    st = opt.init(params)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 6))
    ys = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 3)
    chunk = deep.make_population_train_step(LP, optimizer=opt, scan_steps=2)
    _ = chunk(params, st, xs, ys, 0.05)
    assert params["w_in"].is_deleted()
    assert st["mu"]["w_in"].is_deleted()
    with pytest.raises(ValueError, match="optimizer"):
        deep.make_population_train_step(LP, grad_clip=1.0)


# --------------------------------------------------------------------- #
# shard padding of optimizer state                                      #
# --------------------------------------------------------------------- #

def test_pad_state_zero_fillers_real_region_bit_exact():
    params = _params()
    opt = adamw(weight_decay=0.01, state_dtype=jnp.bfloat16)
    st = opt.init(params)
    x, y = _batch()
    for _ in range(2):
        params, st, *_ = deep.opt_step(params, st, x, y, 0.05, opt, LP)
    lpp = LP.shard_pad(3)
    padded = deep.pad_state(st, LP, lpp)
    # scalar count passes through; moments keep their (bf16) dtype
    assert int(padded["count"]) == int(st["count"])
    assert padded["m"]["w_in"].dtype == jnp.bfloat16
    # real region bit-identical, filler rows exactly zero
    h0 = LP.layer_pop(0).total_hidden
    np.testing.assert_array_equal(np.asarray(padded["m"]["w_in"][:h0]),
                                  np.asarray(st["m"]["w_in"]))
    assert not np.any(np.asarray(padded["m"]["w_in"][h0:],
                                 dtype=np.float32))
    assert not np.any(np.asarray(padded["v"]["b_out"][LP.num_members:],
                                 dtype=np.float32))
    # no-op when already aligned
    assert deep.pad_state(st, LP, LP) is st


def test_padded_momentum_trajectory_equals_unpadded():
    """pad_params + pad_state mid-run (the rung-boundary repack) leaves
    the real members' stateful trajectory identical to the unpadded run."""
    params = _params()
    opt = sgd(momentum=0.9)
    st = opt.init(params)
    x, y = _batch(16)
    for _ in range(2):
        params, st, *_ = deep.opt_step(params, st, x, y, 0.05, opt, LP)
    lpp = LP.shard_pad(3)
    padded = deep.pad_params(params, LP, lpp,
                             jax.random.fold_in(jax.random.PRNGKey(0), 1))
    st_p = deep.pad_state(st, LP, lpp)
    for _ in range(3):
        params, st, _, per_u, _ = deep.opt_step(params, st, x, y, 0.05,
                                                opt, LP)
        padded, st_p, _, per_p, _ = deep.opt_step(padded, st_p, x, y, 0.05,
                                                  opt, lpp)
    np.testing.assert_allclose(np.asarray(per_p[:LP.num_members]),
                               np.asarray(per_u), rtol=1e-5, atol=1e-6)
    for m in range(LP.num_members):
        a = deep.extract_member(params, LP, m)
        b = deep.extract_member(padded, lpp, m)
        jax.tree.map(lambda x_, y_: None if isinstance(x_, str)
                     else np.testing.assert_allclose(
                         np.asarray(x_), np.asarray(y_),
                         rtol=1e-5, atol=1e-6), a, b)


def test_pad_state_rejects_unpaddable_leaves():
    with pytest.raises(ValueError, match="params-shaped"):
        deep.pad_state({"weird": jnp.zeros((3,))}, LP, LP.shard_pad(3))


# --------------------------------------------------------------------- #
# sharding plumbing                                                     #
# --------------------------------------------------------------------- #

def test_population_opt_shardings_structure():
    """population_opt_shardings returns one NamedSharding per state leaf
    (momentum moments follow their parameters; count replicates)."""
    from repro.compat import make_mesh
    from repro.distributed.sharding import population_opt_shardings
    mesh = make_mesh((1, 1), ("data", "model"))
    opt = sgd(momentum=0.9)
    sh = population_opt_shardings(LP, opt, mesh)
    state = opt.init(_params())
    assert (jax.tree_util.tree_structure(jax.tree.map(lambda s: 0, sh))
            == jax.tree_util.tree_structure(jax.tree.map(lambda x: 0,
                                                         state)))
    born = jax.jit(opt.init, out_shardings=sh)(_params())
    assert int(born["count"]) == 0


# --------------------------------------------------------------------- #
# per-leaf hyperparameter trees at the optimizer layer                  #
# --------------------------------------------------------------------- #

def test_adamw_per_member_weight_decay_tree():
    """A weight-decay scale tree decays each member's params by its own
    coefficient (checked against per-scalar whole-population runs)."""
    params = _params()
    x, y = _batch()
    wds = [0.0, 0.25, 0.0, 0.25]
    tree_opt = adamw(weight_decay=deep.member_lr_tree(LP, jnp.asarray(wds)))
    p_tree, st = params, tree_opt.init(params)
    for _ in range(2):
        p_tree, st, *_ = deep.opt_step(p_tree, st, x, y, 0.05, tree_opt, LP)
    for wd in sorted(set(wds)):
        opt = adamw(weight_decay=wd)
        p_s, st_s = params, opt.init(params)
        for _ in range(2):
            p_s, st_s, *_ = deep.opt_step(p_s, st_s, x, y, 0.05, opt, LP)
        for m in range(LP.num_members):
            if wds[m] != wd:
                continue
            a = deep.extract_member(p_tree, LP, m)
            b = deep.extract_member(p_s, LP, m)
            jax.tree.map(lambda x_, y_: None if isinstance(x_, str)
                         else np.testing.assert_allclose(
                             np.asarray(x_), np.asarray(y_),
                             rtol=1e-6, atol=1e-7), a, b)


def test_broadcast_scale_rejects_raw_vectors_and_bad_structure():
    from repro.optim import broadcast_scale, hyper_on
    params = {"a": jnp.zeros((2,)), "b": jnp.zeros((3,))}
    with pytest.raises(ValueError, match="momentum"):
        broadcast_scale(jnp.zeros((4,)), params, "momentum")
    with pytest.raises(ValueError, match="structure"):
        broadcast_scale({"a": 1.0}, params, "weight_decay")
    assert hyper_on({"a": 0.0}) and hyper_on(0.1) and not hyper_on(0.0)
