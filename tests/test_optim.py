"""Optimizers: reference-math agreement, dtype policies, factored shapes,
schedules, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor, adamw, apply_updates, clip_by_global_norm,
                         constant_lr, global_norm, make_optimizer, sgd,
                         warmup_cosine)


def test_sgd_matches_formula():
    opt = sgd()
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -1.0])}
    st = opt.init(p)
    upd, st = opt.update(g, st, p, 0.1)
    p2 = apply_updates(p, upd)
    np.testing.assert_allclose(np.asarray(p2["w"]), [0.95, 2.1], atol=1e-7)


def test_sgd_momentum():
    opt = sgd(momentum=0.9)
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    st = opt.init(p)
    vel = 0.0
    pv = 0.0
    for _ in range(3):
        upd, st = opt.update(g, st, p, 0.1)
        p = apply_updates(p, upd)
        vel = 0.9 * vel + 1.0
        pv -= 0.1 * vel
    np.testing.assert_allclose(float(p["w"][0]), pv, rtol=1e-6)


def test_adamw_matches_reference():
    b1, b2, eps, wd, lr = 0.9, 0.95, 1e-8, 0.1, 1e-2
    opt = adamw(b1=b1, b2=b2, eps=eps, weight_decay=wd)
    p = {"w": jnp.asarray([0.3, -0.7])}
    st = opt.init(p)
    m = np.zeros(2)
    v = np.zeros(2)
    pw = np.asarray(p["w"]).copy()
    for t in range(1, 4):
        g = np.asarray([0.1 * t, -0.2])
        upd, st = opt.update({"w": jnp.asarray(g)}, st, p, lr)
        p = apply_updates(p, upd)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        pw = pw - lr * (mh / (np.sqrt(vh) + eps) + wd * pw)
        np.testing.assert_allclose(np.asarray(p["w"]), pw, rtol=1e-5)


def test_adamw_bf16_state_halves_memory():
    opt = adamw(state_dtype=jnp.bfloat16)
    p = {"w": jnp.zeros((128, 64))}
    st = opt.init(p)
    assert st["m"]["w"].dtype == jnp.bfloat16
    upd, st = opt.update({"w": jnp.ones((128, 64))}, st, p, 1e-3)
    assert st["v"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(upd["w"], np.float32)).all()


def test_adafactor_factored_state_is_small():
    opt = adafactor()
    p = {"w": jnp.zeros((512, 256)), "b": jnp.zeros((256,))}
    st = opt.init(p)
    leaves = st["leaves"]
    assert leaves["w"]["v_row"].shape == (512,)
    assert leaves["w"]["v_col"].shape == (256,)
    assert "v" in leaves["b"]                      # vectors unfactored
    assert leaves["w"]["m"].dtype == jnp.bfloat16
    # state for the matrix is O(n+m), not O(nm)
    matrix_state = leaves["w"]["v_row"].size + leaves["w"]["v_col"].size
    assert matrix_state < p["w"].size // 64


def test_adafactor_descends():
    opt = adafactor(momentum=0.0)
    w = jnp.asarray(np.random.default_rng(0).normal(0, 1, (16, 8)),
                    jnp.float32)
    p = {"w": w}
    st = opt.init(p)

    def loss(pp):
        return (pp["w"] ** 2).sum()

    for _ in range(20):
        g = jax.grad(loss)(p)
        upd, st = opt.update(g, st, p, 0.05)
        p = apply_updates(p, upd)
    assert float(loss(p)) < float(loss({"w": w}))


def test_sgd_momentum_scale_tree_per_leaf():
    """A momentum TREE applies each leaf's own coefficient — the mechanism
    carrying per-member momentum into fused populations."""
    p = {"a": jnp.zeros(1), "b": jnp.zeros(1)}
    g = {"a": jnp.ones(1), "b": jnp.ones(1)}
    moms = {"a": 0.5, "b": 0.875}
    opt = sgd(momentum=moms)
    st = opt.init(p)
    assert "mu" in st                             # trees are always stateful
    ref = {k: sgd(momentum=moms[k]) for k in p}
    ref_p = {k: {"w": p[k]} for k in p}
    ref_st = {k: ref[k].init(ref_p[k]) for k in p}
    for _ in range(3):
        upd, st = opt.update(g, st, p, 0.1)
        p = apply_updates(p, upd)
        for k in ref:
            u, ref_st[k] = ref[k].update({"w": g[k]}, ref_st[k], ref_p[k],
                                         0.1)
            ref_p[k] = apply_updates(ref_p[k], u)
    for k in p:
        np.testing.assert_array_equal(np.asarray(p[k]),
                                      np.asarray(ref_p[k]["w"]))


def test_adamw_weight_decay_scale_tree_per_leaf():
    p = {"a": jnp.full((2,), 0.5), "b": jnp.full((2,), 0.5)}
    g = {"a": jnp.full((2,), 0.1), "b": jnp.full((2,), 0.1)}
    opt = adamw(weight_decay={"a": 0.0, "b": 0.5})
    st = opt.init(p)
    upd, st = opt.update(g, st, p, 1e-2)
    ua, ub = np.asarray(upd["a"]), np.asarray(upd["b"])
    # identical grads → the decayed leaf steps further downhill by wd·p·lr
    np.testing.assert_allclose(ub - ua, -1e-2 * 0.5 * 0.5, rtol=1e-5)


def test_broadcast_scale_structure_check():
    from repro.optim import broadcast_scale
    p = {"a": jnp.zeros(1)}
    with pytest.raises(ValueError, match="momentum"):
        broadcast_scale(jnp.zeros((3,)), p, "momentum")


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 10.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the limit → untouched
    same, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, warmup_steps=10, total_steps=110, min_ratio=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert 0.09 < float(lr(jnp.asarray(110))) < 0.11
    assert float(lr(jnp.asarray(60))) < 1.0


def test_state_spec_structures_match():
    from jax.sharding import PartitionSpec as P
    p = {"w": jnp.zeros((8, 4)), "nest": {"v": jnp.zeros((4,))}}
    specs = {"w": P("data", "model"), "nest": {"v": P(None)}}
    absp = jax.eval_shape(lambda: p)
    for name in ("sgd", "adamw", "adafactor"):
        opt = make_optimizer(name)
        st = opt.init(p)
        ss = opt.state_specs(specs, absp)
        assert jax.tree.structure(st) == jax.tree.structure(
            ss, is_leaf=lambda x: isinstance(x, P))
