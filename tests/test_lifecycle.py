"""Successive-halving lifecycle (core.lifecycle + the --halving driver):
compaction is a bit-exact gather (params AND optimizer moments), a
survivor's post-compaction trajectory equals its no-pruning trajectory,
leaderboards keep speaking in ORIGINAL member ids across rungs, and
--resume restores mid-ladder onto the compacted layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deep
from repro.core.lifecycle import HalvingSchedule, compact, survivors
from repro.core.population import LayeredPopulation

LP = LayeredPopulation(
    6, 3,
    widths=((7,), (13, 5), (64, 32, 16), (13, 5), (9,), (16, 8)),
    activations=("relu", ("tanh", "gelu"), ("mish", "sigmoid", "tanh"),
                 ("tanh", "gelu"), "relu", ("relu", "tanh")),
    block=8).sorted()


# --------------------------------------------------------------------- #
# schedule                                                              #
# --------------------------------------------------------------------- #

def test_schedule_parse_and_segments():
    s = HalvingSchedule.parse("500:0.5, 1000:0.5,2000:0.25")
    assert s.rungs == ((500, 0.5), (1000, 0.5), (2000, 0.25))
    assert s.segments(3000) == ((500, 0.5), (1000, 0.5), (2000, 0.25),
                                (3000, None))
    # rungs at or past the total never fire: a short run is a ladder prefix
    assert s.segments(1500) == ((500, 0.5), (1000, 0.5), (1500, None))
    assert s.segments(300) == ((300, None),)


@pytest.mark.parametrize("bad", ["", "500", "500:0.5:1", "a:0.5",
                                 "500:0.5,400:0.5", "500:0", "500:1.5",
                                 "0:0.5"])
def test_schedule_rejects_bad_specs(bad):
    with pytest.raises(ValueError):
        HalvingSchedule.parse(bad)


def test_n_keep_floor_never_below_one():
    assert HalvingSchedule.n_keep(8, 0.5) == 4
    assert HalvingSchedule.n_keep(5, 0.5) == 2
    assert HalvingSchedule.n_keep(3, 0.25) == 1
    assert HalvingSchedule.n_keep(1, 0.01) == 1


def test_survivors_sorted_and_deterministic_on_ties():
    losses = np.array([3.0, 1.0, 2.0, 5.0, 1.0, 9.0])
    np.testing.assert_array_equal(survivors(losses, 0.5), [1, 2, 4])
    # tie between members 1 and 4 → stable sort keeps the lower index first
    np.testing.assert_array_equal(survivors(losses, 1 / 6), [1])


# --------------------------------------------------------------------- #
# subset / compact                                                      #
# --------------------------------------------------------------------- #

def test_subset_validation():
    with pytest.raises(ValueError, match="empty"):
        LP.subset(())
    with pytest.raises(ValueError, match="increasing"):
        LP.subset((2, 1))
    with pytest.raises(ValueError, match="out of range"):
        LP.subset((0, LP.num_members))
    pad = LP.shard_pad(4)
    with pytest.raises(ValueError, match="fillers"):
        pad.subset((0, pad.num_real))  # a pad slot cannot survive


def _tree_eq(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def test_compact_params_and_opt_moments_bit_exact():
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    # fabricated SGD-momentum state: params-shaped 'mu' + scalar count
    mu = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(7), x.shape), params)
    state = {"count": jnp.asarray(3, jnp.int32), "mu": mu}

    keep = [0, 2, 3, 5]
    new_lp, new_p, new_st = compact(LP, params, state, keep)
    assert new_lp == LP.subset(keep)
    assert int(new_st["count"]) == 3
    for i, m in enumerate(keep):
        _tree_eq(deep.extract_member(new_p, new_lp, i),
                 deep.extract_member(params, LP, m))
        # optimizer moments ride through the SAME index maps, bit-exact
        _tree_eq(deep.extract_member(new_st["mu"], new_lp, i),
                 deep.extract_member(mu, LP, m))


def test_compact_device_gather_bit_exact_vs_host():
    """The jitted static-index device gather (the default — no host
    round-trip at rung boundaries) produces trees BIT-identical to the
    device_get → numpy fallback, params and optimizer moments alike."""
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    mu = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(7), x.shape), params)
    state = {"count": jnp.asarray(3, jnp.int32), "mu": mu}
    keep = [0, 2, 3, 5]
    lp_d, p_d, st_d = compact(LP, params, state, keep, gather="device")
    lp_h, p_h, st_h = compact(LP, params, state, keep, gather="host")
    assert lp_d == lp_h
    for a, b in zip(jax.tree.leaves((p_d, st_d)),
                    jax.tree.leaves((p_h, st_h))):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "gather drift"
    with pytest.raises(ValueError, match="gather"):
        compact(LP, params, None, keep, gather="tpu")


def test_compact_from_padded_pop_equals_unpadded():
    """Gathering survivors out of a shard-padded layout gives the same
    tree as gathering them from the unpadded one (pads are trailing and
    never share a bucket with real members)."""
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    lpp = LP.shard_pad(4)
    padded = deep.pad_params(params, LP, lpp,
                             jax.random.fold_in(jax.random.PRNGKey(0), 1))
    keep = [1, 2, 4]
    lp_a, p_a, _ = compact(LP, params, None, keep)
    lp_b, p_b, _ = compact(lpp, padded, None, keep)
    assert lp_a == lp_b
    _tree_eq(p_a, p_b)


def test_compact_depth_shrinks_and_forward_matches():
    """Pruning every depth-3 member truncates the layout (survivors were
    identity pass-throughs in the dropped layers) and the compacted
    forward equals the survivors' slices of the full forward."""
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    keep = [m for m in range(LP.num_members) if LP.member_depths[m] < 3]
    new_lp, new_p, _ = compact(LP, params, None, keep)
    assert new_lp.depth == 2 and len(new_p["mid"]) == 1
    x = jax.random.normal(jax.random.PRNGKey(1), (9, 6))
    full = deep.forward(params, x, LP)
    np.testing.assert_allclose(np.asarray(full[:, keep]),
                               np.asarray(deep.forward(new_p, x, new_lp)),
                               rtol=1e-6, atol=1e-6)


def test_compact_regroups_bucket_around_pruned_member():
    """Pruning a member out of the middle of a bucket re-buckets the
    non-contiguous survivors into one run, weights gathered in order.
    In the sorted LP, members 2, 3 ((13,5)) and 4 ((16,8)) share one
    padded-(16,8) projection-0 bucket; member 3 is dropped."""
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    old_real = [bk for bk in LP.proj_buckets(0) if bk[6]]
    assert old_real[0][:2] == (2, 3)  # the shared (16,8)-padded run
    keep = [2, 4]
    new_lp, new_p, _ = compact(LP, params, None, keep)
    real = [bk for bk in new_lp.proj_buckets(0) if bk[6]]
    assert len(real) == 1 and real[0][1] == 2  # one bucket, both members
    assert len(new_p["mid"][0]["w"]) == 1
    old_w = np.asarray(params["mid"][0]["w"][0])
    np.testing.assert_array_equal(np.asarray(new_p["mid"][0]["w"][0]),
                                  old_w[[0, 2]])


def test_compact_real_adamw_state_bit_exact_incl_bf16_moments():
    """compact gathers REAL AdamW state (not a fabricated tree): after two
    engine steps the survivors' m/v moments come out bit-exact, in their
    stored (bf16) dtype, with the step count riding through."""
    from repro.optim import adamw
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    opt = adamw(weight_decay=0.01, state_dtype=jnp.bfloat16)
    state = opt.init(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (9, 6))
    y = jax.random.randint(jax.random.PRNGKey(2), (9,), 0, 3)
    for _ in range(2):
        params, state, *_ = deep.opt_step(params, state, x, y, 0.05, opt,
                                          LP)
    keep = [0, 2, 3, 5]
    new_lp, new_p, new_st = compact(LP, params, state, keep)
    assert int(new_st["count"]) == 2
    assert new_st["m"]["w_in"].dtype == jnp.bfloat16
    for i, m in enumerate(keep):
        for mom in ("m", "v"):
            _tree_eq(deep.extract_member(new_st[mom], new_lp, i),
                     deep.extract_member(state[mom], LP, m))


def test_trajectory_equals_no_pruning_run_with_momentum_state():
    """The lifecycle invariant EXTENDED to stateful optimizers: a
    survivor's post-compaction trajectory — params AND momentum buffers
    riding through compact + the engine — equals its never-pruned
    trajectory to float tolerance."""
    from repro.optim import sgd as make_sgd
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    opt = make_sgd(momentum=0.9)
    lr = jnp.linspace(0.02, 0.08, LP.num_members)
    xs = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 6))
    ys = jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 3)

    full, st_full = params, opt.init(params)
    for t in range(8):
        full, st_full, *_ = deep.opt_step(full, st_full, xs[t], ys[t], lr,
                                          opt, LP)

    pruned, st = params, opt.init(params)
    for t in range(4):
        pruned, st, *_ = deep.opt_step(pruned, st, xs[t], ys[t], lr, opt,
                                       LP)
    keep = [0, 2, 3, 5]
    new_lp, pruned, st = compact(LP, pruned, st, keep)
    lr2 = lr[np.asarray(keep)]
    for t in range(4, 8):
        pruned, st, *_ = deep.opt_step(pruned, st, xs[t], ys[t], lr2, opt,
                                       new_lp)

    for i, m in enumerate(keep):
        for tree_a, tree_b in ((pruned, full), (st["mu"], st_full["mu"])):
            a = deep.extract_member(tree_a, new_lp, i)
            b = deep.extract_member(tree_b, LP, m)
            jax.tree.map(
                lambda x, y: None if isinstance(x, str)
                else np.testing.assert_allclose(np.asarray(x),
                                                np.asarray(y),
                                                rtol=1e-5, atol=1e-6), a, b)


def test_compact_rejects_factored_state_and_wrong_layout():
    from repro.optim import adafactor
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    state = adafactor().init(params)
    with pytest.raises(ValueError, match="factored|compactable"):
        compact(LP, params, state, [0, 1])
    from repro.core.population import Population
    pop = Population(4, 2, (8, 8), ("relu", "relu"))
    with pytest.raises(TypeError, match="LayeredPopulation"):
        compact(pop, params, None, [0])


# --------------------------------------------------------------------- #
# adafactor rung compaction (compact_factored)                          #
# --------------------------------------------------------------------- #

def test_compact_factored_carries_momentum_bit_exact():
    """compact_factored on a REAL trained adafactor state: survivors'
    momentum comes out bit-exact (in its stored bf16 dtype) through the
    same gather as the params, the step count rides through, and the
    factored v_row/v_col — which mix members over the fused axis — are
    dropped for the caller to re-initialise."""
    from repro.core.lifecycle import compact_factored
    from repro.optim import adafactor
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    opt = adafactor(momentum=0.9)
    state = opt.init(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (9, 6))
    y = jax.random.randint(jax.random.PRNGKey(2), (9,), 0, 3)
    for _ in range(3):
        params, state, *_ = deep.opt_step(params, state, x, y, 0.01, opt,
                                          LP)
    keep = [0, 2, 3, 5]
    new_lp, new_p, carry = compact_factored(LP, params, state, keep)
    assert new_lp == LP.subset(keep)
    assert int(carry["count"]) == 3
    assert carry["m"] is not None
    # momentum gathered exactly as the params are
    from repro.core.lifecycle import compact_params

    def leaf(st):
        return st["m"]

    from repro.core.lifecycle import _is_factored_leaf
    m_tree = jax.tree.map(leaf, state["leaves"], is_leaf=_is_factored_leaf)
    _tree_eq(carry["m"], compact_params(LP, new_lp, m_tree, keep))
    for i, m in enumerate(keep):
        _tree_eq(deep.extract_member(carry["m"], new_lp, i),
                 deep.extract_member(m_tree, LP, m))


def test_compact_factored_without_momentum_and_validation():
    from repro.core.lifecycle import compact_factored
    from repro.optim import adafactor
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    state = adafactor(momentum=0.0).init(params)
    new_lp, new_p, carry = compact_factored(LP, params, state, [1, 4])
    assert carry["m"] is None and int(carry["count"]) == 0
    assert new_lp.num_members == 2
    # params-shaped (non-factored) states belong to compact(), loudly
    with pytest.raises(ValueError, match="adafactor"):
        compact_factored(LP, params, {"mu": params}, [0])


def test_trajectory_equals_no_pruning_run():
    """THE lifecycle invariant: members are independent, so a survivor's
    post-compaction trajectory (smaller fused layout, re-jitted step)
    equals its trajectory in the never-pruned population to float
    tolerance — per-member lr vector included."""
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    lr = jnp.linspace(0.02, 0.08, LP.num_members)
    xs = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 6))
    ys = jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 3)

    full = params
    for t in range(8):
        full, _, _ = deep.sgd_step(full, xs[t], ys[t], lr, LP)

    pruned = params
    for t in range(4):
        pruned, _, _ = deep.sgd_step(pruned, xs[t], ys[t], lr, LP)
    keep = [0, 2, 3, 5]
    new_lp, pruned, _ = compact(LP, pruned, None, keep)
    lr2 = lr[np.asarray(keep)]
    for t in range(4, 8):
        pruned, _, _ = deep.sgd_step(pruned, xs[t], ys[t], lr2, new_lp)

    for i, m in enumerate(keep):
        a = deep.extract_member(pruned, new_lp, i)
        b = deep.extract_member(full, LP, m)
        jax.tree.map(   # skip the activation-name string leaves
            lambda x, y: None if isinstance(x, str)
            else np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                            rtol=1e-5, atol=1e-6), a, b)


# --------------------------------------------------------------------- #
# leaderboard identity                                                  #
# --------------------------------------------------------------------- #

def test_leaderboard_reports_original_ids_after_two_rungs():
    from repro.core.selection import leaderboard
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    member_ids = np.arange(LP.num_members)

    lp, p = LP, params
    rng = np.random.default_rng(0)
    for frac in (0.5, 0.5):                     # two rungs
        losses = rng.normal(1.0, 0.3, lp.num_members)
        keep = survivors(losses, frac)
        member_ids = member_ids[keep]
        lp, p, _ = compact(lp, p, None, keep)

    assert lp.num_members == 1 and len(member_ids) == 1
    losses = np.array([0.42])
    rows = leaderboard(lp, losses, member_ids=member_ids)
    assert rows[0]["member"] == int(member_ids[0])
    assert rows[0]["slot"] == 0
    # the reported architecture is the ORIGINAL member's architecture
    assert rows[0]["hidden"] == LP.widths[int(member_ids[0])]
    with pytest.raises(ValueError, match="member_ids"):
        leaderboard(lp, losses, member_ids=np.arange(5))


# --------------------------------------------------------------------- #
# driver: --halving end to end                                          #
# --------------------------------------------------------------------- #

_BASE = ["--arch", "parallelmlp-10k", "--reduced", "--scan-steps", "2",
         "--samples", "256", "--population-acts", "relu,tanh",
         "--population-depths", "8,4;8,4;6;5;12,6;7;9;10",
         "--per-member-lr", "--ckpt-every", "2"]
_DRIVER = _BASE + ["--halving", "4:0.5,8:0.5"]


def test_halving_driver_prunes_and_checkpoints_lifecycle(tmp_path):
    from repro.checkpoint import lifecycle_from_meta, load_meta
    from repro.launch.train import main
    params, lp = main(_DRIVER + ["--steps", "12",
                                 "--ckpt-dir", str(tmp_path / "ck")])
    # 8 → 4 → 2 members; the returned layout is the compacted one
    assert lp.num_real == 2
    meta, step = load_meta(str(tmp_path / "ck"))
    assert step == 11
    rung, member_ids, n0 = lifecycle_from_meta(meta, lp)
    assert rung == 2 and n0 == 8
    assert len(member_ids) == 2
    assert all(0 <= m < 8 for m in member_ids)


def test_halving_resume_mid_ladder_matches_straight_run(tmp_path):
    """Stop between rungs, --resume with the same ladder: the continued
    run must equal the uninterrupted one — layout, params, and the
    survivor→original mapping."""
    from repro.checkpoint import load_meta
    from repro.launch.train import main
    # run A stops mid-ladder (rung 0 applied at step 4, rung 1 not reached)
    main(_DRIVER + ["--steps", "6", "--ckpt-dir", str(tmp_path / "ck")])
    meta_a, _ = load_meta(str(tmp_path / "ck"))
    assert meta_a["lifecycle"]["rung"] == 1
    p_res, lp_res = main(_DRIVER + ["--steps", "12", "--resume",
                                    "--ckpt-dir", str(tmp_path / "ck")])
    p_str, lp_str = main(_DRIVER + ["--steps", "12",
                                    "--ckpt-dir", str(tmp_path / "ck2")])
    assert lp_res == lp_str
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7), p_res, p_str)
    meta_r, _ = load_meta(str(tmp_path / "ck"))
    meta_s, _ = load_meta(str(tmp_path / "ck2"))
    assert meta_r["lifecycle"] == meta_s["lifecycle"]


_ADAMW = ["--optimizer", "adamw", "--weight-decay", "0.01",
          "--opt-state-dtype", "bfloat16"]


def test_halving_driver_adamw_moments_through_rungs(tmp_path):
    """Driver-level halving with a STATEFUL optimizer: AdamW moments are
    compacted through two rung boundaries, the final checkpoint carries
    the (bf16) state tree for the compacted layout, and the optimizer
    record rides in meta['train']."""
    from repro.checkpoint import load_meta, restore_population
    from repro.core import deep
    from repro.launch.train import main
    from repro.optim import adamw

    params, lp = main(_DRIVER + _ADAMW
                      + ["--steps", "12", "--ckpt-dir",
                         str(tmp_path / "ck")])
    assert lp.num_real == 2
    meta, step = load_meta(str(tmp_path / "ck"))
    assert step == 11
    rec = meta["train"]["optimizer"]
    assert rec["name"] == "adamw" and rec["state_dtype"] == "bfloat16"
    # restore the saved opt state for the COMPACTED layout and check the
    # moments are live (non-zero) in the stored dtype
    opt = adamw(weight_decay=0.01, state_dtype=jnp.bfloat16)
    extra_like = jax.eval_shape(opt.init, deep.abstract_params(lp))
    _, lp2, _, st = restore_population(str(tmp_path / "ck"),
                                       extra_like=extra_like)
    assert lp2 == lp
    assert int(st["count"]) == 12
    assert st["m"]["w_in"].dtype == jnp.bfloat16
    assert np.any(np.asarray(st["m"]["w_in"], dtype=np.float32))


def test_halving_adamw_resume_mid_ladder_matches_straight_run(tmp_path):
    """Resume-mid-ladder equality with STATEFUL opt state: stopping
    between rungs and resuming must reproduce the uninterrupted AdamW
    run — the restored moments (and their compaction at the later rung)
    carry the trajectory, so parameter equality proves the state
    round-trip."""
    from repro.checkpoint import load_meta
    from repro.launch.train import main
    main(_DRIVER + _ADAMW + ["--steps", "6",
                             "--ckpt-dir", str(tmp_path / "ck")])
    meta_a, _ = load_meta(str(tmp_path / "ck"))
    assert meta_a["lifecycle"]["rung"] == 1
    p_res, lp_res = main(_DRIVER + _ADAMW
                         + ["--steps", "12", "--resume",
                            "--ckpt-dir", str(tmp_path / "ck")])
    p_str, lp_str = main(_DRIVER + _ADAMW
                         + ["--steps", "12",
                            "--ckpt-dir", str(tmp_path / "ck2")])
    assert lp_res == lp_str
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7), p_res, p_str)
    meta_r, _ = load_meta(str(tmp_path / "ck"))
    meta_s, _ = load_meta(str(tmp_path / "ck2"))
    assert meta_r["lifecycle"] == meta_s["lifecycle"]
    assert meta_r["train"]["optimizer"] == meta_s["train"]["optimizer"]


_ADAMW_HALVING_4DEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
import jax, jax.numpy as jnp, numpy as np
from repro.launch.train import main

BASE = ["--arch", "parallelmlp-10k", "--reduced", "--scan-steps", "2",
        "--samples", "256", "--population-acts", "relu,tanh",
        "--population-depths", "8,4;8,4;6;5;12,6;7;9;10",
        "--per-member-lr", "--ckpt-every", "2",
        "--halving", "4:0.5,8:0.5",
        "--optimizer", "adamw", "--weight-decay", "0.01",
        "--opt-state-dtype", "bfloat16"]
assert len(jax.devices()) == 4
# stop between rungs, then resume mid-ladder: rung 1 fires on the
# compacted SHARDED layout with restored (sharded) AdamW moments
main(BASE + ["--steps", "6", "--ckpt-dir", sys.argv[1] + "/ck"])
p_res, lp_res = main(BASE + ["--steps", "12", "--resume",
                             "--ckpt-dir", sys.argv[1] + "/ck"])
p_str, lp_str = main(BASE + ["--steps", "12",
                             "--ckpt-dir", sys.argv[1] + "/ck2"])
assert lp_res == lp_str
jax.tree.map(lambda a, b: np.testing.assert_allclose(
    np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), p_res, p_str)
print("OK")
"""


@pytest.mark.slow
def test_adamw_halving_resume_on_4_device_mesh(tmp_path):
    """Acceptance: an AdamW --halving run prunes/compacts/resumes with opt
    moments surviving rung boundaries ON THE 4-FAKE-DEVICE MESH — the
    resumed ladder equals the uninterrupted one with sharded moment
    restore + sharded compaction in the loop."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    r = subprocess.run([sys.executable, "-c", _ADAMW_HALVING_4DEV,
                        str(tmp_path)],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_halving_catchup_prune_saves_compacted_latest(tmp_path):
    """Resuming a pre-ladder checkpoint PAST a rung boundary applies the
    missed prune immediately and force-saves the compacted state at the
    last COMPLETED step — never at the long-gone boundary step — so the
    directory's LATEST checkpoint always matches the live layout (a crash
    in the next segment must replay onto the compacted state)."""
    from repro.checkpoint import latest_steps, restore_population
    from repro.launch.train import main
    # plain run (no ladder) to step 6: checkpoints at 1, 3, 5
    main(_BASE + ["--steps", "6", "--ckpt-dir", str(tmp_path / "ck")])
    # resume with a rung boundary (step 2) that is already behind
    params, lp = main(_BASE + ["--steps", "10", "--resume",
                               "--halving", "2:0.5",
                               "--ckpt-dir", str(tmp_path / "ck")])
    assert lp.num_real == 4  # 8 members, one 0.5 rung, applied on resume
    # the catch-up save landed at the last completed step (5), with the
    # COMPACTED layout — not at the boundary step (1) under stale latest
    steps = latest_steps(str(tmp_path / "ck"))
    assert 5 in steps and 1 not in steps
    _, lp5, _ = restore_population(str(tmp_path / "ck"), step=5)
    assert lp5.num_real == 4
