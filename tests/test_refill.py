"""Slot-refill search (DESIGN.md §13): layout growth is the bit-exact
inverse of compaction, constant-size refill rewrites pruned slots in place
with ZERO re-jit, refilled members get zero optimizer moments and fresh
ids (never a pruned seed's), and the --refill driver is deterministic
across resume — while --refill off stays bit-identical to the historical
halving driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deep
from repro.core.lifecycle import (compact, compact_params, grow,
                                  grow_params, member_moment_mask,
                                  refill_params, refill_state)
from repro.core.population import LayeredPopulation
from repro.optim import adafactor, adamw, scale_member_moments, sgd
from repro.search import RefillController, SearchSpace

LP = LayeredPopulation(
    6, 3,
    widths=((7,), (13, 5), (64, 32, 16), (13, 5), (9,), (16, 8)),
    activations=("relu", ("tanh", "gelu"), ("mish", "sigmoid", "tanh"),
                 ("tanh", "gelu"), "relu", ("relu", "tanh")),
    block=8).sorted()

NEW_W = ((13, 5), (8,))
NEW_A = (("tanh", "gelu"), "relu")


def _tree_eq(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def _all_zero(tree) -> bool:
    """True iff every NUMERIC leaf is zero (extract_member trees carry
    string metadata like the activation names)."""
    return all(not np.asarray(x).any() for x in jax.tree.leaves(tree)
               if np.issubdtype(np.asarray(x).dtype, np.number))


# --------------------------------------------------------------------- #
# layout growth                                                         #
# --------------------------------------------------------------------- #

def test_grow_positions_keep_sorted_layout():
    positions = LP.grow_positions(NEW_W, NEW_A)
    grown = LP.grow(NEW_W, NEW_A, positions)
    assert grown.num_real == LP.num_real + 2
    # sorted base stays sorted after the merge placement
    assert grown == grown.sorted()
    # positions[j] carries new member j's architecture
    for j, p in enumerate(positions):
        assert grown.widths[p] == NEW_W[j]
        assert grown.activations[p] == (
            NEW_A[j] if isinstance(NEW_A[j], tuple)
            else (NEW_A[j],) * len(NEW_W[j]))
    # removing the grown positions reads back the original layout
    rest = tuple(m for m in range(grown.num_real)
                 if m not in set(positions))
    assert grown.subset(rest) == LP


def test_grow_validation():
    with pytest.raises(ValueError, match="shard-pad"):
        LP.shard_pad(4).grow(NEW_W, NEW_A, (0, 1))
    with pytest.raises(ValueError, match="duplicate"):
        LP.grow(NEW_W, NEW_A, (2, 2))
    with pytest.raises(ValueError, match="range"):
        LP.grow(NEW_W, NEW_A, (0, LP.num_real + 2))


@pytest.mark.parametrize("gather", ["host", "device"])
def test_grow_then_compact_roundtrip_bit_exact(gather):
    """The tentpole invariant: grow-then-compact is BIT-IDENTICAL to
    never growing (survivors), and the grown members carry exactly their
    fresh init — grow_params is the inverse of compact_params."""
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    positions = LP.grow_positions(NEW_W, NEW_A)
    grown = LP.grow(NEW_W, NEW_A, positions)
    fresh_lp = grown.subset(tuple(sorted(positions)))
    fresh = deep.init_params(jax.random.PRNGKey(9), fresh_lp)
    gp = grow_params(LP, grown, params, positions, fresh, gather=gather)
    # compact the grown tree back down to the survivors → original tree
    rest = tuple(m for m in range(grown.num_real)
                 if m not in set(positions))
    back = compact_params(grown, LP, gp, rest, gather=gather)
    _tree_eq(back, params)
    # born members == their fresh init, member by member
    for r, p in enumerate(sorted(positions)):
        _tree_eq(deep.extract_member(gp, grown, p),
                 deep.extract_member(fresh, fresh_lp, r))


def test_grow_unsorted_positions_pair_members_correctly():
    """grow_positions pairs positions[j] with new member j even when the
    sorted-merge places them OUT of tuple order — the splice must index
    the fresh tree by position rank, not tuple index."""
    # deeper-first arch order vs the sorted layout → descending positions
    w, a = NEW_W, NEW_A
    positions = LP.grow_positions(w, a)
    assert tuple(sorted(positions)) != positions  # exercises the rank map
    grown = LP.grow(w, a, positions)
    fresh_lp = grown.subset(tuple(sorted(positions)))
    fresh = deep.init_params(jax.random.PRNGKey(9), fresh_lp)
    gp = grow_params(LP, grown, params=deep.init_params(
        jax.random.PRNGKey(0), LP), positions=positions, fresh=fresh)
    for r, p in enumerate(sorted(positions)):
        _tree_eq(deep.extract_member(gp, grown, p),
                 deep.extract_member(fresh, fresh_lp, r))


def test_grow_params_rejects_mismatched_layout():
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    positions = LP.grow_positions(NEW_W, NEW_A)
    grown = LP.grow(NEW_W, NEW_A, positions)
    fresh = deep.init_params(jax.random.PRNGKey(9),
                             grown.subset(tuple(sorted(positions))))
    wrong = tuple(m for m in range(len(positions)))
    if set(wrong) != set(positions):
        with pytest.raises(ValueError, match="grow"):
            grow_params(LP, grown, params, wrong, fresh)


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(), lambda: sgd(momentum=0.9),
    lambda: adamw(weight_decay=0.01)])
def test_grow_state_zero_moments_survivors_bit_exact(make_opt):
    """Grown opt state: every newborn's moments are ZERO (what opt.init
    gives a fresh member), survivors' moments and the scalar count ride
    through bit-exact — for every params-shaped-subtree optimizer."""
    opt = make_opt()
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    state = opt.init(params)
    # fabricate non-zero moments so zeros are meaningful
    state = jax.tree.map(
        lambda x: x + 1 if x.ndim else x, state)
    positions = LP.grow_positions(NEW_W, NEW_A)
    grown = LP.grow(NEW_W, NEW_A, positions)
    gst = deep.grow_state(state, LP, grown, positions)
    assert int(gst["count"]) == int(state["count"])
    rest = tuple(m for m in range(grown.num_real)
                 if m not in set(positions))
    for key in state:
        if key == "count":
            continue
        for i, m in enumerate(rest):
            _tree_eq(deep.extract_member(gst[key], grown, m),
                     deep.extract_member(state[key], LP, i))
        for p in positions:
            assert _all_zero(deep.extract_member(gst[key], grown, p))


def test_grow_state_rejects_factored_adafactor():
    """Factored v_row/v_col reduce over the fused axis and cannot be
    spliced member-major — grow_state must fail LOUDLY (the driver
    carries adafactor momentum via compact_factored + grow_params)."""
    opt = adafactor()
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    state = opt.init(params)
    positions = LP.grow_positions(NEW_W, NEW_A)
    grown = LP.grow(NEW_W, NEW_A, positions)
    with pytest.raises(ValueError, match="grow_state"):
        deep.grow_state(state, LP, grown, positions)


def test_grow_orchestrator_end_to_end():
    """lifecycle.grow: params + opt state in one call, fresh init from the
    key, zero moments for the newborns."""
    opt = sgd(momentum=0.9)
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    state = jax.tree.map(lambda x: x + 1 if x.ndim else x,
                         opt.init(params))
    positions = LP.grow_positions(NEW_W, NEW_A)
    new_pop, new_p, new_st = grow(LP, params, state, NEW_W, NEW_A,
                                  positions, jax.random.PRNGKey(9))
    assert new_pop == LP.grow(NEW_W, NEW_A, positions)
    fresh_lp = new_pop.subset(tuple(sorted(positions)))
    fresh = deep.init_params(jax.random.PRNGKey(9), fresh_lp)
    for r, p in enumerate(sorted(positions)):
        _tree_eq(deep.extract_member(new_p, new_pop, p),
                 deep.extract_member(fresh, fresh_lp, r))
        assert _all_zero(deep.extract_member(new_st["mu"], new_pop, p))


# --------------------------------------------------------------------- #
# constant-size in-place refill                                         #
# --------------------------------------------------------------------- #

def _dup_slots(lp):
    """(slot, parent) for the fixture's duplicated (13, 5) architecture."""
    pair = [m for m in range(lp.num_real) if lp.widths[m] == (13, 5)]
    assert len(pair) == 2
    return pair


@pytest.mark.parametrize("gather", ["host", "device"])
def test_refill_params_in_place(gather):
    """One clone + one fresh refill: survivors' bytes untouched, the clone
    equals its parent bit-exact, the fresh slot equals its init — and the
    LAYOUT is the same object-equal dataclass (zero re-jit key)."""
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    slot_c, parent = _dup_slots(LP)
    slot_f = next(m for m in range(LP.num_real)
                  if m not in (slot_c, parent))
    fresh_lp = LayeredPopulation(
        LP.in_features, LP.out_features, (LP.widths[slot_f],),
        (LP.activations[slot_f],), block=LP.block)
    fresh = deep.init_params(jax.random.PRNGKey(9), fresh_lp)
    out = refill_params(LP, params, ((slot_c, parent), (slot_f, -1)),
                        fresh, gather=gather)
    for m in range(LP.num_real):
        if m in (slot_c, slot_f):
            continue
        _tree_eq(deep.extract_member(out, LP, m),
                 deep.extract_member(params, LP, m))
    _tree_eq(deep.extract_member(out, LP, slot_c),
             deep.extract_member(params, LP, parent))
    _tree_eq(deep.extract_member(out, LP, slot_f),
             deep.extract_member(fresh, fresh_lp, 0))


def test_refill_params_host_equals_device():
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    slot_c, parent = _dup_slots(LP)
    out_d = refill_params(LP, params, ((slot_c, parent),), gather="device")
    out_h = refill_params(LP, params, ((slot_c, parent),), gather="host")
    for a, b in zip(jax.tree.leaves(out_d), jax.tree.leaves(out_h)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_refill_params_validation():
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    slot_c, parent = _dup_slots(LP)
    with pytest.raises(ValueError, match="duplicate"):
        refill_params(LP, params, ((slot_c, parent), (slot_c, -1)))
    with pytest.raises(ValueError, match="surviving"):
        refill_params(LP, params, ((slot_c, parent), (parent, slot_c)))
    mismatch = next(m for m in range(LP.num_real)
                    if LP.widths[m] != LP.widths[slot_c])
    with pytest.raises(ValueError, match="arch"):
        refill_params(LP, params, ((slot_c, mismatch),))
    with pytest.raises(ValueError, match="fresh"):
        refill_params(LP, params, ((slot_c, -1),))
    with pytest.raises(ValueError, match="range"):
        refill_params(LP, params, ((LP.num_real, parent),))


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(), lambda: sgd(momentum=0.9),
    lambda: adamw(weight_decay=0.01), lambda: adafactor()])
def test_refill_state_zero_moments_all_optimizers(make_opt):
    """refill_state zeroes the refilled slots' member-major moments for
    ALL FOUR optimizers — including adafactor, where the unfactorable m
    is masked per member and the factored v_row/v_col (which mix members
    over the fused axis) pass through bit-identical, re-warming like any
    post-rung adafactor state."""
    opt = make_opt()
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    state = jax.tree.map(lambda x: x + 1 if x.ndim else x,
                         opt.init(params))
    slot_c, parent = _dup_slots(LP)
    out = refill_state(state, LP, (slot_c,))
    assert int(out["count"]) == int(state["count"])
    if "leaves" in state:                      # adafactor
        def leaf_dicts(st):
            return [d for d in jax.tree.leaves(
                st["leaves"], is_leaf=lambda x: isinstance(x, dict)
                and ("v" in x or "v_row" in x))]
        for d_in, d_out in zip(leaf_dicts(state), leaf_dicts(out)):
            for k in ("v_row", "v_col"):
                if k in d_in:
                    np.testing.assert_array_equal(np.asarray(d_in[k]),
                                                  np.asarray(d_out[k]))
        return
    for key in state:
        if key == "count":
            continue
        assert _all_zero(deep.extract_member(out[key], LP, slot_c))
        for m in range(LP.num_real):
            if m == slot_c:
                continue
            _tree_eq(deep.extract_member(out[key], LP, m),
                     deep.extract_member(state[key], LP, m))


def test_member_moment_mask_matches_refill_state():
    """The mask is the mechanism: multiplying a moment tree by the keep
    mask equals refill_state's member-major zeroing."""
    opt = sgd(momentum=0.9)
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    state = jax.tree.map(lambda x: x + 1 if x.ndim else x,
                         opt.init(params))
    slot_c, _ = _dup_slots(LP)
    mask = member_moment_mask(LP, (slot_c,))
    via_scale = scale_member_moments(state, deep.abstract_params(LP), mask)
    _tree_eq(via_scale, refill_state(state, LP, (slot_c,)))


def test_refill_keeps_chunk_jaxpr_identical():
    """Zero re-jit, asserted at the jaxpr level: the refilled tree traces
    to the EXACT same program as the pre-refill tree (same layout → same
    shapes, dtypes, and jaxpr), so the driver's cached chunk callable is
    a guaranteed compile-cache hit."""
    opt = sgd(momentum=0.9)
    lp = LP.shard_pad(1)
    params = deep.init_params(jax.random.PRNGKey(0), lp)
    state = opt.init(params)
    chunk = deep.make_population_train_step(lp, optimizer=opt,
                                            scan_steps=2)
    xs = jnp.zeros((2, 4, lp.in_features))
    ys = jnp.zeros((2, 4), jnp.int32)
    jaxpr_before = str(jax.make_jaxpr(chunk)(params, state, xs, ys, 0.01))
    slot_c, parent = _dup_slots(lp)
    params2 = refill_params(lp, params, ((slot_c, parent),))
    state2 = refill_state(state, lp, (slot_c,))
    jaxpr_after = str(jax.make_jaxpr(chunk)(params2, state2, xs, ys, 0.01))
    assert jaxpr_before == jaxpr_after


# --------------------------------------------------------------------- #
# search space + controller                                             #
# --------------------------------------------------------------------- #

def test_search_space_parse_grammar():
    sp = SearchSpace.parse("widths=64,32|16,8;acts=relu,tanh;lr=0.5..2;"
                           "momentum=0.6..0.95;wd=0.4..2.5;"
                           "lr_perturb=0.9,1.1;momentum_jitter=0.02")
    assert sp.widths == ((64, 32), (16, 8))
    assert sp.acts == ("relu", "tanh")
    assert sp.lr_scale == (0.5, 2.0)
    assert sp.momentum_range == (0.6, 0.95)
    assert sp.wd_scale == (0.4, 2.5)
    assert sp.lr_perturb == (0.9, 1.1)
    assert sp.momentum_jitter == 0.02
    assert SearchSpace.parse(None) == SearchSpace()
    for bad in ("lr=3..0.3", "nope=1", "lr=0.3", "widths"):
        with pytest.raises(ValueError):
            SearchSpace.parse(bad)


def test_search_space_init_vectors_match_historical_draws():
    """The default space reproduces the driver's historical hardcoded
    per-member recipe draws BIT-FOR-BIT (the PR-8/9 trajectory
    invariant): same keys, same transform order, same ranges."""
    sp = SearchSpace()
    seed, n0, lr, wd = 3, 8, 0.01, 0.001
    np.testing.assert_array_equal(
        np.asarray(sp.init_lr(seed, n0, lr)),
        np.asarray(jnp.exp(jax.random.uniform(
            jax.random.PRNGKey(seed + 1), (n0,),
            minval=jnp.log(lr * 0.3), maxval=jnp.log(lr * 3.0)))))
    np.testing.assert_array_equal(
        np.asarray(sp.init_momentum(seed, n0)),
        np.asarray(jax.random.uniform(jax.random.PRNGKey(seed + 2),
                                      (n0,), minval=0.5, maxval=0.99)))
    np.testing.assert_array_equal(
        np.asarray(sp.init_wd(seed, n0, wd)),
        np.asarray(jnp.exp(jax.random.uniform(
            jax.random.PRNGKey(seed + 3), (n0,),
            minval=jnp.log(wd * 0.3), maxval=jnp.log(wd * 3.0)))))


def test_controller_plan_deterministic_and_exploit():
    losses = np.array([0.1, 0.9, 0.2, 0.8, 0.3, 0.7])
    keep = [0, 2, 4]
    ids = np.arange(LP.num_real)
    c = RefillController(SearchSpace(), mode="pbt", seed=7)
    lr = np.linspace(0.001, 0.006, LP.num_real)
    p1 = c.plan(LP, losses, keep, ids, rung=1, next_id=6, base_lr=0.01,
                lr=lr)
    p2 = c.plan(LP, losses, keep, ids, rung=1, next_id=6, base_lr=0.01,
                lr=lr)
    assert p1 == p2                           # resume-deterministic
    p3 = c.plan(LP, losses, keep, ids, rung=2, next_id=6, base_lr=0.01,
                lr=lr)
    assert [m.slot for m in p3.members] == [m.slot for m in p1.members]
    assert p1.slots == tuple(s for s in range(LP.num_real)
                             if s not in keep)
    for j, m in enumerate(p1.members):
        assert m.member_id == 6 + j           # fresh ids, never reused
        assert m.birth_rung == 1
        assert m.widths == LP.widths[m.slot]  # pbt adopts the slot arch
        if m.origin == "exploit":
            assert m.parent_slot in keep
            assert LP.widths[m.parent_slot] == LP.widths[m.slot]
            assert m.lr is not None and m.lr != lr[m.parent_slot]
        else:
            assert m.parent_slot == -1 and m.parent_id == -1
    # the fixture's duplicated (13, 5) arch: whichever of the pair is
    # pruned exploits the surviving twin
    pair = _dup_slots(LP)
    pruned_twin = [m for m in p1.members if m.slot in pair]
    assert pruned_twin and all(m.origin == "exploit" for m in pruned_twin)


def test_controller_arch_mode_needs_widths_menu():
    with pytest.raises(ValueError, match="widths"):
        RefillController(SearchSpace(), mode="arch")
    sp = SearchSpace.parse("widths=8,4|6")
    c = RefillController(sp, mode="arch", seed=0)
    plan = c.plan(LP, np.arange(6.0), [0, 1, 2], np.arange(6), rung=1,
                  next_id=6, base_lr=0.01)
    assert all(m.origin == "fresh" and m.widths in sp.widths
               for m in plan.members)


def test_refill_member_ids_never_alias(tmp_path):
    """selection's duplicate-id guard: a refilled member aliasing a pruned
    seed's id is an error, fresh monotone ids are accepted."""
    from repro.core.selection import leaderboard, member_metrics
    losses = np.linspace(1.0, 2.0, LP.num_real)
    with pytest.raises(ValueError, match="alias"):
        leaderboard(LP, losses, member_ids=[0, 1, 2, 2, 4, 5])
    with pytest.raises(ValueError, match="entries"):
        member_metrics(LP, losses, member_ids=[0, 1])
    lineage = {7: (2, 1)}
    rows = member_metrics(LP, losses, member_ids=[0, 1, 2, 7, 4, 5],
                          lineage=lineage)
    by_id = {r["member"]: r for r in rows}
    assert by_id[7]["lineage"] == {"member": 7, "parent": 2,
                                   "born_rung": 1}
    assert by_id[0]["lineage"] == {"member": 0, "parent": -1,
                                   "born_rung": 0}
    top = leaderboard(LP, losses, member_ids=[0, 1, 2, 7, 4, 5],
                      lineage=lineage, k=6)
    assert all("lineage" in r for r in top)


# --------------------------------------------------------------------- #
# data plane: signature-gated retarget                                  #
# --------------------------------------------------------------------- #

def test_retarget_keeps_staging_on_matching_signature():
    from repro.data import Prefetcher, staging_signature

    def make_staging():
        return (np.empty((2, 4, 3), np.float32), np.empty((2, 4), np.int32))

    def produce(c, staging):
        sx, sy = staging
        sx[...] = c
        return np.array(sx)

    pf = Prefetcher(produce, 4, make_staging=make_staging)
    ids0 = tuple(id(a) for a in pf._staging[0] + pf._staging[1])
    assert pf.get(0)[0, 0, 0] == 0
    sig = staging_signature(make_staging())
    pf.retarget(produce, 4, make_staging=make_staging, signature=sig)
    # same signature → the SAME staging buffers, not reallocations
    assert tuple(id(a) for a in pf._staging[0] + pf._staging[1]) == ids0
    assert pf.get(0)[0, 0, 0] == 0
    pf.close()


def test_retarget_rebuilds_staging_on_mismatch_or_none():
    from repro.data import Prefetcher

    def make_a():
        return np.empty((2, 4), np.float32)

    def make_b():
        return np.empty((2, 3), np.float32)  # shrinking rung: new shapes

    def produce_a(c, staging):
        staging[...] = c
        return np.array(staging)

    pf = Prefetcher(produce_a, 4, make_staging=make_a)
    ids0 = tuple(id(a) for a in pf._staging)
    # mismatched signature → rebuild with the NEW factory
    pf.retarget(produce_a, 4, make_staging=make_b,
                signature=(((2, 3), np.dtype(np.float32).str),))
    assert tuple(id(a) for a in pf._staging) != ids0
    assert pf._staging[0].shape == (2, 3)
    assert pf.get(0).shape == (2, 3)
    # omitted signature → conservative rebuild even with matching shapes
    ids1 = tuple(id(a) for a in pf._staging)
    pf.retarget(produce_a, 4, make_staging=make_b)
    assert tuple(id(a) for a in pf._staging) != ids1
    pf.close()


# --------------------------------------------------------------------- #
# driver: --refill end to end                                           #
# --------------------------------------------------------------------- #

_BASE = ["--arch", "parallelmlp-10k", "--reduced", "--scan-steps", "2",
         "--samples", "256", "--population-acts", "relu,tanh",
         "--population-depths", "8,4;8,4;6;5;12,6;7;9;10",
         "--per-member-lr", "--ckpt-every", "2",
         "--halving", "4:0.5,8:0.5"]
_REFILL = _BASE + ["--refill", "pbt"]


def test_refill_driver_constant_size_zero_rejit(tmp_path, capsys):
    """--refill pbt: population size constant through both rungs, every
    rung boundary is a chunk-cache hit, the whole 3-segment ladder
    compiles ONE chunk program, and the leaderboard reports lineage."""
    from repro.launch.train import main
    params, lp = main(_REFILL + ["--steps", "12",
                                 "--ckpt-dir", str(tmp_path / "ck")])
    assert lp.num_real == 8                   # prune 4 → refill 4, twice
    out = capsys.readouterr().out
    assert out.count("cache-hit (zero re-jit)") == 2
    assert "1 chunk builds" in out
    assert "explored 16 models" in out
    assert "born r" in out


def test_refill_driver_survivor_prefix_matches_plain_halving(tmp_path):
    """Up to the first refill rung the refill run IS the plain-halving
    run: at the boundary, every survivor's params in the refilled layout
    equal the compacted no-refill run's, bit for bit."""
    from repro.checkpoint import load_meta, restore_population
    from repro.launch.train import main
    main(_REFILL + ["--steps", "6", "--ckpt-dir", str(tmp_path / "rf")])
    main(_BASE + ["--steps", "6", "--ckpt-dir", str(tmp_path / "off")])
    # both force-saved their post-rung state at the boundary step (3)
    p_rf, lp_rf, _ = restore_population(str(tmp_path / "rf"), step=3)
    p_off, lp_off, _ = restore_population(str(tmp_path / "off"), step=3)
    meta_rf, _ = load_meta(str(tmp_path / "rf"))
    meta_off, _ = load_meta(str(tmp_path / "off"))
    ids_rf = meta_rf["lifecycle"]["member_ids"]
    ids_off = meta_off["lifecycle"]["member_ids"]
    assert lp_rf.num_real == 8 and lp_off.num_real == 4
    # seed ids == seed slots at the first rung: survivors sit at ids_off
    for i, mid in enumerate(ids_off):
        assert mid in ids_rf
        _tree_eq(deep.extract_member(p_rf, lp_rf, ids_rf.index(mid)),
                 deep.extract_member(p_off, lp_off, i))
    # refilled members carry FRESH ids above every seed id
    assert sorted(set(ids_rf) - set(ids_off))[0] >= 8


def test_refill_driver_resume_mid_ladder_bit_exact(tmp_path):
    """Stop between refill rungs, --resume: identical params, lineage,
    and recipe-vector tails to the uninterrupted run (the controller rng
    folds (seed, rung), the grown vectors ride the checkpoint meta)."""
    from repro.checkpoint import load_meta
    from repro.launch.train import main
    main(_REFILL + ["--steps", "6", "--ckpt-dir", str(tmp_path / "ck")])
    meta_a, _ = load_meta(str(tmp_path / "ck"))
    assert meta_a["lifecycle"]["rung"] == 1
    assert meta_a["lifecycle"]["next_id"] == 12
    p_res, lp_res = main(_REFILL + ["--steps", "12", "--resume",
                                    "--ckpt-dir", str(tmp_path / "ck")])
    p_str, lp_str = main(_REFILL + ["--steps", "12",
                                    "--ckpt-dir", str(tmp_path / "ck2")])
    assert lp_res == lp_str
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), p_res, p_str)
    meta_r, _ = load_meta(str(tmp_path / "ck"))
    meta_s, _ = load_meta(str(tmp_path / "ck2"))
    assert meta_r["lifecycle"] == meta_s["lifecycle"]
    assert meta_r["lifecycle"]["lineage"]      # newborns recorded


def test_refill_driver_arch_mode_grows_layout(tmp_path, capsys):
    """--refill arch: pruned slots are replaced by freshly SAMPLED
    architectures spliced into a grown layout."""
    from repro.launch.train import main
    params, lp = main(_BASE + [
        "--refill", "arch",
        "--search-space", "widths=8,4|6|10,5;acts=relu,tanh",
        "--steps", "12", "--ckpt-dir", str(tmp_path / "ck")])
    assert lp.num_real == 8                   # 8 -4 +4, twice
    out = capsys.readouterr().out
    assert out.count("grew 4 sampled archs") == 2
    menu = {(8, 4), (6,), (10, 5)}
    assert set(lp.widths) <= menu | {(5,), (7,), (9,), (10,), (12, 6),
                                     (8, 4), (6,)}


_REFILL_4DEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
import jax, jax.numpy as jnp, numpy as np
from repro.core import deep
from repro.core.lifecycle import compact_params, grow_params
from repro.core.population import LayeredPopulation
from repro.distributed.sharding import population_shardings
from repro.launch.mesh import make_host_mesh
from repro.compat import set_mesh

assert len(jax.devices()) == 4
LP = LayeredPopulation(
    6, 3,
    widths=((7,), (13, 5), (64, 32, 16), (13, 5), (9,), (16, 8)),
    activations=("relu", ("tanh", "gelu"), ("mish", "sigmoid", "tanh"),
                 ("tanh", "gelu"), "relu", ("relu", "tanh")),
    block=8).sorted()
NEW_W, NEW_A = ((13, 5), (8,)), (("tanh", "gelu"), "relu")
mesh = make_host_mesh()
with set_mesh(mesh):
    lp = LP.shard_pad(4)
    params = jax.device_put(deep.init_params(jax.random.PRNGKey(0), lp),
                            population_shardings(lp, mesh))
    # grow the REAL prefix: compact off the pad, splice, re-pad
    real = tuple(range(LP.num_real))
    p_real = compact_params(lp, LP, params, real, gather="device")
    positions = LP.grow_positions(NEW_W, NEW_A)
    grown = LP.grow(NEW_W, NEW_A, positions)
    fresh_lp = grown.subset(tuple(sorted(positions)))
    fresh = deep.init_params(jax.random.PRNGKey(9), fresh_lp)
    gp = grow_params(LP, grown, p_real, positions, fresh, gather="device")
    pad = grown.shard_pad(4)
    gp_pad = jax.device_put(deep.pad_params(gp, grown, pad,
                                            jax.random.PRNGKey(1)),
                            population_shardings(pad, mesh))
    # the born-sharded splice round-trips bit-exact on the 4-device mesh
    host = grow_params(LP, grown, jax.tree.map(np.asarray, p_real),
                       positions, jax.tree.map(np.asarray, fresh),
                       gather="host")
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), gp, host)
    for l in jax.tree.leaves(gp_pad):
        assert len(l.sharding.device_set) == 4
print("OK")
"""


@pytest.mark.slow
def test_grow_splice_on_4_device_mesh(tmp_path):
    """Born-sharded splice: device-gather growth on the 4-fake-device
    mesh equals the host path bit-exact, and the re-padded tree lands
    sharded across all 4 devices."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    r = subprocess.run([sys.executable, "-c", _REFILL_4DEV],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
