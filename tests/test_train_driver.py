"""launch.train population driver: depth-spec parsing errors, the
TrainRunner-backed loop's checkpoint behaviour (no duplicate final save),
and resume with a DIFFERENT requested layout (the checkpoint's layout
wins)."""
import jax
import numpy as np
import pytest

import repro.checkpoint as ckpt_mod
from repro.launch.train import main, parse_depth_spec


def test_parse_depth_spec():
    assert parse_depth_spec("64,32,16;13,5;7") == ((64, 32, 16), (13, 5),
                                                   (7,))
    # stray separators / whitespace are tolerated, not members
    assert parse_depth_spec(" 8 ; ;4,2 ") == ((8,), (4, 2))


@pytest.mark.parametrize("bad", ["", ";", " ; ; "])
def test_parse_depth_spec_empty_groups(bad):
    with pytest.raises(ValueError):
        parse_depth_spec(bad)


@pytest.mark.parametrize("bad", ["a", "8,b;4", "8;;4,2,x", "1.5"])
def test_parse_depth_spec_bad_ints(bad):
    with pytest.raises(ValueError):
        parse_depth_spec(bad)


def _run(tmp_path, steps, ckpt_every, extra=()):
    return main(["--arch", "parallelmlp-10k", "--reduced",
                 "--steps", str(steps), "--ckpt-every", str(ckpt_every),
                 "--ckpt-dir", str(tmp_path / "ck"),
                 "--population-depths", "8,4;8,4;6;5",
                 "--population-acts", "relu,tanh",
                 "--scan-steps", "2", "--samples", "256", *extra])


def test_no_duplicate_final_checkpoint(tmp_path, monkeypatch):
    """When the cadence already saved the final step, the after-loop save
    must not write it a second time (the old loop saved twice whenever
    steps %% ckpt_every == 0)."""
    calls = []
    orig = ckpt_mod.save_population

    def counting(*a, **kw):
        calls.append(a[1])
        return orig(*a, **kw)

    monkeypatch.setattr(ckpt_mod, "save_population", counting)
    # scan=2, ckpt_every=2 → the runner cadence saves every chunk (steps
    # 1,3,5,7); the final step 7 is already on disk, so the after-loop
    # save_population must NOT fire (the old loop wrote it twice).
    _run(tmp_path, steps=8, ckpt_every=2)
    assert calls == [], calls
    saved = ckpt_mod.latest_steps(str(tmp_path / "ck"))
    assert saved and saved[-1] == 7

    # cadence that does NOT land on the final step → exactly ONE final save
    _run(tmp_path, steps=12, ckpt_every=8, extra=["--resume"])
    assert calls == [11], calls
    saved = ckpt_mod.latest_steps(str(tmp_path / "ck"))
    assert saved[-1] == 11


def test_resume_prefers_checkpoint_layout(tmp_path):
    params, lp1 = _run(tmp_path, steps=4, ckpt_every=2)
    assert ckpt_mod.latest_steps(str(tmp_path / "ck"))
    # resume with a DIFFERENT --population-depths: the checkpoint's layout
    # must win (params and layout travel together)
    params2, lp2 = main([
        "--arch", "parallelmlp-10k", "--reduced", "--steps", "6",
        "--ckpt-every", "2", "--ckpt-dir", str(tmp_path / "ck"),
        "--population-depths", "32,16,8;3", "--population-acts", "gelu",
        "--scan-steps", "2", "--samples", "256", "--resume"])
    assert lp2 == lp1
    assert jax.tree_util.tree_structure(params2) == \
        jax.tree_util.tree_structure(params)


def test_driver_fused_bf16_halving_with_cheap_rungs(tmp_path):
    """The fused kernel + bf16 policy + subsampled rung evals compose with
    the halving lifecycle end to end: the driver prunes on schedule, the
    final leaderboard eval runs the full split, and the checkpoint meta
    records the training policy."""
    params, lp = _run(
        tmp_path, steps=6, ckpt_every=2,
        extra=["--bd-impl", "fused", "--compute-dtype", "bfloat16",
               "--halving", "2:0.5,4:0.5", "--rung-eval-batches", "1"])
    assert lp.num_real == 1                      # 4 → 2 → 1 members
    assert all(p.dtype == np.float32             # f32 masters checkpointed
               for p in jax.tree.leaves(params))
    meta, _ = ckpt_mod.load_meta(str(tmp_path / "ck"))
    assert meta["train"] == {"compute_dtype": "bfloat16",
                             "bd_impl": "fused", "act_impl": "sliced"}


def test_resume_continues_training(tmp_path):
    """4 + 4 resumed steps equal 8 uninterrupted steps (step-indexed data,
    layout-carrying checkpoints)."""
    _run(tmp_path, steps=4, ckpt_every=4)
    p_resumed, lp = _run(tmp_path, steps=8, ckpt_every=4,
                         extra=["--resume"])
    p_straight, lp2 = main([
        "--arch", "parallelmlp-10k", "--reduced", "--steps", "8",
        "--ckpt-every", "0", "--ckpt-dir", str(tmp_path / "ck2"),
        "--population-depths", "8,4;8,4;6;5", "--population-acts",
        "relu,tanh", "--scan-steps", "2", "--samples", "256"])
    assert lp == lp2
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        p_resumed, p_straight)
