"""launch.train population driver: depth-spec parsing errors, the
TrainRunner-backed loop's checkpoint behaviour (no duplicate final save),
and resume with a DIFFERENT requested layout (the checkpoint's layout
wins)."""
import jax
import numpy as np
import pytest

import repro.checkpoint as ckpt_mod
from repro.launch.train import main, parse_depth_spec


def test_parse_depth_spec():
    assert parse_depth_spec("64,32,16;13,5;7") == ((64, 32, 16), (13, 5),
                                                   (7,))
    # stray separators / whitespace are tolerated, not members
    assert parse_depth_spec(" 8 ; ;4,2 ") == ((8,), (4, 2))


@pytest.mark.parametrize("bad", ["", ";", " ; ; "])
def test_parse_depth_spec_empty_groups(bad):
    with pytest.raises(ValueError):
        parse_depth_spec(bad)


@pytest.mark.parametrize("bad", ["a", "8,b;4", "8;;4,2,x", "1.5"])
def test_parse_depth_spec_bad_ints(bad):
    with pytest.raises(ValueError):
        parse_depth_spec(bad)


def _run(tmp_path, steps, ckpt_every, extra=()):
    return main(["--arch", "parallelmlp-10k", "--reduced",
                 "--steps", str(steps), "--ckpt-every", str(ckpt_every),
                 "--ckpt-dir", str(tmp_path / "ck"),
                 "--population-depths", "8,4;8,4;6;5",
                 "--population-acts", "relu,tanh",
                 "--scan-steps", "2", "--samples", "256", *extra])


def test_no_duplicate_final_checkpoint(tmp_path, monkeypatch):
    """When the cadence already saved the final step, the after-loop save
    must not write it a second time (the old loop saved twice whenever
    steps %% ckpt_every == 0)."""
    calls = []
    orig = ckpt_mod.save_population

    def counting(*a, **kw):
        calls.append(a[1])
        return orig(*a, **kw)

    monkeypatch.setattr(ckpt_mod, "save_population", counting)
    # scan=2, ckpt_every=2 → the runner cadence saves every chunk (steps
    # 1,3,5,7); the final step 7 is already on disk, so the after-loop
    # save_population must NOT fire (the old loop wrote it twice).
    _run(tmp_path, steps=8, ckpt_every=2)
    assert calls == [], calls
    saved = ckpt_mod.latest_steps(str(tmp_path / "ck"))
    assert saved and saved[-1] == 7

    # cadence that does NOT land on the final step → exactly ONE final save
    _run(tmp_path, steps=12, ckpt_every=8, extra=["--resume"])
    assert calls == [11], calls
    saved = ckpt_mod.latest_steps(str(tmp_path / "ck"))
    assert saved[-1] == 11


def test_resume_prefers_checkpoint_layout(tmp_path):
    params, lp1 = _run(tmp_path, steps=4, ckpt_every=2)
    assert ckpt_mod.latest_steps(str(tmp_path / "ck"))
    # resume with a DIFFERENT --population-depths: the checkpoint's layout
    # must win (params and layout travel together)
    params2, lp2 = main([
        "--arch", "parallelmlp-10k", "--reduced", "--steps", "6",
        "--ckpt-every", "2", "--ckpt-dir", str(tmp_path / "ck"),
        "--population-depths", "32,16,8;3", "--population-acts", "gelu",
        "--scan-steps", "2", "--samples", "256", "--resume"])
    assert lp2 == lp1
    assert jax.tree_util.tree_structure(params2) == \
        jax.tree_util.tree_structure(params)


def test_driver_fused_bf16_halving_with_cheap_rungs(tmp_path):
    """The fused kernel + bf16 policy + subsampled rung evals compose with
    the halving lifecycle end to end: the driver prunes on schedule, the
    final leaderboard eval runs the full split, and the checkpoint meta
    records the training policy."""
    params, lp = _run(
        tmp_path, steps=6, ckpt_every=2,
        extra=["--bd-impl", "fused", "--compute-dtype", "bfloat16",
               "--halving", "2:0.5,4:0.5", "--rung-eval-batches", "1"])
    assert lp.num_real == 1                      # 4 → 2 → 1 members
    assert all(p.dtype == np.float32             # f32 masters checkpointed
               for p in jax.tree.leaves(params))
    meta, _ = ckpt_mod.load_meta(str(tmp_path / "ck"))
    assert meta["train"]["compute_dtype"] == "bfloat16"
    assert meta["train"]["bd_impl"] == "fused"
    assert meta["train"]["act_impl"] == "sliced"
    # the stateful-optimizer engine records its config too (sgd default)
    assert meta["train"]["optimizer"]["name"] == "sgd"


def test_resume_optimizer_mismatch_fails_loudly(tmp_path):
    """--resume must refuse to reinterpret a stored optimizer state tree
    under a different config: optimizer name AND hyperparameter changes
    both fail with the stored-vs-requested diff; the matching config
    resumes."""
    _run(tmp_path, steps=4, ckpt_every=2, extra=["--optimizer", "momentum"])
    with pytest.raises(ValueError, match="optimizer config mismatch"):
        _run(tmp_path, steps=8, ckpt_every=2,
             extra=["--optimizer", "adamw", "--resume"])
    with pytest.raises(ValueError, match="momentum"):
        _run(tmp_path, steps=8, ckpt_every=2,
             extra=["--optimizer", "momentum", "--momentum", "0.5",
                    "--resume"])
    # flipping a per-member flag is a different recipe too
    with pytest.raises(ValueError, match="per_member_momentum"):
        _run(tmp_path, steps=8, ckpt_every=2,
             extra=["--optimizer", "momentum", "--per-member-momentum",
                    "--resume"])
    params, lp = _run(tmp_path, steps=8, ckpt_every=2,
                      extra=["--optimizer", "momentum", "--resume"])
    assert lp.num_real == 4


def test_driver_checkpoints_opt_state_and_records_config(tmp_path):
    """Population checkpoints carry the optimizer state under 'extra'
    (momentum buffers on disk, restorable) and the full optimizer record
    under meta['train']['optimizer']."""
    import numpy as _np
    _run(tmp_path, steps=4, ckpt_every=2,
         extra=["--optimizer", "momentum", "--grad-clip", "1.0"])
    meta, step = ckpt_mod.load_meta(str(tmp_path / "ck"))
    rec = meta["train"]["optimizer"]
    assert rec["name"] == "momentum" and rec["momentum"] == 0.9
    assert rec["grad_clip"] == 1.0
    import os
    data = _np.load(os.path.join(str(tmp_path / "ck"),
                                 f"step_{step:08d}", "arrays.npz"))
    mu_keys = [k for k in data.files if k.startswith("extra/mu/")]
    assert mu_keys and any(_np.any(data[k]) for k in mu_keys)
    assert "extra/count" in data.files


def test_stateful_resume_equals_straight_run(tmp_path):
    """4 + 4 resumed MOMENTUM steps equal 8 uninterrupted ones — the
    restored momentum buffers carry the trajectory, so equality proves
    the opt-state checkpoint round-trip."""
    mom = ["--optimizer", "momentum", "--per-member-momentum"]
    _run(tmp_path, steps=4, ckpt_every=4, extra=mom)
    p_resumed, lp = _run(tmp_path, steps=8, ckpt_every=4,
                         extra=mom + ["--resume"])
    p_straight, lp2 = main([
        "--arch", "parallelmlp-10k", "--reduced", "--steps", "8",
        "--ckpt-every", "0", "--ckpt-dir", str(tmp_path / "ck2"),
        "--population-depths", "8,4;8,4;6;5", "--population-acts",
        "relu,tanh", "--scan-steps", "2", "--samples", "256", *mom])
    assert lp == lp2
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        p_resumed, p_straight)
    # a different --seed would silently redraw the per-member vectors
    # beneath the restored moments — the config guard catches it
    with pytest.raises(ValueError, match="seed"):
        _run(tmp_path, steps=12, ckpt_every=4,
             extra=mom + ["--resume", "--seed", "1"])


def test_driver_flag_validation():
    with pytest.raises(SystemExit):
        main(["--arch", "parallelmlp-10k", "--reduced", "--steps", "1",
              "--per-member-momentum"])          # needs --optimizer momentum
    with pytest.raises(SystemExit):
        main(["--arch", "parallelmlp-10k", "--reduced", "--steps", "1",
              "--optimizer", "adamw", "--per-member-weight-decay"])  # wd=0
    with pytest.raises(SystemExit):   # would be silently ignored otherwise
        main(["--arch", "parallelmlp-10k", "--reduced", "--steps", "1",
              "--optimizer", "momentum", "--opt-state-dtype", "bfloat16"])


def test_resume_continues_training(tmp_path):
    """4 + 4 resumed steps equal 8 uninterrupted steps (step-indexed data,
    layout-carrying checkpoints)."""
    _run(tmp_path, steps=4, ckpt_every=4)
    p_resumed, lp = _run(tmp_path, steps=8, ckpt_every=4,
                         extra=["--resume"])
    p_straight, lp2 = main([
        "--arch", "parallelmlp-10k", "--reduced", "--steps", "8",
        "--ckpt-every", "0", "--ckpt-dir", str(tmp_path / "ck2"),
        "--population-depths", "8,4;8,4;6;5", "--population-acts",
        "relu,tanh", "--scan-steps", "2", "--samples", "256"])
    assert lp == lp2
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        p_resumed, p_straight)
