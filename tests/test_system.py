"""End-to-end behaviour: the paper's workflow (fused population training →
model selection) and the framework workflow (LM training improves loss;
serve generates; checkpoint/restart mid-LM-training)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Population, init_params, sgd_step
from repro.core.selection import evaluate_population, leaderboard, select_best
from repro.data import TabularTask, TokenTask


@pytest.mark.slow
def test_population_training_end_to_end():
    """Train a 160-member heterogeneous population on separable tabular
    data; the best member must beat 85% accuracy and the leaderboard must
    prefer nonlinear members (the data is tanh-warped)."""
    task = TabularTask(1024, 10, n_classes=2, seed=0)
    (xtr, ytr), (xte, yte) = task.split()
    pop = Population.grid(10, 2, range(1, 21), ("identity", "relu",
                                                "tanh", "gelu"),
                          repeats=2, block=8)
    params = init_params(jax.random.PRNGKey(0), pop)
    for step in range(120):
        xb, yb = task.batch(step, 128)
        params, loss, per = sgd_step(params, jnp.asarray(xb),
                                     jnp.asarray(yb), 0.1, pop)
    losses, accs = evaluate_population(params, pop, jnp.asarray(xte),
                                       jnp.asarray(yte))
    m, best = select_best(params, pop, losses)
    assert float(accs[m]) > 0.85, (m, float(accs[m]))
    rows = leaderboard(pop, losses, accs, k=10)
    assert rows[0]["loss"] <= rows[-1]["loss"]


@pytest.mark.slow
def test_lm_training_reduces_loss():
    from repro.configs import get_arch
    from repro.launch.cells import build_optimizer
    from repro.models import lm
    from repro.optim import constant_lr

    arch = get_arch("qwen3-1.7b", reduced=True)
    cfg = arch.model
    task = TokenTask(vocab=cfg.vocab, seed=0)
    params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = build_optimizer(arch)
    state = opt.init(params)
    step = jax.jit(lm.make_train_step(cfg, opt, constant_lr(3e-3)),
                   donate_argnums=(0, 1))
    losses = []
    for s in range(60):
        batch = task.batch(s, 8, 64)
        params, state, m = step(params, state,
                                jax.tree.map(jnp.asarray, batch),
                                jnp.asarray(s, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


@pytest.mark.slow
def test_train_driver_with_restart(tmp_path):
    """The launch.train driver path: run 30 steps with a checkpoint every
    10, kill at 25, resume, and match the uninterrupted run's loss curve."""
    from repro.configs import get_arch
    from repro.launch.cells import build_optimizer
    from repro.models import lm
    from repro.optim import constant_lr
    from repro.distributed import TrainRunner

    arch = get_arch("mamba2-780m", reduced=True)
    cfg = arch.model
    task = TokenTask(vocab=cfg.vocab, seed=0)
    opt = build_optimizer(arch)
    jit_step = jax.jit(lm.make_train_step(cfg, opt, constant_lr(1e-3)))

    def make_runner(ckpt_dir, failure_hook=None):
        params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
        state = {"params": params, "opt": opt.init(params)}

        def step_fn(st, s):
            batch = jax.tree.map(jnp.asarray, task.batch(s, 4, 32))
            p, o, m = jit_step(st["params"], st["opt"], batch,
                               jnp.asarray(s, jnp.int32))
            return {"params": p, "opt": o}, {"loss": float(m["loss"])}

        return TrainRunner(step_fn, state, ckpt_dir=ckpt_dir,
                           ckpt_every=10, failure_hook=failure_hook)

    ref = make_runner(str(tmp_path / "ref"))
    ref.run(30)

    boom = {25: True}

    def hook(s):
        if boom.pop(s, False):
            raise RuntimeError("chip gone")

    ft = make_runner(str(tmp_path / "ft"), hook)
    ft.run(30)
    ref_final = {s: m["loss"] for s, m in ref.metrics_log}
    ft_final = {s: m["loss"] for s, m in ft.metrics_log}
    assert abs(ref_final[29] - ft_final[29]) < 1e-4


@pytest.mark.slow
def test_serve_generates():
    from repro.configs import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import generate_lm

    arch = get_arch("hymba-1.5b", reduced=True)
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, arch.model.vocab, (2, 12)),
        jnp.int32)
    toks, stats = generate_lm(arch, prompts, 8, make_host_mesh())
    assert toks.shape == (2, 20)
    assert stats["tok_per_s"] > 0
