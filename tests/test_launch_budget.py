"""The per-step kernel-launch budget (launch/launch_count.py, DESIGN.md
§9): the fully fused population path costs exactly 2·(depth+1) Pallas
launches per train step — one per layer per direction — INDEPENDENT of
batch size.  Counted statically off the jaxpr (backend-independent, so the
CI interpret-mode count equals the TPU dispatch count); the scanned train
chunk multiplies the budget by its trip count and nothing else."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.activations import ACTIVATION_ORDER
from repro.core import deep
from repro.core.population import LayeredPopulation
from repro.launch.launch_count import (count_pallas_launches,
                                       fused_step_budget, phase_launches)

_WIDTHS = ((5, 3), (12, 9), (7,), (17, 9, 5), (8, 8),
           (5, 3), (3, 11, 2), (24, 16), (4,), (9, 9, 9))
LP = LayeredPopulation(6, 3, _WIDTHS, ACTIVATION_ORDER, block=8)


def _loss(x, y):
    def loss(p):
        return deep.fused_loss(p, x, y, LP, "bucketed", "fused",
                               "pallas")[0]
    return loss


@pytest.mark.parametrize("b", [9, 1024], ids=["small_b", "large_b"])
def test_fused_step_meets_budget(b):
    """fwd = depth+1 launches, bwd = depth+1 launches, at B=9 AND B=1024:
    the two-level-grid backward keeps the count batch-independent."""
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    x = jnp.zeros((b, LP.in_features))
    y = jnp.zeros((b,), jnp.int32)
    assert phase_launches(_loss(x, y), params) == fused_step_budget(LP.depth)


def test_budget_formula():
    assert fused_step_budget(1) == {"fwd": 2, "bwd": 2, "total": 4}
    assert fused_step_budget(3) == {"fwd": 4, "bwd": 4, "total": 8}


def test_xla_path_launches_nothing():
    """The einsum path is the zero baseline — it proves the counter counts
    pallas_call equations, not ops in general."""
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    x = jnp.zeros((9, LP.in_features))
    y = jnp.zeros((9,), jnp.int32)

    def loss(p):
        return deep.fused_loss(p, x, y, LP, "bucketed", "einsum")[0]
    assert phase_launches(loss, params) == {"fwd": 0, "bwd": 0, "total": 0}


def test_scan_chunk_is_budget_times_trip_count():
    """The scanned train chunk (make_population_train_step) is loop-
    weighted: scan_steps × the per-step budget, nothing hidden outside
    the scan body."""
    scan_steps = 4
    params = deep.init_params(jax.random.PRNGKey(0), LP)
    chunk = deep.make_population_train_step(
        LP, bd_impl="fused", act_impl="pallas", scan_steps=scan_steps,
        donate=False)
    xs = jnp.zeros((scan_steps, 9, LP.in_features))
    ys = jnp.zeros((scan_steps, 9), jnp.int32)
    n = count_pallas_launches(chunk, params, xs, ys, 0.05)
    assert n == scan_steps * fused_step_budget(LP.depth)["total"]
