"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
1-device CPU (the 512-device override belongs to repro.launch.dryrun ONLY)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end tests")
