"""The forward-only serving path (DESIGN.md §10): ``deep.forward(infer=
True)`` routes every fused impl to its residual-free twin and the output
projection through the one-launch infer head.  Three invariant families:

  numerics — infer logits match the einsum reference bit-for-tolerance
             across ALL activations, under the bf16 policy, on ragged and
             shard-padded layouts, with and without in-kernel log-softmax;
  budget   — exactly depth+1 Pallas launches, every one single-output (a
             2-output launch means a residual survived), at any batch;
  fillers  — shard_pad identity fillers can never leak into an ensemble
             reduction: the member axis is sliced to ``num_real`` before
             any mean/argmax, and explicit member sets naming a filler
             slot fail loudly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deep
from repro.core.activations import ACTIVATION_ORDER
from repro.core.ensemble import (ENSEMBLE_MODES, _validate_slots, best_member,
                                 disagreement, ensemble_predict,
                                 member_log_probs, real_slots, soft_vote)
from repro.core.population import LayeredPopulation, Population
from repro.core.selection import (evaluate_population, leaderboard,
                                  member_metrics)
from repro.launch.launch_count import (count_pallas_launches,
                                       fused_infer_budget, max_eqn_outputs)

# one member per activation — the reference sweep covers the whole table
_WIDTHS = ((5, 3), (12, 9), (7,), (17, 9, 5), (8, 8),
           (5, 3), (3, 11, 2), (24, 16), (4,), (9, 9, 9))
LP = LayeredPopulation(6, 3, _WIDTHS, ACTIVATION_ORDER, block=8)
B = 9


def _params(lp=LP, seed=0):
    return deep.init_params(jax.random.PRNGKey(seed), lp)


def _x(b=B, lp=LP):
    return jax.random.normal(jax.random.PRNGKey(1), (b, lp.in_features))


def _infer(params, x, lp=LP, **kw):
    return deep.forward(params, x, lp, bd_impl="fused", act_impl="pallas",
                        infer=True, **kw)


def _ref(params, x, lp=LP):
    return deep.forward(params, x, lp, bd_impl="einsum", act_impl="sliced")


# --------------------------------------------------------------------- #
# numerics                                                              #
# --------------------------------------------------------------------- #


def test_infer_matches_einsum_all_activations():
    """Forward-only fused path vs the pure-XLA reference, one member per
    activation in the table."""
    params, x = _params(), _x()
    np.testing.assert_allclose(_infer(params, x), _ref(params, x),
                               rtol=1e-5, atol=1e-6)


def test_infer_log_probs_in_kernel():
    """``log_probs=True`` folds the log-softmax into the head epilogue —
    same launch count, log-probabilities out."""
    params, x = _params(), _x()
    got = _infer(params, x, log_probs=True)
    want = jax.nn.log_softmax(_ref(params, x), axis=-1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.exp(got).sum(-1), 1.0, rtol=1e-5)


def test_infer_xla_head_routing():
    """``head_impl="xla"`` keeps the fused hidden stack but runs the
    bucketed output projection — numerics identical."""
    params, x = _params(), _x()
    np.testing.assert_allclose(_infer(params, x, head_impl="xla"),
                               _ref(params, x), rtol=1e-5, atol=1e-6)


def test_infer_rejects_unknown_head():
    with pytest.raises(ValueError, match="head_impl"):
        _infer(_params(), _x(), head_impl="nope")


def test_infer_bf16_policy():
    """The mixed-precision policy applies to the infer path too: bf16
    operands, f32 accumulators/bias/logits."""
    params, x = _params(), _x()
    got = _infer(params, x, compute_dtype="bfloat16")
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, _ref(params, x), rtol=1e-1, atol=5e-2)


@pytest.mark.parametrize("widths,acts", [
    (((24,), (13, 5), (17, 9), (32, 16, 8)),
     ("relu", "tanh", "gelu", "sigmoid")),
    (((3,), (3,), (31, 2)), ("identity", "mish", "elu")),
], ids=["mixed_depth", "tiny_ragged"])
def test_infer_ragged_layouts(widths, acts):
    lp = LayeredPopulation(7, 4, widths, acts, block=8)
    params = _params(lp)
    x = _x(11, lp)
    np.testing.assert_allclose(_infer(params, x, lp), _ref(params, x, lp),
                               rtol=1e-5, atol=1e-6)


def test_infer_on_shard_padded_layout():
    """The kernels compute filler slots like any member (real arrays, no
    special cases) — every slot, filler included, matches the reference."""
    lpp = LP.shard_pad(4)
    assert lpp.num_members > real_slots(lpp)
    params = _params(lpp)
    x = _x(lp=lpp)
    np.testing.assert_allclose(_infer(params, x, lpp), _ref(params, x, lpp),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# launch budget / no-residual assertion                                 #
# --------------------------------------------------------------------- #


def test_infer_budget_formula():
    assert fused_infer_budget(1) == {"fwd": 2, "total": 2}
    assert fused_infer_budget(3) == {"fwd": 4, "total": 4}


@pytest.mark.parametrize("b", [9, 1024], ids=["small_b", "large_b"])
def test_infer_budget_batch_independent(b):
    """depth+1 launches at B=9 AND B=1024 — and every pallas_call is
    single-output: no residual buffer exists anywhere in the program."""
    params = _params()
    x = jnp.zeros((b, LP.in_features))

    def fwd(p):
        return _infer(p, x)

    assert count_pallas_launches(fwd, params) == \
        fused_infer_budget(LP.depth)["total"]
    assert max_eqn_outputs(fwd, params) == 1


def test_train_reuse_keeps_residuals_alive():
    """The counter-example the infer path exists for: serving off the
    training step's VJP-forward leaves 2-output pallas_calls (logits +
    g' residual) in the jaxpr."""
    params = _params()
    x = _x()

    def reuse(p):
        return jax.vjp(lambda q: deep.forward(
            q, x, LP, bd_impl="fused", act_impl="pallas"), p)[0]

    assert max_eqn_outputs(reuse, params) == 2


def test_infer_log_probs_same_budget():
    params = _params()

    def fwd(p):
        return _infer(p, _x(), log_probs=True)

    assert count_pallas_launches(fwd, params) == \
        fused_infer_budget(LP.depth)["total"]


# --------------------------------------------------------------------- #
# ensemble reductions + the filler-exclusion invariant                  #
# --------------------------------------------------------------------- #


def _poisoned_padded_logits():
    """Real logits from the unpadded population, with filler rows set to
    a value that would wreck any reduction that sees them."""
    lpp = LP.shard_pad(4)
    nr = real_slots(lpp)
    logits = _infer(_params(), _x())
    assert logits.shape[1] == nr
    poison = jnp.full((B, lpp.num_members - nr, logits.shape[-1]), 1e30)
    return jnp.concatenate([logits, poison], axis=1), logits, lpp


def test_fillers_never_reach_reductions():
    """Regression for the shard_pad leak: reductions over the padded
    layout equal reductions over the unpadded one, poison and all."""
    lg_pad, lg, lpp = _poisoned_padded_logits()
    np.testing.assert_allclose(soft_vote(lg_pad, lpp), soft_vote(lg, LP),
                               rtol=1e-6)
    for k, v in disagreement(lg_pad, lpp).items():
        np.testing.assert_allclose(v, disagreement(lg, LP)[k], rtol=1e-5,
                                   err_msg=k)
        assert np.all(np.isfinite(np.asarray(v))), k
    out = ensemble_predict(lg_pad, lpp, "all", with_uncertainty=True)
    np.testing.assert_allclose(
        out["probs"], ensemble_predict(lg, LP, "all")["probs"], rtol=1e-6)


def test_filler_slots_fail_loudly():
    lg_pad, _, lpp = _poisoned_padded_logits()
    nr = real_slots(lpp)
    with pytest.raises(ValueError, match="filler"):
        best_member(lg_pad, lpp, nr)          # first filler slot
    with pytest.raises(ValueError, match="filler"):
        soft_vote(lg_pad, lpp, member_ids=[0, nr])
    with pytest.raises(ValueError, match="filler"):
        ensemble_predict(lg_pad, lpp, "topk", member_ids=[1, lpp.num_members - 1])
    with pytest.raises(ValueError, match="empty"):
        _validate_slots([], nr)


def test_ensemble_modes_and_shapes():
    logits = _infer(_params(), _x())
    assert ENSEMBLE_MODES == ("best1", "topk", "all")
    for mode, ids in (("best1", [3]), ("topk", [3, 0, 7]), ("all", None)):
        out = ensemble_predict(logits, LP, mode, member_ids=ids,
                               with_uncertainty=True)
        assert out["probs"].shape == (B, LP.out_features)
        assert out["pred"].shape == (B,)
        np.testing.assert_allclose(out["probs"].sum(-1), 1.0, rtol=1e-5)
        assert np.all(np.asarray(out["mutual_information"]) > -1e-5)
    with pytest.raises(ValueError, match="member_ids"):
        ensemble_predict(logits, LP, "best1")


def test_reductions_accept_logits_or_log_probs():
    """softmax is shift-invariant per row, so the head may emit either."""
    logits = _infer(_params(), _x())
    logp = member_log_probs(logits)
    np.testing.assert_allclose(soft_vote(logits, LP), soft_vote(logp, LP),
                               rtol=1e-5)
    np.testing.assert_allclose(best_member(logits, LP, 2),
                               best_member(logp, LP, 2), rtol=1e-5)


def test_weighted_soft_vote():
    logits = _infer(_params(), _x())
    # weight mass entirely on member 4 == best_member(4)
    np.testing.assert_allclose(
        soft_vote(logits, LP, member_ids=[4, 6], weights=[1.0, 0.0]),
        best_member(logits, LP, 4), rtol=1e-6)
    with pytest.raises(ValueError, match="weights"):
        soft_vote(logits, LP, member_ids=[4, 6], weights=[1.0])


# --------------------------------------------------------------------- #
# selection: infer-path eval routing, leaderboard sort_by, metrics rows #
# --------------------------------------------------------------------- #


def test_eval_routes_through_infer_path():
    """``evaluate_population(infer=True)`` scores on the serving kernels
    and must agree with the training-path eval to f32 tolerance."""
    params = _params()
    x = _x(64)
    y = jax.random.randint(jax.random.PRNGKey(2), (64,), 0, LP.out_features)
    l_ref, a_ref = evaluate_population(params, LP, x, y)
    l_inf, a_inf = evaluate_population(params, LP, x, y, bd_impl="fused",
                                       act_impl="pallas", infer=True)
    np.testing.assert_allclose(l_inf, l_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(a_inf, a_ref, rtol=1e-6)


def test_single_layer_population_rejects_infer():
    pop = Population(6, 3, (5, 9), ("relu", "tanh"), block=8)
    from repro.core import parallel_mlp as pmlp
    params = pmlp.init_params(jax.random.PRNGKey(0), pop)
    x = _x(8)
    y = jnp.zeros((8,), jnp.int32)
    with pytest.raises(ValueError, match="infer"):
        evaluate_population(params, pop, x, y, infer=True)


def test_leaderboard_sort_by_acc():
    losses = np.linspace(0.1, 1.0, LP.num_members)
    accs = np.linspace(0.0, 0.9, LP.num_members)   # best acc = last member
    by_loss = leaderboard(LP, losses, accs, k=3)
    by_acc = leaderboard(LP, losses, accs, k=3, sort_by="acc")
    assert by_loss[0]["slot"] == 0
    assert by_acc[0]["slot"] == LP.num_members - 1
    assert by_acc[0]["acc"] == pytest.approx(0.9)
    with pytest.raises(ValueError, match="acc"):
        leaderboard(LP, losses, None, sort_by="acc")
    with pytest.raises(ValueError, match="sort_by"):
        leaderboard(LP, losses, accs, sort_by="vibes")


def test_member_metrics_rows():
    lpp = LP.shard_pad(4)
    losses = np.arange(lpp.num_members, dtype=np.float64)
    rows = member_metrics(lpp, losses)
    assert len(rows) == real_slots(lpp)            # fillers excluded
    for m, row in enumerate(rows):
        assert row["slot"] == m
        assert row["depth"] == len(_WIDTHS[m])
        assert row["hidden"] == _WIDTHS[m]
        assert row["loss"] == pytest.approx(float(losses[m]))
