"""Population layout invariants (property-based).

The fused layout is the paper's core data structure; everything else trusts
these invariants: block alignment, disjoint member slices covering the
fused axis, padding masks, per-unit metadata consistency."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.activations import PAPER_TEN
from repro.core.population import Population

ACTS = st.sampled_from(sorted(PAPER_TEN))


@st.composite
def populations(draw):
    n = draw(st.integers(1, 12))
    sizes = draw(st.lists(st.integers(1, 70), min_size=n, max_size=n))
    acts = draw(st.lists(ACTS, min_size=n, max_size=n))
    block = draw(st.sampled_from([1, 2, 8, 128]))
    return Population(5, 3, tuple(sizes), tuple(acts), block=block)


@given(populations())
@settings(max_examples=60, deadline=None)
def test_layout_invariants(pop):
    # alignment
    assert pop.total_hidden % pop.block == 0
    assert all(s % pop.block == 0 for s in pop.padded_sizes)
    # offsets partition the axis
    assert pop.offsets[0] == 0 and pop.offsets[-1] == pop.total_hidden
    assert np.all(np.diff(pop.offsets) == pop.padded_sizes)
    # per-unit member ids: monotone, counts match padded sizes
    seg = pop.segment_ids
    assert seg.shape == (pop.total_hidden,)
    assert np.all(np.diff(seg) >= 0)
    counts = np.bincount(seg, minlength=pop.num_members)
    assert np.all(counts == pop.padded_sizes)
    # mask marks exactly the real units
    assert pop.hidden_mask.sum() == sum(pop.hidden_sizes)
    for m in range(pop.num_members):
        sl = pop.member_slice(m)
        assert np.all(pop.hidden_mask[sl] == 1.0)
        assert sl.stop - sl.start == pop.hidden_sizes[m]
    # block-level ids expand back to unit-level
    assert np.all(np.repeat(pop.block_segment_ids, pop.block) == seg)
    assert np.all(np.repeat(pop.block_act_ids, pop.block) == pop.act_ids)


@given(populations())
@settings(max_examples=30, deadline=None)
def test_sorted_is_permutation(pop):
    s = pop.sorted()
    assert sorted(zip(s.activations, s.hidden_sizes)) == \
        sorted(zip(pop.activations, pop.hidden_sizes))
    # sorted ⇒ act runs are at most one per activation
    names = [a for a, _, _ in s.act_runs]
    assert len(names) == len(set(names))


def test_grid_matches_paper():
    pop = Population.grid(100, 2, range(1, 101), PAPER_TEN, repeats=10,
                          block=128)
    assert pop.num_members == 10_000
    assert pop.total_hidden == 10_000 * 128     # all sizes pad to 128
    assert set(pop.hidden_sizes) == set(range(1, 101))


def test_validation_errors():
    with pytest.raises(ValueError):
        Population(4, 2, (3,), ("relu", "tanh"))
    with pytest.raises(ValueError):
        Population(4, 2, (0,), ("relu",))
    with pytest.raises(ValueError):
        Population(4, 2, (3,), ("nope",))
