"""Layered populations (the unified engine): heterogeneous member DEPTHS and
per-layer activations stay exactly independent under fused training, and the
block-diagonal Pallas kernel agrees with the einsum bucket loop — values and
gradients — over odd widths/buckets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.activations import ACTIVATIONS
from repro.core.deep import (BD_IMPLS, block_diag_matmul, extract_member,
                             forward, fused_loss, init_params, member_forward,
                             member_lr_tree, sgd_step)
from repro.core.population import LayeredPopulation

# widths (7,), (13, 5), (64, 32, 16) — the acceptance-criteria mix — plus a
# duplicate-shape member and per-layer activations.
LP = LayeredPopulation(
    in_features=6, out_features=3,
    widths=((7,), (13, 5), (64, 32, 16), (13, 5)),
    activations=("relu", ("tanh", "gelu"), ("mish", "sigmoid", "tanh"),
                 ("tanh", "gelu")),
    block=8)


def test_mixed_depth_forward_matches_members():
    params = init_params(jax.random.PRNGKey(0), LP)
    x = jax.random.normal(jax.random.PRNGKey(1), (9, 6))
    fused = forward(params, x, LP)
    for m in range(LP.num_members):
        want = member_forward(extract_member(params, LP, m), x)
        np.testing.assert_allclose(np.asarray(fused[:, m]), np.asarray(want),
                                   rtol=2e-5, atol=2e-5, err_msg=f"member {m}")


def _standalone_step(member, x, y, lr):
    acts = member["activations"]

    def loss(flat):
        w_in, b_in, mids, w_out, b_out = flat
        h = ACTIVATIONS[acts[0]](x @ w_in.T + b_in)
        for l, (w, b) in enumerate(mids):
            h = ACTIVATIONS[acts[l + 1]](h @ w.T + b)
        logits = h @ w_out.T + b_out
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    flat = (member["w_in"], member["b_in"],
            tuple((l["w"], l["b"]) for l in member["mid"]),
            member["w_out"], member["b_out"])
    g = jax.grad(loss)(flat)
    new = jax.tree.map(lambda p, gg: p - lr * gg, flat, g)
    return {"w_in": new[0], "b_in": new[1],
            "mid": [{"w": w, "b": b} for w, b in new[2]],
            "w_out": new[3], "b_out": new[4], "activations": acts}


@pytest.mark.parametrize("bd_impl", sorted(BD_IMPLS))
def test_heterogeneous_depth_training_is_independent(bd_impl):
    """Fused SGD over mixed depths + per-member learning rates equals every
    member trained standalone (acceptance criterion: ≤1e-4 after ≥3 steps)."""
    params = init_params(jax.random.PRNGKey(42), LP)
    members = [extract_member(params, LP, m) for m in range(LP.num_members)]
    lrs = jnp.array([0.05, 0.1, 0.02, 0.07])
    key = jax.random.PRNGKey(7)
    for _ in range(4):
        key, k1, k2 = jax.random.split(key, 3)
        x = jax.random.normal(k1, (16, 6))
        y = jax.random.randint(k2, (16,), 0, 3)
        params, _, _ = sgd_step(params, x, y, lrs, LP, "bucketed", bd_impl)
        members = [_standalone_step(mem, x, y, float(lrs[m]))
                   for m, mem in enumerate(members)]
    for m in range(LP.num_members):
        got, want = extract_member(params, LP, m), members[m]
        np.testing.assert_allclose(
            np.asarray(got["w_in"]), np.asarray(want["w_in"]),
            rtol=1e-4, atol=1e-5, err_msg=f"member {m} w_in")
        for l in range(len(want["mid"])):
            np.testing.assert_allclose(
                np.asarray(got["mid"][l]["w"]),
                np.asarray(want["mid"][l]["w"]), rtol=1e-4, atol=1e-5,
                err_msg=f"member {m} mid {l} — cross-member leak!")
            np.testing.assert_allclose(
                np.asarray(got["mid"][l]["b"]),
                np.asarray(want["mid"][l]["b"]), rtol=1e-4, atol=1e-5,
                err_msg=f"member {m} mid-bias {l}")
        np.testing.assert_allclose(
            np.asarray(got["w_out"]), np.asarray(want["w_out"]),
            rtol=1e-4, atol=1e-5, err_msg=f"member {m} w_out")


@pytest.mark.parametrize("widths,acts,block", [
    (((3,), (5, 2), (9, 7, 4)), ("relu", "tanh", "gelu"), 4),
    (((1, 1), (2, 3), (2, 3), (6, 6)), ("relu", "relu", "tanh", "mish"), 8),
    (((11, 3, 5), (4,), (11, 3, 5)), ("gelu", "sigmoid", "gelu"), 2),
])
def test_block_diag_pallas_matches_einsum(widths, acts, block):
    """block_diag_gemm (interpret) vs the einsum reference over odd widths
    and bucket patterns, values AND gradients, every mid layer."""
    lp = LayeredPopulation(5, 2, widths, acts, block=block)
    params = init_params(jax.random.PRNGKey(3), lp)
    x = jax.random.normal(jax.random.PRNGKey(4), (7, 5))
    for l in range(lp.depth - 1):
        w = params["mid"][l]["w"]
        h = jax.random.normal(jax.random.PRNGKey(10 + l),
                              (7, lp.layer_pop(l).total_hidden))
        ye = block_diag_matmul(h, w, lp, l, impl="einsum")
        yp = block_diag_matmul(h, w, lp, l, impl="pallas")
        np.testing.assert_allclose(np.asarray(ye), np.asarray(yp),
                                   rtol=1e-5, atol=1e-6)

        def loss(impl):
            return lambda hh, ww: (
                block_diag_matmul(hh, ww, lp, l, impl=impl) ** 2).sum()

        ge = jax.grad(loss("einsum"), argnums=(0, 1))(h, w)
        gp = jax.grad(loss("pallas"), argnums=(0, 1))(h, w)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6), ge, gp)
    # whole-network logits agreement (acceptance criterion: 1e-5)
    ye = forward(params, x, lp, bd_impl="einsum")
    yp = forward(params, x, lp, bd_impl="pallas")
    np.testing.assert_allclose(np.asarray(ye), np.asarray(yp),
                               rtol=1e-5, atol=1e-6)


def test_passthrough_slices_carry_final_activations():
    """A depth-1 member's slice in later layers is EXACTLY its layer-0
    activations (identity pass-through: no weight, no bias, no activation)."""
    lp = LayeredPopulation(4, 2, ((6,), (5, 5, 5)), ("tanh", "relu"), block=4)
    params = init_params(jax.random.PRNGKey(0), lp)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 4))
    p0 = lp.layer_pop(0)
    h0 = jnp.tanh(x @ params["w_in"][p0.member_slice(0)].T
                  + params["b_in"][p0.member_slice(0)])
    # run the fused stack up to the last hidden layer
    from repro.core.deep import _act
    h = _act(lp, 0, x @ params["w_in"].T + params["b_in"])
    for l in range(lp.depth - 1):
        h = block_diag_matmul(h, params["mid"][l]["w"], lp, l)
        h = h + params["mid"][l]["b"] * jnp.asarray(
            lp.active_unit_mask(l + 1), h.dtype)
        h = _act(lp, l + 1, h)
        sl = lp.layer_pop(l + 1).member_slice(0)
        np.testing.assert_allclose(np.asarray(h[:, sl]), np.asarray(h0),
                                   rtol=1e-6, atol=1e-6)
        # pass-through bias must be exactly zero (it is masked, not trained)
        np.testing.assert_array_equal(
            np.asarray(params["mid"][l]["b"][sl]), 0.0)


def test_member_lr_tree_structure():
    lrs = jnp.arange(1.0, LP.num_members + 1)
    tree = member_lr_tree(LP, lrs)
    params = init_params(jax.random.PRNGKey(0), LP)
    assert (jax.tree_util.tree_structure(tree)
            == jax.tree_util.tree_structure(params))
    # every scale leaf broadcasts against its parameter leaf
    jax.tree.map(lambda p, s: np.broadcast_shapes(p.shape, s.shape),
                 params, tree)


def test_validation():
    with pytest.raises(ValueError):  # activation list length != depth
        LayeredPopulation(4, 2, ((3, 4),), (("relu",),))
    with pytest.raises(ValueError):  # unknown activation
        LayeredPopulation(4, 2, ((3,),), ("nope",))
    with pytest.raises(ValueError):  # empty widths
        LayeredPopulation(4, 2, ((),), ("relu",))


def test_grid_and_sorted_bucket_compaction():
    lp = LayeredPopulation.grid(
        8, 2, [(4,), (4, 4), (6, 3)], ("relu", "tanh"), repeats=2, block=4)
    assert lp.num_members == 12
    # sorted: equal (depth, padded widths, act) members are contiguous →
    # bucket count per projection is bounded by the number of shape classes
    for l in range(lp.depth - 1):
        assert len(lp.proj_buckets(l)) <= 6


def test_optimizer_per_member_lr_tree():
    """The optim layer takes a member_lr_tree as ``lr`` directly."""
    from repro.optim import apply_updates, sgd
    params = init_params(jax.random.PRNGKey(0), LP)
    opt = sgd()
    state = opt.init(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 6))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 3)
    grads = jax.grad(lambda p: fused_loss(p, x, y, LP)[0])(params)
    lrs = jnp.full((LP.num_members,), 0.05)
    upd_tree, _ = opt.update(grads, state, params, member_lr_tree(LP, lrs))
    upd_scal, _ = opt.update(grads, state, params, 0.05)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7),
        upd_tree, upd_scal)
    apply_updates(params, upd_tree)  # structure round-trips


def test_population_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore_population, save_population
    params = init_params(jax.random.PRNGKey(0), LP)
    save_population(str(tmp_path), 5, params, LP)
    got, lp2, step = restore_population(str(tmp_path))
    assert step == 5 and lp2 == LP
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, got)


def test_selection_over_layered_population():
    from repro.core.selection import (evaluate_population, leaderboard,
                                      select_best)
    params = init_params(jax.random.PRNGKey(0), LP)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 6))
    y = jax.random.randint(jax.random.PRNGKey(2), (32,), 0, 3)
    losses, accs = evaluate_population(params, LP, x, y)
    assert losses.shape == (LP.num_members,)
    m, best = select_best(params, LP, losses)
    want = member_forward(best, x)
    fused = forward(params, x, LP)
    np.testing.assert_allclose(np.asarray(fused[:, m]), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    rows = leaderboard(LP, losses, accs, k=3)
    assert rows[0]["loss"] <= rows[-1]["loss"]
    assert isinstance(rows[0]["hidden"], tuple)
