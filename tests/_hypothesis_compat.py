"""Hypothesis, or per-test skip stubs when it isn't installed.

A module-level ``pytest.importorskip("hypothesis")`` would skip the WHOLE
test module, silently disabling the plain (non-property) tests that live
alongside the ``@given`` ones.  Importing ``given/settings/st`` from here
instead keeps plain tests running everywhere: with hypothesis absent,
``@given`` marks just that test skipped, and ``st`` is an inert stub that
absorbs strategy construction at decoration time.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # partial-deps container: skip only the property tests
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)
