"""Fused loss head (kernels/loss_head.py, DESIGN.md §9): output projection
(M3) + per-member softmax cross-entropy + dlogits in ONE Pallas pass — the
logits never reach HBM.  Interpret-mode equivalence vs the XLA reference
(m3 + log_softmax) for the per-member losses and the h/W_out/b_out
gradients, including non-uniform per-member cotangents, multi-batch-tile
shapes, and bf16 operands."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.activations import ACTIVATION_ORDER
from repro.core.deep import init_params
from repro.core.m3 import (FUSED_LOSS_IMPLS, LOSS_IMPLS, m3, m3_loss_head)
from repro.core.population import LayeredPopulation

_WIDTHS = ((5, 3), (12, 9), (7,), (17, 9, 5), (8, 8),
           (5, 3), (3, 11, 2), (24, 16), (4,), (9, 9, 9))
LP = LayeredPopulation(6, 3, _WIDTHS, ACTIVATION_ORDER, block=8)
POP = LP.layer_pop(LP.depth - 1)


def _head_inputs(b=9, seed=0):
    params = init_params(jax.random.PRNGKey(seed), LP)
    h = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (b, POP.total_hidden))
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (b,), 0,
                           LP.out_features)
    return h, params["w_out"], params["b_out"], y


def _per_ref(h, w2, b2, y):
    """The pre-§9 XLA loss head: M3 logits in HBM + log_softmax + NLL."""
    logits = m3(h, w2, POP, impl="bucketed") + b2
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None, None], axis=2)[:, :, 0]
    return nll.mean(axis=0)


def test_registry():
    assert set(LOSS_IMPLS) == {"xla", "fused"}
    assert "fused" in FUSED_LOSS_IMPLS


def test_per_member_loss_matches_xla():
    h, w2, b2, y = _head_inputs()
    pe = _per_ref(h, w2, b2, y)
    pf = m3_loss_head(h, w2, b2, y, POP)
    assert pf.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(pe), np.asarray(pf),
                               rtol=1e-5, atol=1e-6)


def test_grads_match_xla():
    h, w2, b2, y = _head_inputs(seed=3)
    ge = jax.grad(lambda *a: _per_ref(*a, y).sum(),
                  argnums=(0, 1, 2))(h, w2, b2)
    gf = jax.grad(lambda *a: m3_loss_head(*a, y, POP).sum(),
                  argnums=(0, 1, 2))(h, w2, b2)
    for a, f in zip(ge, gf):
        assert f.shape == a.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(f),
                                   rtol=1e-4, atol=1e-6)


def test_grads_with_per_member_cotangent():
    """A NON-uniform per-member cotangent (the real caller is per.sum(),
    but selection/halving code may weight members): the backward must
    scale each member's dlogits tile by ITS d_per, not a shared scalar."""
    h, w2, b2, y = _head_inputs(seed=5)
    wts = jnp.linspace(0.1, 2.0, POP.num_members)
    ge = jax.grad(lambda *a: (_per_ref(*a, y) * wts).sum(),
                  argnums=(0, 1, 2))(h, w2, b2)
    gf = jax.grad(lambda *a: (m3_loss_head(*a, y, POP) * wts).sum(),
                  argnums=(0, 1, 2))(h, w2, b2)
    for a, f in zip(ge, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(f),
                                   rtol=1e-4, atol=1e-6)


def test_multi_batch_tile():
    """B > block_b (300 → 3 padded batch tiles at block_b=128): per-member
    means and grads stay exact — pad rows carry target −1 and contribute
    zero loss / zero dlogits."""
    h, w2, b2, y = _head_inputs(b=300, seed=7)
    pe = _per_ref(h, w2, b2, y)
    pf = m3_loss_head(h, w2, b2, y, POP)
    np.testing.assert_allclose(np.asarray(pe), np.asarray(pf),
                               rtol=1e-5, atol=1e-6)
    ge = jax.grad(lambda hh: _per_ref(hh, w2, b2, y).sum())(h)
    gf = jax.grad(lambda hh: m3_loss_head(hh, w2, b2, y, POP).sum())(h)
    np.testing.assert_allclose(np.asarray(ge), np.asarray(gf),
                               rtol=1e-4, atol=1e-6)


def test_bf16_operands_f32_loss():
    """bf16 h/W_out tiles: the logits accumulator, softmax math, and the
    per-member losses stay f32; the result tracks the XLA bf16 reference
    within bf16 tolerance."""
    h, w2, b2, y = _head_inputs(seed=9)
    h16, w16 = h.astype(jnp.bfloat16), w2.astype(jnp.bfloat16)
    pe = _per_ref(h16, w16, b2, y)
    pf = m3_loss_head(h16, w16, b2, y, POP)
    assert pf.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(pe, dtype=np.float32),
                               np.asarray(pf), rtol=5e-2, atol=5e-2)
