"""Static HLO cost model: must agree with XLA on loop-free dot flops and
apply trip-count weighting that XLA's cost_analysis lacks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import cost_analysis_dict
from repro.launch.hlo_cost import analyze, parse_module
from repro.launch.hlo_stats import collective_stats, shape_bytes


def test_loop_free_dot_matches_xla():
    def f(a, b):
        return (a @ b).sum()

    A = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    B = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = jax.jit(f).lower(A, B).compile()
    mine = analyze(c.as_text())
    want = 2 * 64 * 128 * 32
    assert abs(mine["flops"] - want) / want < 0.01
    xla = cost_analysis_dict(c)["flops"]
    assert abs(mine["flops"] - xla) / xla < 0.05


def test_scan_trip_count_weighting():
    L = 7

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y.sum()

    X = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    W = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(X, W).compile()
    mine = analyze(c.as_text())
    want = 2 * 32 * 64 * 64 * L
    assert abs(mine["flops"] - want) / want < 0.01
    assert any(n == L for _, n in mine["loops"])
    # XLA undercounts exactly by the trip count
    xla = cost_analysis_dict(c)["flops"]
    assert mine["flops"] > xla * (L - 1) / 2


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    X = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    W = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = jax.jit(f).lower(X, W).compile()
    mine = analyze(c.as_text())
    want = 2 * 16 * 32 * 32 * 15
    assert abs(mine["flops"] - want) / want < 0.01


def test_shape_bytes_parser():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(f32[4,4], s32[2])") == 64 + 8
    assert shape_bytes("pred[]") == 1
    assert shape_bytes("token[]") == 0


def test_parse_module_handles_wrapped_lines():
    hlo = """HloModule test
%comp (a: (s32[],
  f32[4,4])) -> f32[4,4] {
  %p = (s32[], /*index=1*/
    f32[4,4]) parameter(0)
  ROOT %r = f32[4,4]{1,0} get-tuple-element(%p), index=1
}
ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %x.1 = f32[4,4]{1,0} parameter(0)
  ROOT %c = f32[4,4]{1,0} copy(%x.1)
}
"""
    comps = parse_module(hlo)
    assert "comp" in comps and "main" in comps
    assert any(op.opcode == "copy" for op in comps["main"])


def test_collective_stats_regex():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(%x), dimensions={0}
  %ar = bf16[256]{0} all-reduce(%y), to_apply=%add
  %rs = f32[32]{0} reduce-scatter(%z), dimensions={0}
  %a2a = f32[16,16]{1,0} all-to-all(%w), dimensions={0}
  %cp = f32[8]{0} collective-permute(%v), source_target_pairs={{0,1}}
"""
    st = collective_stats(hlo)
    assert st["counts"] == {"all-gather": 1, "all-reduce": 1,
                            "reduce-scatter": 1, "all-to-all": 1,
                            "collective-permute": 1}
    assert st["per_device_bytes"]["all-gather"] == 64 * 128 * 4
    assert st["per_device_bytes"]["all-reduce"] == 256 * 2
