"""The dry-run machinery itself: cell construction → lower → compile →
loop-aware profile, exercised on reduced configs over a multi-device
subprocess mesh (the same path the 512-device production dry-run takes)."""
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax
from repro.compat import make_mesh
from repro.configs import get_arch
from repro.configs.base import ShapeSpec
from repro.launch.cells import make_cell
from repro.launch.hlo_cost import analyze

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

CASES = [
    ("qwen3-1.7b", ShapeSpec("train_4k", "train", 32, 8)),
    ("mamba2-780m", ShapeSpec("decode_32k", "decode", 64, 8)),
    ("deepseek-moe-16b", ShapeSpec("prefill_32k", "prefill", 64, 4)),
]
for aid, sh in CASES:
    arch = get_arch(aid, reduced=True)
    cell = make_cell(arch, sh, mesh)
    compiled = cell.lower().compile()
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    prof = analyze(compiled.as_text())
    assert prof["flops"] > 0, (aid, sh.name)
    assert prof["hbm_bytes"] > 0
    if sh.kind == "train":
        # the layer scan must be trip-count weighted (fwd + bwd loops)
        assert any(n >= 3 for _, n in prof["loops"]), prof["loops"]
    # stats must be JSON-serialisable (the sweep writes them per cell)
    json.dumps({"coll": prof["collective_bytes"],
                "counts": prof["collective_count"]})
    print("OK", aid, sh.name, int(prof["flops"]))
print("ALL OK")
"""


def test_cells_lower_compile_profile_subprocess():
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"},
                       cwd="/root/repo", timeout=900)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    assert "ALL OK" in r.stdout
