"""Attention: chunked==dense (exactness of the online-softmax path), SWA
masks, GQA grouping, decode-vs-full consistency, RoPE/M-RoPE equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import (AttnConfig, attn_init, attention, decode_step,
                                init_kv_cache)
from repro.nn.rope import apply_mrope, apply_rope


def _setup(window=None, qk_norm=False, kv=2, rope="rope"):
    cfg = AttnConfig(d_model=48, n_heads=6, n_kv_heads=kv, d_head=8,
                     qk_norm=qk_norm, sliding_window=window, rope_kind=rope,
                     mrope_sections=(1, 1, 2))
    p, _ = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, p


@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("seq", [8, 33, 64])
def test_chunked_equals_dense(window, seq):
    cfg, p = _setup(window=window)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, seq, 48))
    pos = jnp.broadcast_to(jnp.arange(seq)[None], (2, seq))
    dense = attention(p, cfg, x, pos, chunked_threshold=10**9)
    chunked = attention(p, cfg, x, pos, chunked_threshold=1)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_swa_masks_far_tokens():
    """With window w, output at position t must not depend on tokens < t-w+1."""
    cfg, p = _setup(window=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 48))
    pos = jnp.arange(16)[None]
    base = attention(p, cfg, x, pos)
    x2 = x.at[0, 0].add(100.0)       # perturb token 0
    out2 = attention(p, cfg, x2, pos)
    # positions >= 4 cannot see token 0
    np.testing.assert_allclose(np.asarray(out2[0, 4:]),
                               np.asarray(base[0, 4:]), atol=1e-4)
    assert not np.allclose(np.asarray(out2[0, 1]), np.asarray(base[0, 1]))


def test_causality():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 12, 48))
    pos = jnp.arange(12)[None]
    base = attention(p, cfg, x, pos)
    x2 = x.at[0, -1].add(10.0)       # future token
    out2 = attention(p, cfg, x2, pos)
    np.testing.assert_allclose(np.asarray(out2[0, :-1]),
                               np.asarray(base[0, :-1]), atol=1e-4)


@pytest.mark.parametrize("window", [None, 5])
def test_decode_matches_full(window):
    cfg, p = _setup(window=window, qk_norm=True)
    S = 12
    x = jax.random.normal(jax.random.PRNGKey(3), (2, S, 48))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (2, S))
    full = attention(p, cfg, x, pos)
    cache = init_kv_cache(cfg, 2, S, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = decode_step(p, cfg, x[:, t:t + 1], cache,
                               jnp.full((2,), t, jnp.int32))
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
    if window:   # ring buffer bounded by the window
        assert cache["k"].shape[1] == window


def test_mrope_equals_rope_for_text():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    q1, k1 = apply_rope(q, k, pos, 8, 1e4)
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    q2, k2 = apply_mrope(q, k, pos3, 8, 1e4, sections=(1, 1, 2))
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), atol=1e-6)


def test_gqa_grouping_matches_repeated_kv():
    """GQA grouped einsum == repeating KV to query heads."""
    cfg, p = _setup(kv=2)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 10, 48))
    pos = jnp.arange(10)[None]
    from repro.nn.attention import qkv_project, attend_dense, out_project, _apply_pos_emb
    q, k, v = qkv_project(p, cfg, x)
    q, k = _apply_pos_emb(cfg, q, k, pos)
    o1 = attend_dense(q, k, v, pos[0], pos[0], causal=True, window=None,
                      scale=cfg.d_head ** -0.5)
    # repeat kv to full head count, run as MHA (group dim 1)
    g = cfg.n_heads // cfg.n_kv_heads
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    qr = q.reshape(1, 10, cfg.n_heads, 1, cfg.d_head)
    o2 = attend_dense(qr, kr, vr, pos[0], pos[0], causal=True, window=None,
                      scale=cfg.d_head ** -0.5)
    np.testing.assert_allclose(
        np.asarray(o1.reshape(1, 10, -1)), np.asarray(o2.reshape(1, 10, -1)),
        rtol=2e-5, atol=2e-5)
