"""Fault-tolerance demo: train with injected chip failures + a straggler
watchdog, then verify the restarted run matches an uninterrupted one.

    PYTHONPATH=src python examples/fault_tolerant_train.py

What this shows (the 1000-node design, exercised on one host):
  * async checkpoints every K steps (off the step path),
  * ANY step failure → automatic restore of the last committed checkpoint
    and bitwise replay (step-indexed data),
  * straggler policy raising after N slow steps → same restart path,
  * gradient compression for the cross-pod axis (int8 + error feedback).
"""
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import TokenTask
from repro.distributed import StragglerPolicy, TrainRunner
from repro.distributed.compression import quantize_int8
from repro.launch.cells import build_optimizer
from repro.models import lm
from repro.optim import constant_lr


def main():
    arch = get_arch("qwen3-1.7b", reduced=True)
    cfg = arch.model
    task = TokenTask(vocab=cfg.vocab, seed=0)
    opt = build_optimizer(arch)
    jit_step = jax.jit(lm.make_train_step(cfg, opt, constant_lr(1e-3)))

    def fresh_state():
        params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
        return {"params": params, "opt": opt.init(params)}

    def step_fn(state, s):
        batch = jax.tree.map(jnp.asarray, task.batch(s, 4, 64))
        p, o, m = jit_step(state["params"], state["opt"], batch,
                           jnp.asarray(s, jnp.int32))
        return {"params": p, "opt": o}, {"loss": float(m["loss"])}

    for d in ("/tmp/ft_ref", "/tmp/ft_demo"):
        shutil.rmtree(d, ignore_errors=True)

    print("reference run (no failures), 40 steps…")
    ref = TrainRunner(step_fn, fresh_state(), ckpt_dir="/tmp/ft_ref",
                      ckpt_every=10)
    ref.run(40)

    print("failure run: chips die at steps 17 and 33…")
    boom = {17: True, 33: True}

    def failure(s):
        if boom.pop(s, False):
            raise RuntimeError(f"simulated ICI link failure @ step {s}")

    runner = TrainRunner(
        step_fn, fresh_state(), ckpt_dir="/tmp/ft_demo", ckpt_every=10,
        failure_hook=failure,
        straggler=StragglerPolicy(timeout_s=120.0, max_strikes=3))
    t0 = time.time()
    runner.run(40)
    print(f"  finished with {runner.restarts} restarts "
          f"in {time.time()-t0:.1f}s")

    ref_loss = dict((s, m["loss"]) for s, m in ref.metrics_log)[39]
    ft_loss = dict((s, m["loss"]) for s, m in runner.metrics_log)[39]
    print(f"  final loss  ref={ref_loss:.6f}  restarted={ft_loss:.6f}  "
          f"(identical: {abs(ref_loss - ft_loss) < 1e-6})")

    print("\nint8 gradient compression (cross-pod DCI all-reduce):")
    g = jnp.asarray(np.random.default_rng(0).normal(0, 0.02, (4096,)),
                    jnp.float32)
    err = jnp.zeros_like(g)
    q, scale, err = quantize_int8(g, err)
    rec = q.astype(jnp.float32) * scale
    rel = float(jnp.linalg.norm(rec - g) / jnp.linalg.norm(g))
    print(f"  wire bytes: {q.nbytes + 4} vs f32 {g.nbytes} "
          f"(4.0x less); rel err {rel:.4f} "
          f"(error feedback carries the residual forward)")


if __name__ == "__main__":
    main()
