"""Slot-refill search over a fused MLP population — DESIGN.md §13, live.

    PYTHONPATH=src python examples/search_population.py

Plain successive halving prunes losers and lets the freed device slots
idle.  This demo runs the same rung ladder with the PR-10 search
controller instead: at every rung the losers are pruned AND their slots
are refilled in place — PBT-style exploit clones of the best survivors
with perturbed learning rates, plus fresh inits where no same-arch
survivor exists.  Because the population size (and therefore the fused
layout) never changes, every rung boundary is one jitted gather/scatter
and the WHOLE ladder trains through a single compiled chunk — the demo
counts the compiles to prove it, then prints the lineage-annotated
leaderboard ("born r2 of 3" = cloned from member 3 at rung 2).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LayeredPopulation, lifecycle
from repro.core import deep as deep_mod
from repro.core.selection import evaluate_population
from repro.data import TabularTask
from repro.search import RefillController, SearchSpace

SEED = 0
STEPS, BATCH = 48, 128
LADDER = "12:0.5,24:0.5,36:0.5"


def main():
    lp = LayeredPopulation.grid(
        16, 2, [(32, 16), (24, 12), (16, 8), (8, 4)], ("relu", "tanh"),
        repeats=2, block=8)
    n0 = lp.num_members
    space = SearchSpace.parse("lr=0.3..3;lr_perturb=0.8,1.25")
    controller = RefillController(space, mode="pbt", seed=SEED)
    print(f"population: {lp.describe()}")
    print(f"ladder: {LADDER} over {STEPS} steps, space: lr=0.3..3\n")

    task = TabularTask(4096, 16, n_classes=2, seed=SEED)
    _, (xte, yte) = task.split()
    xte, yte = jnp.asarray(xte), jnp.asarray(yte)

    params = deep_mod.init_params(jax.random.PRNGKey(SEED), lp)
    # per-member lr drawn from the SAME space the controller perturbs
    lr = np.array(space.init_lr(SEED, n0, 0.05))
    member_ids = np.arange(n0)
    lineage = {int(i): (-1, 0) for i in member_ids}   # id -> (parent, rung)
    next_id = n0

    # ONE chunk for the whole run: per-member lr rides as a runtime
    # argument, so refilled recipes re-enter the same executable
    compiles = 0
    schedule = lifecycle.HalvingSchedule.parse(LADDER)
    chunk = None
    pos = 0
    t0 = time.perf_counter()
    for rung, (end, frac) in enumerate(schedule.segments(STEPS), start=1):
        if chunk is None:       # compiled exactly once — layout never changes
            chunk = deep_mod.make_population_train_step(
                lp, scan_steps=end - pos, donate=False)
            compiles += 1
        bs = [task.batch(s, BATCH) for s in range(pos, end)]
        xs = jnp.asarray(np.stack([x for x, _ in bs]))
        ys = jnp.asarray(np.stack([y for _, y in bs]))
        params = chunk(params, xs, ys, jnp.asarray(lr))[0]
        pos = end
        if frac is None:
            continue
        losses, _ = evaluate_population(params, lp, xte, yte)
        keep = lifecycle.survivors(np.asarray(losses), frac)
        plan = controller.plan(lp, np.asarray(losses), keep, member_ids,
                               rung=rung, next_id=next_id, base_lr=0.05,
                               lr=lr)
        fresh = None
        if plan.fresh_members:
            fresh = deep_mod.init_params(
                jax.random.fold_in(jax.random.PRNGKey(SEED), 5000 + rung),
                LayeredPopulation(
                    lp.in_features, lp.out_features,
                    tuple(f.widths for f in plan.fresh_members),
                    tuple(f.acts for f in plan.fresh_members),
                    block=lp.block))
        params = lifecycle.refill_params(lp, params, plan.assignments, fresh)
        member_ids = member_ids.copy()
        for f in plan.members:
            member_ids[f.slot] = f.member_id
            lineage[f.member_id] = (f.parent_id, f.birth_rung)
            lr[f.slot] = f.lr
        next_id += len(plan.members)
        n_ex = sum(1 for f in plan.members if f.origin == "exploit")
        print(f"rung {rung} @ step {end}: pruned {n0 - len(keep)}, "
              f"refilled {len(plan.members)} ({n_ex} exploit clones, "
              f"{len(plan.members) - n_ex} fresh) — layout unchanged")
    dt = time.perf_counter() - t0

    losses, _ = evaluate_population(params, lp, xte, yte)
    order = np.argsort(np.asarray(losses))[:5]
    print(f"\nexplored {next_id} models in {dt:.1f}s "
          f"({next_id / dt:.1f} models/s) with {compiles} chunk compile")
    print("\nrank  loss     id   lr      born")
    for r, slot in enumerate(order, start=1):
        mid = int(member_ids[slot])
        parent, born = lineage[mid]
        origin = ("seed" if born == 0 else
                  f"r{born} of {parent}" if parent >= 0 else f"r{born} fresh")
        print(f"{r:4d}  {float(losses[slot]):.4f}  {mid:3d}  "
              f"{lr[slot]:.4f}  {origin}")
    assert compiles == 1, "constant-size refill must never re-compile"


if __name__ == "__main__":
    main()
