"""Batched serving example — prefill a prompt batch, stream greedy decode.

    PYTHONPATH=src python examples/serve_batch.py --arch hymba-1.5b \
        --batch 8 --prompt-len 64 --tokens 64

Exercises the full inference stack on the reduced family config: ring-buffer
SWA caches, SSM state carry (hybrid archs), in-place donated cache updates —
the same serve_step the decode_32k / long_500k dry-run cells lower at
production scale.
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCH_IDS, get_arch
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate_encdec, generate_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b", choices=ALL_ARCH_IDS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    arch = get_arch(args.arch, reduced=True)
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    if arch.kind == "population":
        raise SystemExit("population archs don't decode; see quickstart.py")
    if arch.kind == "encdec":
        frames = jnp.asarray(rng.normal(
            0, 1, (args.batch, args.prompt_len, arch.model.d_model)),
            jnp.float32)
        toks, stats = generate_encdec(arch, frames, args.tokens, mesh)
    else:
        prompts = jnp.asarray(rng.integers(
            0, arch.model.vocab, (args.batch, args.prompt_len)), jnp.int32)
        toks, stats = generate_lm(arch, prompts, args.tokens, mesh,
                                  greedy=not args.sample,
                                  temperature=args.temperature)
    print(f"arch={args.arch} (reduced family config)")
    print(f"prefill {stats['prefill_s']*1e3:.0f} ms | "
          f"decode {stats['decode_s']:.2f} s | "
          f"{stats['tok_per_s']:.1f} tok/s")
    print("first two sequences (last 16 tokens):")
    print(np.asarray(toks[:2, -16:]))


if __name__ == "__main__":
    main()
