"""End-to-end LM training driver — a ~100M-parameter qwen3-family model for
a few hundred steps on synthetic token data (deliverable (b): the training
kind's end-to-end example).

    PYTHONPATH=src python examples/train_lm.py --steps 300

Uses the SAME code path as the full-scale launcher (repro.launch.train):
jit'd microbatched train step, AdamW, warmup-cosine, async checkpointing,
restart-safe data. On a pod the only difference is the mesh.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.checkpoint import AsyncCheckpointer
from repro.data import TokenTask
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.lm import LayerSpec, LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.ffn import FFNConfig
from repro.optim import adamw, warmup_cosine


def config_100m() -> LMConfig:
    """qwen3-family, ~110M params: 12L d768 12H(kv4) ff2304 qk-norm tied."""
    return LMConfig(
        name="qwen3-100m", vocab=32_000, d_model=768,
        layers=tuple(LayerSpec("attn", "dense", 0) for _ in range(12)),
        attn=AttnConfig(d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
                        qk_norm=True, rope_theta=1e6),
        ffn=FFNConfig(768, 2304, act="silu", gated=True),
        norm="rmsnorm", tie_embeddings=True, param_dtype="float32",
        remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--num-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    mesh = make_host_mesh()
    with set_mesh(mesh):
        params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"model: {cfg.name}  params={n/1e6:.1f}M  "
              f"mesh={dict(mesh.shape)}")
        opt = adamw(weight_decay=0.1)
        opt_state = opt.init(params)
        lr_fn = warmup_cosine(args.lr, warmup_steps=20,
                              total_steps=args.steps)
        step_fn = jax.jit(
            lm.make_train_step(cfg, opt, lr_fn, num_micro=args.num_micro),
            donate_argnums=(0, 1))
        task = TokenTask(vocab=cfg.vocab, seed=0)
        ckpt = AsyncCheckpointer(args.ckpt_dir, every=100)

        tokens_per_step = args.batch * args.seq
        t0 = time.time()
        for s in range(args.steps):
            batch = jax.tree.map(jnp.asarray,
                                 task.batch(s, args.batch, args.seq))
            params, opt_state, m = step_fn(params, opt_state, batch,
                                           jnp.asarray(s, jnp.int32))
            ckpt.maybe_save(s, {"params": params, "opt": opt_state})
            if s % 20 == 0 or s == args.steps - 1:
                dt = time.time() - t0
                tps = tokens_per_step * (s + 1) / dt
                print(f"step {s:4d}  loss {float(m['loss']):.4f}  "
                      f"lr {float(m['lr']):.2e}  "
                      f"grad_norm {float(m['grad_norm']):.2f}  "
                      f"{tps:.0f} tok/s")
        ckpt.wait()
        print(f"done in {time.time()-t0:.1f}s; checkpoints: {ckpt.saved}")


if __name__ == "__main__":
    main()
