"""Quickstart — the paper's experiment end-to-end at laptop scale.

Trains a heterogeneous population of MLPs (hidden sizes × all ten paper
activations, fused into ONE network) on a synthetic tabular task, then does
model selection over the population — the workflow the paper's speedup
enables (§5: "perform model selection in the large pool of trained MLPs").

    PYTHONPATH=src python examples/quickstart.py [--members 400] [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import Population, init_params, sgd_step
from repro.core.activations import PAPER_TEN
from repro.core.selection import evaluate_population, leaderboard, select_best
from repro.data import TabularTask


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--members", type=int, default=400)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--features", type=int, default=20)
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--per-member-lr", action="store_true",
                    help="paper §7: every member gets its own step size")
    args = ap.parse_args()

    task = TabularTask(args.samples, args.features, n_classes=2, seed=0)
    (xtr, ytr), (xte, yte) = task.split()
    hidden = range(1, args.members // (10 * 2) + 1)
    pop = Population.grid(args.features, 2, hidden, PAPER_TEN,
                          repeats=2, block=8)
    print(f"fused population: {pop.describe()}")

    params = init_params(jax.random.PRNGKey(0), pop)
    lr = args.lr
    if args.per_member_lr:
        key = jax.random.PRNGKey(1)
        lr = jnp.exp(jax.random.uniform(key, (pop.num_members,),
                                        minval=jnp.log(0.01),
                                        maxval=jnp.log(0.3)))
        print("per-member learning rates in [0.01, 0.3]")

    t0 = time.time()
    for step in range(args.steps):
        xb, yb = task.batch(step, args.batch)
        params, loss, per = sgd_step(params, jnp.asarray(xb),
                                     jnp.asarray(yb), lr, pop)
        if step % 50 == 0:
            print(f"step {step:4d}  mean member loss "
                  f"{float(loss)/pop.num_members:.4f}")
    dt = time.time() - t0
    print(f"trained {pop.num_members} MLPs × {args.steps} steps "
          f"in {dt:.1f}s ({pop.num_members * args.steps / dt:.0f} "
          f"model-steps/s)")

    losses, accs = evaluate_population(params, pop, jnp.asarray(xte),
                                       jnp.asarray(yte))
    m, best = select_best(params, pop, losses)
    print(f"\nbest member #{m}: hidden={pop.hidden_sizes[m]} "
          f"act={pop.activations[m]} loss={float(losses[m]):.4f} "
          f"acc={float(accs[m]):.3f}")
    print("\nleaderboard:")
    for row in leaderboard(pop, losses, accs, k=10):
        print(f"  #{row['rank']:2d} member {row['member']:4d} "
              f"hidden={row['hidden']:3d} {row['activation']:11s} "
              f"loss={row['loss']:.4f} acc={row['acc']:.3f}")


if __name__ == "__main__":
    main()
