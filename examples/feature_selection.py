"""Feature selection with ParallelMLPs — the paper's §7 future work, live.

    PYTHONPATH=src python examples/feature_selection.py

Builds a task where only 3 of 16 features carry signal, trains a fused
population of identical MLPs under random per-member feature masks
(projected SGD keeps masked features provably inert), then reads feature
importance out of the population by loss-gap attribution.  One training
run answers "which features matter AND which architecture works" —
the search the paper's speedup makes affordable."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Population, init_params
from repro.core.feature_selection import (apply_masks, feature_importance,
                                          masked_sgd_step, random_masks)
from repro.core.parallel_mlp import forward, member_losses


def main():
    rng = np.random.default_rng(0)
    F, N, signal = 16, 4096, (2, 7, 11)
    x = rng.normal(0, 1, (N, F)).astype(np.float32)
    logit = x[:, signal[0]] + 0.8 * x[:, signal[1]] - 1.2 * x[:, signal[2]]
    y = (logit > 0).astype(np.int32)
    print(f"task: {F} features, signal carried by {signal}")

    P = 64
    pop = Population(F, 2, tuple([8] * P), ("relu",) * P, block=8)
    masks = random_masks(jax.random.PRNGKey(1), P, F, keep_prob=0.5,
                         always_full=4)
    params = init_params(jax.random.PRNGKey(0), pop)
    xb, yb = jnp.asarray(x), jnp.asarray(y)
    for step in range(150):
        i = (step * 256) % (N - 256)
        params, loss, per = masked_sgd_step(
            params, xb[i:i + 256], yb[i:i + 256], 0.2, pop, masks)
        if step % 50 == 0:
            print(f"step {step:3d}  mean loss {float(loss)/P:.4f}")

    logits = forward(apply_masks(params, pop, masks), xb, pop)
    per = member_losses(logits, yb, "classification")
    imp = feature_importance(pop, masks, per)
    order = np.argsort(imp)[::-1]
    print("\nfeature importance (loss-gap attribution):")
    for f in order[:6]:
        tag = " <-- signal" if f in signal else ""
        print(f"  feature {f:2d}: {imp[f]:+.4f}{tag}")
    found = set(order[:3].tolist())
    print(f"\ntop-3 = {sorted(found)}  (true signal = {sorted(signal)}; "
          f"recovered {len(found & set(signal))}/3)")


if __name__ == "__main__":
    main()
