"""Search layer on top of the halving lifecycle (DESIGN.md §13): a
declarative hyperparameter/architecture space (``space.SearchSpace``) and
the slot-refill controller (``controller.RefillController``) that turns
successive halving into a constant-FLOP PBT-style search."""
from repro.search.controller import RefillController, RefillMember, RefillPlan
from repro.search.space import DEFAULT_SPACE, SearchSpace

__all__ = ["DEFAULT_SPACE", "RefillController", "RefillMember",
           "RefillPlan", "SearchSpace"]
