"""Declarative search-space spec (DESIGN.md §13).

One frozen dataclass owns every range the population search draws from —
the per-member recipe ranges that used to be hardcoded in the driver
(``launch/train.py``'s lr/momentum/weight-decay vectors) plus an optional
architecture menu for refill sampling.  The driver and the refill
controller both read THIS object, so the seed recipes and every later
explore/sample step come from one declaration.

Spec grammar (``--search-space``), ';'-separated ``key=value`` fields, any
subset (unlisted keys keep the defaults below, which reproduce the
driver's historical ranges bit-for-bit)::

    widths=64,32|16,8|24   # arch menu: options by '|', layer widths by ','
    acts=relu,tanh         # activation menu (per member, cycled at init)
    lr=0.3..3              # log-uniform MULTIPLIER range around the base lr
    momentum=0.5..0.99     # uniform absolute range
    wd=0.3..3              # log-uniform multiplier range around base decay
    lr_perturb=0.8,1.25    # PBT explore: multiply by one of these
    momentum_jitter=0.05   # PBT explore: additive uniform jitter half-width

The ``init_*`` methods reproduce the driver's exact jax.random draws —
same key derivation (``PRNGKey(seed+1..3)``), same transform order — so a
run configured through the default space is BIT-IDENTICAL to the pre-space
driver (the PR-8/9 trajectory invariant).  The ``sample_*``/``perturb_*``
methods are the controller's numpy-side draws for refilled members.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _parse_range(text: str, what: str) -> tuple:
    lo, sep, hi = text.partition("..")
    if not sep:
        raise ValueError(f"search space: {what} wants 'LO..HI', got {text!r}")
    lo, hi = float(lo), float(hi)
    if not lo < hi:
        raise ValueError(f"search space: {what} range {lo}..{hi} is empty")
    return (lo, hi)


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    widths: tuple = ()                    # arch menu; () = refill keeps slot archs
    acts: tuple = ("relu",)
    lr_scale: tuple = (0.3, 3.0)          # log-uniform, × base lr
    momentum_range: tuple = (0.5, 0.99)   # uniform, absolute
    wd_scale: tuple = (0.3, 3.0)          # log-uniform, × base decay
    lr_perturb: tuple = (0.8, 1.25)       # explore multipliers
    momentum_jitter: float = 0.05         # explore additive half-width

    @classmethod
    def parse(cls, spec: str | None) -> "SearchSpace":
        """``"widths=8,4|6;acts=relu,tanh;lr=0.3..3"`` → SearchSpace.
        ``None``/empty → the default space (the driver's historical
        ranges)."""
        kw = {}
        for field in (spec or "").split(";"):
            field = field.strip()
            if not field:
                continue
            key, sep, val = field.partition("=")
            key, val = key.strip(), val.strip()
            if not sep or not val:
                raise ValueError(f"search space: field {field!r} wants "
                                 "'key=value'")
            if key == "widths":
                kw["widths"] = tuple(
                    tuple(int(w) for w in opt.split(","))
                    for opt in val.split("|"))
            elif key == "acts":
                kw["acts"] = tuple(a.strip() for a in val.split(","))
            elif key == "lr":
                kw["lr_scale"] = _parse_range(val, "lr")
            elif key == "momentum":
                kw["momentum_range"] = _parse_range(val, "momentum")
            elif key == "wd":
                kw["wd_scale"] = _parse_range(val, "wd")
            elif key == "lr_perturb":
                kw["lr_perturb"] = tuple(float(f) for f in val.split(","))
            elif key == "momentum_jitter":
                kw["momentum_jitter"] = float(val)
            else:
                raise ValueError(f"search space: unknown key {key!r} "
                                 "(widths, acts, lr, momentum, wd, "
                                 "lr_perturb, momentum_jitter)")
        return cls(**kw)

    # ---- seed recipe vectors: the driver's exact historical draws ---- #

    def init_lr(self, seed: int, n0: int, base_lr: float):
        """Per-member lr vector over the ORIGINAL population — the exact
        draw ``--per-member-lr`` has always made (PRNGKey(seed+1),
        exp∘uniform in log space), parameterised by this space's range."""
        import jax
        import jax.numpy as jnp
        lo, hi = self.lr_scale
        return jnp.exp(jax.random.uniform(
            jax.random.PRNGKey(seed + 1), (n0,),
            minval=jnp.log(base_lr * lo), maxval=jnp.log(base_lr * hi)))

    def init_momentum(self, seed: int, n0: int):
        import jax
        lo, hi = self.momentum_range
        return jax.random.uniform(jax.random.PRNGKey(seed + 2), (n0,),
                                  minval=lo, maxval=hi)

    def init_wd(self, seed: int, n0: int, base_wd: float):
        import jax
        import jax.numpy as jnp
        lo, hi = self.wd_scale
        return jnp.exp(jax.random.uniform(
            jax.random.PRNGKey(seed + 3), (n0,),
            minval=jnp.log(base_wd * lo), maxval=jnp.log(base_wd * hi)))

    # ---- controller-side draws (numpy rng, deterministic per rung) --- #

    def sample_arch(self, rng: np.random.Generator) -> tuple:
        """One (widths, act) draw from the menu; needs a non-empty
        ``widths`` menu (PBT-mode refill never calls this — it keeps the
        slot's architecture)."""
        if not self.widths:
            raise ValueError("search space: no 'widths' menu to sample "
                             "architectures from")
        w = self.widths[int(rng.integers(len(self.widths)))]
        return w, self.acts[int(rng.integers(len(self.acts)))]

    def sample_lr(self, rng: np.random.Generator, base_lr: float) -> float:
        lo, hi = self.lr_scale
        return float(base_lr * np.exp(rng.uniform(np.log(lo), np.log(hi))))

    def sample_momentum(self, rng: np.random.Generator) -> float:
        lo, hi = self.momentum_range
        return float(rng.uniform(lo, hi))

    def sample_wd(self, rng: np.random.Generator, base_wd: float) -> float:
        lo, hi = self.wd_scale
        return float(base_wd * np.exp(rng.uniform(np.log(lo), np.log(hi))))

    def perturb_lr(self, rng: np.random.Generator, lr: float,
                   base_lr: float) -> float:
        """PBT explore: multiply by one of ``lr_perturb``, clipped back
        into the space's absolute range so a long exploit chain cannot
        walk out of the declared search space."""
        lo, hi = self.lr_scale
        out = lr * float(rng.choice(self.lr_perturb))
        return float(np.clip(out, base_lr * lo, base_lr * hi))

    def perturb_momentum(self, rng: np.random.Generator, m: float) -> float:
        lo, hi = self.momentum_range
        j = self.momentum_jitter
        return float(np.clip(m + rng.uniform(-j, j), lo, hi))

    def perturb_wd(self, rng: np.random.Generator, wd: float,
                   base_wd: float) -> float:
        lo, hi = self.wd_scale
        out = wd * float(rng.choice(self.lr_perturb))
        return float(np.clip(out, base_wd * lo, base_wd * hi))


DEFAULT_SPACE = SearchSpace()
