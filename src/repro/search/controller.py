"""Slot-refill search controller (DESIGN.md §13).

At each halving rung boundary the lifecycle prunes the losing members; the
controller decides what to put back in the freed slots:

  exploit — clone a surviving member whose architecture matches the slot's
            (truncation selection: a uniform draw from the best
            ``exploit_frac`` of the matching survivors), then EXPLORE by
            perturbing the clone's training recipe (lr always; momentum /
            weight decay when those per-member vectors are active).  The
            clone adopts the slot's architecture — that is what keeps the
            layout, and therefore every compiled program, unchanged.
  fresh   — when no survivor shares the slot's architecture (or in
            ``mode="arch"``), initialise a brand-new member: recipe
            sampled from the space, parameters from a fresh PRNG draw,
            architecture either the slot's own (PBT mode) or sampled from
            the space's ``widths`` menu (arch mode — the driver then grows
            the layout instead of scattering in place).

Decisions are a pure function of (seed, rung, losses, layout): the rng is
``np.random.default_rng([seed, 777, rung])``, so a resumed run re-plans a
rung identically to the run that first crossed it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.search.space import SearchSpace


@dataclasses.dataclass(frozen=True)
class RefillMember:
    """One refilled slot: where it goes, where it came from, and the
    recipe it trains with.  ``parent_slot`` is a REAL slot index in the
    pre-refill layout (-1 = fresh init); ``parent_id``/``member_id`` are
    ORIGINAL member ids (the lineage the leaderboard reports);
    ``momentum``/``wd`` are None when that per-member vector is off."""
    slot: int
    parent_slot: int
    parent_id: int
    member_id: int
    birth_rung: int
    widths: tuple
    acts: tuple
    lr: float | None
    momentum: float | None
    wd: float | None
    origin: str                      # "exploit" | "fresh"


@dataclasses.dataclass(frozen=True)
class RefillPlan:
    members: tuple                   # RefillMember, ascending slot order

    @property
    def assignments(self) -> tuple:
        """``(slot, parent_slot)`` pairs for ``lifecycle.refill_params``
        (-1 parents mean the fresh tree)."""
        return tuple((m.slot, m.parent_slot) for m in self.members)

    @property
    def slots(self) -> tuple:
        return tuple(m.slot for m in self.members)

    @property
    def fresh_members(self) -> tuple:
        """The fresh-init members, ascending slot order — the order their
        params tree is built in (``refill_params``'s ``fresh`` contract)."""
        return tuple(m for m in self.members if m.parent_slot < 0)


class RefillController:
    """Plans rung-boundary refills against a :class:`SearchSpace`.

    ``mode="pbt"`` holds the population size constant and every refill
    adopts its slot's architecture (the zero-re-jit path);
    ``mode="arch"`` resamples architectures from the space's menu, so the
    driver takes the grow-layout path instead."""

    def __init__(self, space: SearchSpace, mode: str = "pbt",
                 seed: int = 0, exploit_frac: float = 0.5):
        if mode not in ("pbt", "arch"):
            raise ValueError(f"refill mode {mode!r} (want 'pbt' or 'arch')")
        if mode == "arch" and not space.widths:
            raise ValueError("refill mode 'arch' needs a search space with "
                             "a 'widths' menu")
        self.space = space
        self.mode = mode
        self.seed = int(seed)
        self.exploit_frac = float(exploit_frac)

    def plan(self, lp, losses, keep, member_ids, rung: int, next_id: int,
             base_lr: float, lr=None, momentum=None, wd=None,
             base_momentum: float = 0.9, base_wd: float = 0.0) -> RefillPlan:
        """Decide every freed slot's replacement.

        ``lp`` is the PRE-prune layout, ``losses`` the rung eval over its
        real slots, ``keep`` the survivor slot indices, ``member_ids`` the
        per-slot ORIGINAL ids, ``next_id`` the first unused original id
        (strictly above every id ever issued, so newborns never alias a
        pruned seed).  ``lr``/``momentum``/``wd`` are the per-slot recipe
        values for active vectors (None = that recipe is global)."""
        losses = np.asarray(losses)
        keep_set = set(int(k) for k in keep)
        pruned = [s for s in range(lp.num_real) if s not in keep_set]
        rng = np.random.default_rng([self.seed, 777, int(rung)])
        sp = self.space
        members = []
        for j, slot in enumerate(pruned):
            if self.mode == "arch":
                widths, act = sp.sample_arch(rng)
                members.append(RefillMember(
                    slot=slot, parent_slot=-1, parent_id=-1,
                    member_id=int(next_id) + j, birth_rung=int(rung),
                    widths=tuple(widths), acts=act,
                    lr=None if lr is None else sp.sample_lr(rng, base_lr),
                    momentum=None if momentum is None
                    else sp.sample_momentum(rng),
                    wd=None if wd is None else sp.sample_wd(rng, base_wd),
                    origin="fresh"))
                continue
            cands = [k for k in sorted(keep_set)
                     if lp.widths[k] == lp.widths[slot]
                     and lp.activations[k] == lp.activations[slot]]
            if cands:
                cands.sort(key=lambda k: losses[k])
                top = cands[:max(1, int(np.ceil(len(cands)
                                                * self.exploit_frac)))]
                parent = int(top[int(rng.integers(len(top)))])
                members.append(RefillMember(
                    slot=slot, parent_slot=parent,
                    parent_id=int(member_ids[parent]),
                    member_id=int(next_id) + j, birth_rung=int(rung),
                    widths=lp.widths[slot], acts=lp.activations[slot],
                    lr=None if lr is None
                    else sp.perturb_lr(rng, float(lr[parent]), base_lr),
                    momentum=None if momentum is None
                    else sp.perturb_momentum(rng, float(momentum[parent])),
                    wd=None if wd is None
                    else sp.perturb_wd(rng, float(wd[parent]), base_wd),
                    origin="exploit"))
            else:
                members.append(RefillMember(
                    slot=slot, parent_slot=-1, parent_id=-1,
                    member_id=int(next_id) + j, birth_rung=int(rung),
                    widths=lp.widths[slot], acts=lp.activations[slot],
                    lr=None if lr is None else sp.sample_lr(rng, base_lr),
                    momentum=None if momentum is None
                    else sp.sample_momentum(rng),
                    wd=None if wd is None else sp.sample_wd(rng, base_wd),
                    origin="fresh"))
        return RefillPlan(members=tuple(members))
