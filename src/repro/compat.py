"""jax version compatibility.

The codebase targets the current jax mesh API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``make_mesh(..., axis_types=...)``);
CI containers pin older CPU wheels (0.4.x) where those names don't exist
but the equivalent thread-local mesh context does.  Everything version-
dependent funnels through this module so the rest of the code reads as
current-API jax.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names, devices=None):
    """jax.make_mesh with Auto axis_types when the installed jax has them."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh.

    New jax: ``jax.set_mesh``.  Old jax: ``Mesh`` is itself the context
    manager that installs the thread-local resource env (the pjit-era
    spelling of the same thing)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """The ambient mesh, or None when unset (old jax) / empty (new jax
    returns an AbstractMesh with no axis_names — callers check both)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib  # pre-0.5 thread-local env
    env = getattr(mesh_lib.thread_resources, "env", None)
    m = getattr(env, "physical_mesh", None)
    if m is None or m.empty:
        return None
    return m


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """jax.shard_map (new) / jax.experimental.shard_map (old); ``check``
    maps to check_vma / check_rep respectively."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict: old jax returned a
    one-entry-per-device LIST of dicts, new jax returns the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
