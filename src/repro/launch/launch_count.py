"""Static kernel-launch counting + the per-step launch budget (DESIGN.md §9).

WHY jaxpr walking and not HLO: the CPU container runs every Pallas kernel
in interpret mode, where ``pallas_call`` lowers to ordinary XLA ops — the
compiled module contains no custom-calls to count.  The jaxpr, traced
BEFORE lowering, still carries one ``pallas_call`` equation per launch
site regardless of backend, so the count measured here in CI is exactly
the count a TPU run dispatches.  Sub-jaxprs (custom_vjp branches, pjit
bodies, cond/scan/while) are walked recursively; a ``scan`` multiplies its
body count by the trip length, so a scanned train chunk reports
launches-per-chunk (divide by ``scan_steps`` for per-step numbers).

The budget itself: the fused population path runs each direction of each
layer as exactly ONE launch — input layer, depth−1 mid layers, loss head —
so a train step costs 2·(depth+1) launches at ANY batch size.  The
two-level-grid backward (kernels/fused_layer.py) is what removed the batch
dependence; ``fused_step_budget`` is the committed invariant that
benchmarks and CI enforce against regressions.
"""
from __future__ import annotations

import jax


def _sub_jaxprs(val):
    """Jaxpr-like values reachable from an eqn param (Jaxpr, ClosedJaxpr,
    or containers of them) — duck-typed to survive jax version drift."""
    if hasattr(val, "eqns"):                 # Jaxpr
        return [val]
    if hasattr(val, "jaxpr"):                # ClosedJaxpr
        return [val.jaxpr]
    if isinstance(val, (tuple, list)):
        out = []
        for v in val:
            out.extend(_sub_jaxprs(v))
        return out
    return []


def count_jaxpr_launches(jaxpr) -> int:
    """Number of ``pallas_call`` equations in a (possibly nested) jaxpr,
    loop-weighted: a ``scan`` body counts ``length`` times."""
    n = 0
    for eqn in jaxpr.eqns:
        mult = 1
        if eqn.primitive.name == "scan":
            mult = int(eqn.params.get("length", 1))
        if eqn.primitive.name == "pallas_call":
            n += 1
        inner = 0
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                inner += count_jaxpr_launches(sub)
        n += mult * inner
    return n


def count_pallas_launches(fn, *args, **kwargs) -> int:
    """Kernel launches one call of ``fn(*args, **kwargs)`` dispatches."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return count_jaxpr_launches(closed.jaxpr)


def phase_launches(loss_fn, *args) -> dict:
    """Launches of a scalar-valued ``loss_fn`` split by phase:
    ``{"fwd", "bwd", "total"}`` where ``total`` covers one
    ``jax.grad(loss_fn)`` evaluation (VJP-forward + backward) and ``bwd``
    is ``total − fwd``.  Every kernel here launches once in its primal and
    once in its VJP-forward variant, so the subtraction is exact."""
    fwd = count_pallas_launches(loss_fn, *args)
    total = count_pallas_launches(jax.grad(loss_fn), *args)
    return {"fwd": fwd, "bwd": total - fwd, "total": total}


def fused_step_budget(depth: int) -> dict:
    """The §9 invariant for the fully fused path (``bd_impl="fused"`` with
    default input/loss routing): one launch per layer per direction —
    input + (depth−1) mids + loss head — independent of batch size."""
    per_dir = depth + 1
    return {"fwd": per_dir, "bwd": per_dir, "total": 2 * per_dir}


def fused_infer_budget(depth: int) -> dict:
    """The §10 invariant for the forward-only serving path
    (``forward(infer=True)`` with fused routing): input + (depth−1) mids +
    infer head = depth+1 launches per request batch — half the train step,
    no backward phase to budget, independent of batch size."""
    return {"fwd": depth + 1, "total": depth + 1}


def max_eqn_outputs(fn, *args, primitive: str = "pallas_call",
                    **kwargs) -> int:
    """Largest number of outputs any ``primitive`` equation in ``fn``'s
    (recursively walked) jaxpr carries.  The §10 no-residual assertion:
    a forward-only program's pallas_calls are all single-output — a 2 here
    means some kernel still emits a residual (g'/dlogits) buffer."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)

    def walk(jaxpr) -> int:
        worst = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == primitive:
                worst = max(worst, len(eqn.outvars))
            for val in eqn.params.values():
                for sub in _sub_jaxprs(val):
                    worst = max(worst, walk(sub))
        return worst

    return walk(closed.jaxpr)
