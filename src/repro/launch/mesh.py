"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is pure
data parallelism crossing DCI (gradient all-reduce, optionally int8-
compressed — distributed/compression.py).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run pins the device count via XLA_FLAGS before
any jax import; tests import this file under a 1-device CPU)."""
from __future__ import annotations

import jax
import numpy as np

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Largest (data, model) mesh on the CURRENT device set (examples,
    reduced-scale training, elastic restarts)."""
    n = len(jax.devices())
    if model is None:
        model = 1
        for cand in (16, 8, 4, 2):
            if n % cand == 0 and n >= cand:
                model = cand
                break
    return make_mesh((n // model, model), ("data", "model"))


def mesh_num_devices(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
