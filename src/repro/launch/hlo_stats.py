"""Parse collective traffic and op statistics out of compiled HLO text.

``compiled.as_text()`` is the post-SPMD, per-device module: shapes are shard
shapes, collectives are explicit ops.  We classify every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
and account its LARGEST operand/result bytes as that op's wire payload
(per device).  The roofline's collective term is then

    collective_term = per_device_collective_bytes / link_bw

(equivalently Σ-over-chips / (chips × link_bw), the assignment's form).

This is intentionally a *structural* profile — no wall clock exists for TPU
on this container; the same parse also powers the §Perf iteration loop
(counting redundant gathers, remat recompute, etc.)."""
from __future__ import annotations

import collections
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# start-flavoured async variants count once (the -done carries no new bytes)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s*"
    r"(all-reduce-start|all-gather-start|all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(", re.M)


def shape_bytes(text: str) -> int:
    """Total bytes of every dtype[dims] group in a type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective payload bytes by op kind + op counts."""
    bytes_by_kind = collections.Counter()
    count_by_kind = collections.Counter()
    for m in _OP_RE.finditer(hlo_text):
        result_type, op = m.group(1), m.group(2)
        kind = op.replace("-start", "")
        b = shape_bytes(result_type)
        if op.endswith("all-gather-start"):
            # result tuple repeats the operand; gather payload is the output
            b = b // 2 if b else b
        bytes_by_kind[kind] += b
        count_by_kind[kind] += 1
    return {
        "per_device_bytes": dict(bytes_by_kind),
        "counts": dict(count_by_kind),
        "total_per_device_bytes": sum(bytes_by_kind.values()),
    }


def op_histogram(hlo_text: str, top: int = 20) -> list:
    """(op_name, count) histogram — the 'profile' for §Perf iteration."""
    ops = re.findall(r"=\s*(?:\([^=]*?\)|\S+)\s*([a-z][\w\-]*)\(", hlo_text)
    return collections.Counter(ops).most_common(top)


def fusion_count(hlo_text: str) -> int:
    return len(re.findall(r"\bfusion\(", hlo_text))
