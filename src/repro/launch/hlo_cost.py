"""Loop-aware static cost model over post-SPMD HLO text.

WHY: ``compiled.cost_analysis()`` visits each ``while`` body ONCE — a model
lowered as ``lax.scan`` over 96 layers reports ~1/96 of its real flops,
bytes and collective traffic.  This module parses the compiled per-device
HLO, recovers every loop's trip count from its condition computation
(``compare(counter, constant(N)), direction=LT`` — the shape jax scans
lower to), and walks the call graph weighting each computation by its call
multiplicity.  The result is the honest per-device, per-step profile the
roofline needs:

  flops            — 2·prod(result)·K for every dot (+conv), loop-weighted
  hbm_bytes        — Σ (operands+results) of top-level kernels (fusions,
                     dots, copies, collectives…), loop-weighted; fusion
                     internals are VMEM and excluded, matching how XLA:TPU
                     materialises buffers
  collective_bytes — per collective kind, loop-weighted

Validated against cost_analysis() on loop-free modules
(tests/test_hlo_cost.py) where the two must agree on dot flops.
"""
from __future__ import annotations

import collections
import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:{[^}]*})?))\s*"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_CALLED = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_CALLED_LIST = re.compile(r"calls=\{([^}]*)\}")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")
# ops that materialise HBM buffers at the executable level
_KERNEL_OPS = {"fusion", "dot", "convolution", "copy", "custom-call",
               "dynamic-slice", "dynamic-update-slice", "sort", "rng",
               "gather", "scatter", "transpose", "broadcast", "reshape-x",
               "reduce", "concatenate", "pad", "slice", "select-and-scatter",
               "iota", "cholesky", "triangular-solve"} | set(COLLECTIVE_KINDS) \
    | {k + "-start" for k in COLLECTIVE_KINDS}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str):
    m = _SHAPE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rest: str          # everything after the opening paren

    @property
    def operands(self):
        # operand names appear before the closing paren of the call
        depth, out, buf = 1, [], self.rest
        end = len(buf)
        for i, ch in enumerate(buf):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return _OPERAND.findall(buf[:end])

    @property
    def called(self):
        names = _CALLED.findall(self.rest)
        for lst in _CALLED_LIST.findall(self.rest):
            names.extend(_OPERAND.findall(lst))
        return names


_NEW_OP = re.compile(r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*")


def _logical_lines(hlo: str):
    """Join physical lines into logical lines (long tuple types wrap).

    Boundaries: new op (`%x = `), closing brace, or a computation header —
    headers start at column 0 in HLO text while ops are indented."""
    buf = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        at_col0 = line[0] not in " \t"
        is_boundary = (_NEW_OP.match(line) is not None or stripped == "}"
                       or at_col0)
        if is_boundary:
            if buf is not None:
                yield buf
            buf = line
        else:
            buf = line if buf is None else buf + " " + stripped
    if buf is not None:
        yield buf


def parse_module(hlo: str) -> dict:
    """computation name -> list[Op]"""
    comps = {}
    cur = None
    for line in _logical_lines(hlo):
        if line.rstrip().endswith("{") and ("->" in line or
                                            line.lstrip().startswith("ENTRY")):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            comps[cur].append(Op(*m.groups()))
    return comps


def _entry_name(hlo: str, comps: dict) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    m = re.search(r"entry_computation_name=\"([^\"]+)\"", hlo)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: a computation never referenced by others
    called = {c for ops in comps.values() for op in ops for c in op.called}
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def trip_count(cond_ops) -> int:
    """Recover N from the loop condition: compare(counter, constant(N)) LT."""
    consts = {}
    for op in cond_ops:
        if op.opcode == "constant":
            m = re.match(r"\s*([\-0-9]+)\)?", op.rest)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond_ops:
        if op.opcode == "compare" and "direction=LT" in op.rest:
            for o in op.operands:
                if o in consts:
                    return max(consts[o], 1)
    return 1


def _dot_flops(op: Op, types: dict) -> float:
    _, rshape = _first_shape(op.result_type)
    ops = op.operands
    if not ops:
        return 0.0
    lhs_type = types.get(ops[0], "")
    _, lshape = _first_shape(lhs_type)
    m = _CONTRACT.search(op.rest)
    k = 1
    if m and lshape:
        for d in m.group(1).split(","):
            if d:
                k *= lshape[int(d)]
    out = 2.0 * k
    for d in rshape:
        out *= d
    return out


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)
    coll_count: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)
    loops: list = dataclasses.field(default_factory=list)

    def scaled(self, k: float) -> "Cost":
        c = Cost(self.flops * k, self.hbm_bytes * k)
        for kk, v in self.coll_bytes.items():
            c.coll_bytes[kk] = v * k
        for kk, v in self.coll_count.items():
            c.coll_count[kk] = v * k
        return c

    def add(self, other: "Cost"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.coll_bytes.update(other.coll_bytes)
        self.coll_count.update(other.coll_count)
        self.loops.extend(other.loops)


def _is_fusion_body(name: str) -> bool:
    return name.startswith("fused_") or ".fused" in name


def analyze(hlo: str) -> dict:
    comps = parse_module(hlo)
    entry = _entry_name(hlo, comps)
    memo = {}

    def comp_cost(name: str, in_fusion: bool) -> Cost:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        total = Cost()
        types = {op.name: op.result_type for op in comps.get(name, [])}
        for op in comps.get(name, []):
            if op.opcode == "while":
                body, cond = None, None
                m = re.search(r"condition=%?([\w.\-]+)", op.rest)
                if m:
                    cond = m.group(1)
                m = re.search(r"body=%?([\w.\-]+)", op.rest)
                if m:
                    body = m.group(1)
                m = _TRIP.search(op.rest)
                if m:                               # XLA's own annotation
                    n = max(int(m.group(1)), 1)
                else:                               # fallback: parse the cond
                    n = trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    inner = comp_cost(body, in_fusion)
                    total.add(inner.scaled(n))
                    total.loops.append((body, n))
                continue
            if op.opcode in ("call", "conditional", "async-start", "map"):
                for c in op.called:
                    if c in comps:
                        total.add(comp_cost(c, in_fusion))
                continue
            if op.opcode == "fusion":
                for c in op.called:
                    if c in comps:
                        total.add(comp_cost(c, True))   # flops only
                if not in_fusion:
                    total.hbm_bytes += _shape_bytes(op.result_type)
                    total.hbm_bytes += sum(
                        _shape_bytes(types.get(o, "")) for o in op.operands)
                continue
            if op.opcode in ("dot", "convolution"):
                total.flops += _dot_flops(op, types)
            kind = op.opcode.replace("-start", "")
            if kind in COLLECTIVE_KINDS and not op.opcode.endswith("-done"):
                b = _shape_bytes(op.result_type)
                if op.opcode == "all-gather-start":
                    b //= 2
                total.coll_bytes[kind] += b
                total.coll_count[kind] += 1
            if not in_fusion and op.opcode in _KERNEL_OPS:
                if op.opcode == "dynamic-update-slice":
                    # in-place on TPU: traffic = write+read of the UPDATE
                    ops_ = op.operands
                    upd = _shape_bytes(types.get(ops_[1], "")) if \
                        len(ops_) > 1 else 0
                    total.hbm_bytes += 2 * upd
                elif op.opcode == "dynamic-slice":
                    # reads only the slice it produces
                    total.hbm_bytes += 2 * _shape_bytes(op.result_type)
                else:
                    total.hbm_bytes += _shape_bytes(op.result_type)
                    total.hbm_bytes += sum(
                        _shape_bytes(types.get(o, "")) for o in op.operands)
        memo[key] = total
        return total

    c = comp_cost(entry, False)
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "collective_bytes": dict(c.coll_bytes),
        "collective_count": dict(c.coll_count),
        "total_collective_bytes": float(sum(c.coll_bytes.values())),
        "loops": c.loops,
    }
