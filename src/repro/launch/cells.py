"""Cell construction: (architecture × input shape × mesh) → a jit-lowerable
computation with fully-specified input shardings and abstract arguments.

A *cell* is the unit of the multi-pod dry-run and the roofline table:

  train_*    → train_step   (fwd + bwd + optimizer update, microbatched)
  prefill_*  → prefill      (full-prompt forward + cache build)
  decode_* / long_* → serve_step (one token against a seq_len KV cache)

Nothing here allocates: parameters, optimizer state, caches and batches are
ShapeDtypeStructs; shardings come from the spec trees declared at module
init, filtered against the target mesh (divisibility-aware)."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import set_mesh
from repro.configs import ArchSpec, ShapeSpec
from repro.distributed.sharding import BATCH_AXES, logical_to_sharding
from repro.models import encdec, lm
from repro.optim import constant_lr, make_optimizer

WHISPER_CROSS_LEN = 1504   # whisper's 1500 encoder frames, padded to /16


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


@dataclasses.dataclass
class Cell:
    name: str
    fn: object
    abstract_args: tuple
    in_shardings: tuple
    donate_argnums: tuple
    model_flops: float          # 6·N_active·D (train) / 2·N_active·D (infer)
    meta: dict
    out_shardings: object = None   # None leaves = let XLA choose

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.abstract_args)


def build_optimizer(arch: ArchSpec):
    kw = arch.optimizer_kwargs()
    for k, v in list(kw.items()):
        if isinstance(v, str) and k.endswith("dtype"):
            kw[k] = jnp.dtype(v)
    return make_optimizer(arch.optimizer, **kw)


def _batch_abs_and_spec(cfg, b, s, *, with_labels: bool):
    """LM input batch: tokens or stub embeddings (+ labels)."""
    if getattr(cfg, "frontend", "tokens") == "embeds":
        abs_ = {"embeds": sds((b, s, cfg.d_model), cfg.dtype)}
        spec = {"embeds": P(BATCH_AXES, None, None)}
    else:
        abs_ = {"tokens": sds((b, s), jnp.int32)}
        spec = {"tokens": P(BATCH_AXES, None)}
    if with_labels:
        abs_["labels"] = sds((b, s), jnp.int32)
        spec["labels"] = P(BATCH_AXES, None)
    return abs_, spec


# --------------------------------------------------------------------- #
# LM cells                                                              #
# --------------------------------------------------------------------- #

def _lm_state(arch: ArchSpec, mesh, with_opt: bool):
    cfg = arch.model
    abs_p, specs = lm.abstract_params(cfg)
    p_sh = logical_to_sharding(specs, mesh, abs_p)
    if not with_opt:
        return cfg, abs_p, p_sh, None, None
    opt = build_optimizer(arch)
    abs_o = jax.eval_shape(opt.init, abs_p)
    o_specs = opt.state_specs(specs, abs_p)
    o_sh = logical_to_sharding(o_specs, mesh, abs_o)
    return cfg, abs_p, p_sh, (opt, abs_o), o_sh


def _lm_train_cell(arch: ArchSpec, sh: ShapeSpec, mesh) -> Cell:
    cfg, abs_p, p_sh, (opt, abs_o), o_sh = _lm_state(arch, mesh, True)
    b, s = sh.global_batch, sh.seq_len
    batch_abs, batch_spec = _batch_abs_and_spec(cfg, b, s, with_labels=True)
    b_sh = logical_to_sharding(batch_spec, mesh, batch_abs)
    step_abs = sds((), jnp.int32)
    step_sh = NamedSharding(mesh, P())
    _, specs = lm.abstract_params(cfg)
    fn = lm.make_train_step(cfg, opt, constant_lr(arch.lr),
                            num_micro=arch.micro_for(sh.name), mesh=mesh,
                            param_specs=specs,
                            accum_dtype=jnp.dtype(arch.grad_accum_dtype))
    return Cell(
        name=f"{arch.arch_id}:{sh.name}", fn=fn,
        abstract_args=(abs_p, abs_o, batch_abs, step_abs),
        in_shardings=(p_sh, o_sh, b_sh, step_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
        model_flops=6.0 * cfg.num_active_params() * b * s,
        meta={"tokens": b * s, "params": cfg.num_params(),
              "active_params": cfg.num_active_params(),
              "num_micro": arch.micro_for(sh.name)})


def _lm_prefill_cell(arch: ArchSpec, sh: ShapeSpec, mesh) -> Cell:
    cfg, abs_p, p_sh, _, _ = _lm_state(arch, mesh, False)
    b, s = sh.global_batch, sh.seq_len
    batch_abs, batch_spec = _batch_abs_and_spec(cfg, b, s, with_labels=False)
    b_sh = logical_to_sharding(batch_spec, mesh, batch_abs)
    fn = partial(lm.prefill, cfg=cfg, max_len=s, mesh=mesh)

    def wrapped(params, batch):
        return fn(params, batch=batch)

    # output caches must be born sharded (replicated 32k KV would OOM)
    abs_out = jax.eval_shape(wrapped, abs_p, batch_abs)
    c_out_sh = logical_to_sharding(
        lm.generic_cache_specs(abs_out[1]), mesh, abs_out[1])
    return Cell(
        name=f"{arch.arch_id}:{sh.name}", fn=wrapped,
        abstract_args=(abs_p, batch_abs),
        in_shardings=(p_sh, b_sh), donate_argnums=(),
        out_shardings=(None, c_out_sh),
        model_flops=2.0 * cfg.num_active_params() * b * s,
        meta={"tokens": b * s, "params": cfg.num_params(),
              "active_params": cfg.num_active_params()})


def _lm_decode_cell(arch: ArchSpec, sh: ShapeSpec, mesh) -> Cell:
    cfg, abs_p, p_sh, _, _ = _lm_state(arch, mesh, False)
    b, s = sh.global_batch, sh.seq_len
    abs_c = jax.eval_shape(partial(lm.init_caches, cfg, b, s))
    c_specs = lm.cache_specs(cfg, b, s)
    c_sh = logical_to_sharding(c_specs, mesh, abs_c)
    batch_abs, batch_spec = _batch_abs_and_spec(cfg, b, 1, with_labels=False)
    b_sh = logical_to_sharding(batch_spec, mesh, batch_abs)
    pos_abs = sds((b,), jnp.int32)
    pos_sh = logical_to_sharding(P(BATCH_AXES), mesh, pos_abs)
    fn = lm.make_serve_step(cfg, mesh)
    cache_bytes = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                      for l in jax.tree.leaves(abs_c))
    return Cell(
        name=f"{arch.arch_id}:{sh.name}", fn=fn,
        abstract_args=(abs_p, abs_c, batch_abs, pos_abs),
        in_shardings=(p_sh, c_sh, b_sh, pos_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
        model_flops=2.0 * cfg.num_active_params() * b,
        meta={"tokens": b, "params": cfg.num_params(),
              "active_params": cfg.num_active_params(),
              "kv_cache_bytes": cache_bytes})


# --------------------------------------------------------------------- #
# enc-dec (whisper) cells                                               #
# --------------------------------------------------------------------- #

def _encdec_state(arch: ArchSpec, mesh, with_opt: bool):
    cfg = arch.model
    abs_p, specs = encdec.abstract_params(cfg)
    p_sh = logical_to_sharding(specs, mesh, abs_p)
    if not with_opt:
        return cfg, abs_p, p_sh, None, None
    opt = build_optimizer(arch)
    abs_o = jax.eval_shape(opt.init, abs_p)
    o_sh = logical_to_sharding(opt.state_specs(specs, abs_p), mesh, abs_o)
    return cfg, abs_p, p_sh, (opt, abs_o), o_sh


def _encdec_train_cell(arch: ArchSpec, sh: ShapeSpec, mesh) -> Cell:
    cfg, abs_p, p_sh, (opt, abs_o), o_sh = _encdec_state(arch, mesh, True)
    b, s = sh.global_batch, sh.seq_len
    batch_abs = {"frames": sds((b, s, cfg.d_model), cfg.dtype),
                 "tokens": sds((b, s), jnp.int32),
                 "labels": sds((b, s), jnp.int32)}
    batch_spec = {"frames": P(BATCH_AXES, None, None),
                  "tokens": P(BATCH_AXES, None),
                  "labels": P(BATCH_AXES, None)}
    b_sh = logical_to_sharding(batch_spec, mesh, batch_abs)
    fn = encdec.make_train_step(cfg, opt, constant_lr(arch.lr),
                                num_micro=arch.micro_for(sh.name), mesh=mesh)
    return Cell(
        name=f"{arch.arch_id}:{sh.name}", fn=fn,
        abstract_args=(abs_p, abs_o, batch_abs, sds((), jnp.int32)),
        in_shardings=(p_sh, o_sh, b_sh, NamedSharding(mesh, P())),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
        model_flops=6.0 * cfg.num_params() * b * s,
        meta={"tokens": b * s, "params": cfg.num_params(),
              "active_params": cfg.num_params()})


def _encdec_prefill_cell(arch: ArchSpec, sh: ShapeSpec, mesh) -> Cell:
    """Whisper 'prefill' = encode the source + build decode caches."""
    cfg, abs_p, p_sh, _, _ = _encdec_state(arch, mesh, False)
    b, s = sh.global_batch, sh.seq_len
    frames_abs = sds((b, s, cfg.d_model), cfg.dtype)
    f_sh = logical_to_sharding(P(BATCH_AXES, None, None), mesh, frames_abs)

    def fn(params, frames):
        return encdec.prepare_serve_caches(params, cfg, frames,
                                           max_len=min(s, cfg.max_target))

    abs_out = jax.eval_shape(fn, abs_p, frames_abs)
    c_out_sh = logical_to_sharding(lm.generic_cache_specs(abs_out), mesh,
                                   abs_out)
    return Cell(
        name=f"{arch.arch_id}:{sh.name}", fn=fn,
        abstract_args=(abs_p, frames_abs),
        in_shardings=(p_sh, f_sh), donate_argnums=(),
        out_shardings=c_out_sh,
        model_flops=2.0 * cfg.num_params() * b * s,
        meta={"tokens": b * s, "params": cfg.num_params(),
              "active_params": cfg.num_params()})


def _encdec_decode_cell(arch: ArchSpec, sh: ShapeSpec, mesh) -> Cell:
    cfg, abs_p, p_sh, _, _ = _encdec_state(arch, mesh, False)
    b, s = sh.global_batch, sh.seq_len
    a = cfg.attn
    abs_c = {
        "self": jax.eval_shape(partial(encdec.init_self_caches, cfg, b, s)),
        "cross_k": sds((cfg.n_dec_layers, b, WHISPER_CROSS_LEN,
                        a.n_kv_heads, a.d_head), cfg.dtype),
        "cross_v": sds((cfg.n_dec_layers, b, WHISPER_CROSS_LEN,
                        a.n_kv_heads, a.d_head), cfg.dtype),
    }
    c_spec = {
        "self": jax.tree.map(
            lambda l: P(None, BATCH_AXES, "model") if l.ndim == 3
            else P(None, BATCH_AXES, "model", None, None), abs_c["self"]),
        "cross_k": P(None, BATCH_AXES, "model", None, None),
        "cross_v": P(None, BATCH_AXES, "model", None, None),
    }
    c_sh = logical_to_sharding(c_spec, mesh, abs_c)
    batch_abs = {"tokens": sds((b, 1), jnp.int32)}
    b_sh = logical_to_sharding({"tokens": P(BATCH_AXES, None)}, mesh, batch_abs)
    pos_abs = sds((b,), jnp.int32)
    pos_sh = logical_to_sharding(P(BATCH_AXES), mesh, pos_abs)
    fn = encdec.make_serve_step(cfg, mesh)
    return Cell(
        name=f"{arch.arch_id}:{sh.name}", fn=fn,
        abstract_args=(abs_p, abs_c, batch_abs, pos_abs),
        in_shardings=(p_sh, c_sh, b_sh, pos_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
        model_flops=2.0 * cfg.num_params() * b,
        meta={"tokens": b, "params": cfg.num_params(),
              "active_params": cfg.num_params()})


# --------------------------------------------------------------------- #
# population (the paper's arch) cells                                   #
# --------------------------------------------------------------------- #

def _population_train_cell(arch: ArchSpec, sh: ShapeSpec, mesh) -> Cell:
    from repro.core import parallel_mlp
    pop = arch.model
    abs_p = jax.eval_shape(
        lambda k: parallel_mlp.init_params(k, pop), jax.random.PRNGKey(0))
    # population axis over 'model': zero cross-member collectives (the
    # paper's independence at mesh scale).  ZeRO-style ('model','data')
    # hybrid sharding was tried and REFUTED (§Perf paper-cell iter 4):
    # stateless SGD re-gathers weights 2× per step, costing more than the
    # gradient all-reduce it eliminates (82.7 vs 33.6 MB/dev).
    specs = {"w1": P("model", None), "b1": P("model"),
             "w2": P(None, "model"), "b2": P("model", None)}
    p_sh = logical_to_sharding(specs, mesh, abs_p)
    b = sh.global_batch
    x_abs = sds((b, pop.in_features), jnp.float32)
    y_abs = sds((b,), jnp.int32)
    x_sh = logical_to_sharding(P(BATCH_AXES, None), mesh, x_abs)
    y_sh = logical_to_sharding(P(BATCH_AXES), mesh, y_abs)
    lr = arch.lr

    def fn(params, x, y):
        # act_impl='masked': branchless per-unit activation select.  The
        # sliced path cuts the fused axis at activation-run boundaries that
        # don't align with its 16-way sharding → SPMD rematerialisation
        # (§Perf paper-cell iteration 3; confirmed ~2× on the memory term).
        (loss, per), grads = jax.value_and_grad(
            parallel_mlp.fused_loss, has_aux=True)(
                params, x, y, pop, "classification", m3_impl="bucketed",
                act_impl="masked")
        new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new, loss, per

    real_params = sum(h * (pop.in_features + pop.out_features) + h
                      for h in pop.hidden_sizes) \
        + pop.num_members * pop.out_features
    return Cell(
        name=f"{arch.arch_id}:{sh.name}", fn=fn,
        abstract_args=(abs_p, x_abs, y_abs),
        in_shardings=(p_sh, x_sh, y_sh), donate_argnums=(0,),
        model_flops=6.0 * real_params * b,
        meta={"tokens": b, "params": real_params,
              "active_params": real_params,
              "members": pop.num_members,
              "fused_hidden": pop.total_hidden})


# --------------------------------------------------------------------- #
# dispatch                                                              #
# --------------------------------------------------------------------- #

_BUILDERS = {
    ("lm", "train"): _lm_train_cell,
    ("lm", "prefill"): _lm_prefill_cell,
    ("lm", "decode"): _lm_decode_cell,
    ("encdec", "train"): _encdec_train_cell,
    ("encdec", "prefill"): _encdec_prefill_cell,
    ("encdec", "decode"): _encdec_decode_cell,
    ("population", "train"): _population_train_cell,
}


def make_cell(arch: ArchSpec, sh: ShapeSpec, mesh) -> Cell:
    if not arch.runs(sh.name):
        raise ValueError(f"{arch.arch_id} skips {sh.name}: {arch.skip_reason}")
    builder = _BUILDERS[(arch.kind, sh.kind)]
    with set_mesh(mesh):
        return builder(arch, sh, mesh)
