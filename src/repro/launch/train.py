"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs REAL training at whatever scale the current device set supports
(reduced configs on CPU; the full configs on an actual pod — the code path
is identical, only the mesh differs).  Wires together:

  data (step-indexed, restart-safe) → train_step (jit, sharded) →
  TrainRunner (checkpoint/restart, straggler watchdog) → metrics log

Flags exercise every distributed feature: --compress-grads (int8 cross-pod
all-reduce), --ckpt-every / --resume.

Population archs (``--arch parallelmlp-10k``) train through the layered
population engine (core.deep): ``--population-depths "64,32,16;13,5;7"``
builds a heterogeneous-depth LayeredPopulation (members separated by ';',
per-layer widths by ','), ``--bd-impl pallas`` routes mid layers through the
block-diagonal Pallas kernel, ``--act-impl pallas`` routes per-layer
activations through the seg_act kernel, ``--per-member-lr`` samples one
step size per member, and checkpoints carry the fused layout
(checkpoint.save_population) so ``--resume`` needs no flags re-supplied.
The population path is distribution-native: the layout shard-pads to the
mesh's 'model' axis, params are born sharded, batches shard over 'data',
the step is a donated ``lax.scan`` chunk (``--scan-steps``), and the loop
runs through ``TrainRunner`` exactly like the LM path.  ``--halving
"500:0.5,1000:0.25"`` adds the successive-halving lifecycle: prune at each
rung, compact the survivors into a smaller fused layout, continue.
``--optimizer {sgd,momentum,adamw,adafactor}`` selects the stateful
optimizer engine (DESIGN.md §8): opt state is born sharded, compacted
through rungs, checkpointed, and validated on resume; ``--per-member-lr``
/ ``--per-member-momentum`` / ``--per-member-weight-decay`` race
heterogeneous training recipes across the population; ``--grad-clip``
clips by global norm and logs the pre-clip norm per step.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.checkpoint import latest_steps, restore
from repro.configs import get_arch
from repro.data import TabularTask, TokenTask
from repro.distributed import TrainRunner, StragglerPolicy
from repro.distributed.sharding import logical_to_sharding
from repro.launch.cells import build_optimizer
from repro.launch.mesh import make_host_mesh
from repro.models import encdec, lm
from repro.optim import warmup_cosine


def _init_sharded(init_fn, specs_fn, mesh):
    """jit the initializer with out_shardings so parameters are BORN sharded
    (no host-side full materialisation)."""
    abs_p, specs = specs_fn()
    sh = logical_to_sharding(specs, mesh, abs_p)
    return jax.jit(init_fn, out_shardings=sh)(jax.random.PRNGKey(0)), sh


def run_lm(arch, args, mesh):
    cfg = arch.model
    is_encdec = arch.kind == "encdec"
    mod = encdec if is_encdec else lm
    with set_mesh(mesh):
        params, p_sh = _init_sharded(
            lambda k: mod.init_params(k, cfg)[0],
            lambda: mod.abstract_params(cfg), mesh)
        opt = build_optimizer(arch)
        o_specs = opt.state_specs(mod.abstract_params(cfg)[1],
                                  mod.abstract_params(cfg)[0])
        abs_o = jax.eval_shape(opt.init, params)
        o_sh = logical_to_sharding(o_specs, mesh, abs_o)
        opt_state = jax.jit(opt.init, out_shardings=o_sh)(params)

        lr_fn = warmup_cosine(arch.lr, args.warmup, args.steps)
        # LM default stays 1.0 when the flag is unset (populations default
        # to clipping OFF — plain SGD baselines must stay bit-exact)
        step_fn_raw = mod.make_train_step(
            cfg, opt, lr_fn, num_micro=args.num_micro, mesh=mesh,
            grad_clip=1.0 if args.grad_clip is None else args.grad_clip)
        jit_step = jax.jit(step_fn_raw, donate_argnums=(0, 1))

        task = TokenTask(vocab=cfg.vocab, seed=args.seed)

        def make_batch(step):
            b = task.batch(step, args.batch, args.seq)
            if is_encdec:
                rng = np.random.default_rng([args.seed, step])
                b["frames"] = rng.normal(
                    0, 1, (args.batch, args.seq, cfg.d_model)
                ).astype(np.float32)
            elif cfg.frontend == "embeds":
                rng = np.random.default_rng([args.seed, step])
                b["embeds"] = rng.normal(
                    0, 1, (args.batch, args.seq, cfg.d_model)
                ).astype(np.float32)
                del b["tokens"]
            return b

        state = {"params": params, "opt": opt_state}

        def step_fn(state, step):
            batch = make_batch(step)
            p, o, metrics = jit_step(state["params"], state["opt"], batch,
                                     jnp.asarray(step, jnp.int32))
            return {"params": p, "opt": o}, {
                k: float(v) for k, v in metrics.items()}

        runner = TrainRunner(
            step_fn, state, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
            straggler=StragglerPolicy(timeout_s=args.straggler_timeout),
            mesh=mesh, state_specs={"params": mod.abstract_params(cfg)[1],
                                    "opt": o_specs})
        start = 0
        if args.resume and latest_steps(args.ckpt_dir):
            # restore through the runner's derived sharding tree so resume
            # lands sharded (replicating params+opt first OOMs exactly the
            # configs the mesh exists for)
            runner.state, last = restore(args.ckpt_dir, runner.state,
                                         shardings=runner.restore_shardings)
            start = last + 1
            print(f"resumed from step {last}")
        t0 = time.time()
        runner.run(args.steps, start_step=start)
        dt = time.time() - t0
        losses = [m["loss"] for _, m in runner.metrics_log]
        print(f"done: {len(losses)} steps in {dt:.1f}s; "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
        return runner


def parse_depth_spec(spec: str):
    """"64,32,16;13,5;7" → ((64, 32, 16), (13, 5), (7,)) — one member per
    ';'-separated group, one hidden layer per ','-separated width."""
    widths = []
    for member in spec.split(";"):
        member = member.strip()
        if not member:
            continue
        widths.append(tuple(int(w) for w in member.split(",")))
    if not widths:
        raise ValueError(f"empty population spec {spec!r}")
    return tuple(widths)


def run_population(arch, args):
    """Fused population training through the layered engine (core.deep),
    DISTRIBUTION-NATIVE: the layout is shard-padded to the mesh's
    population ('model') axis, parameters are born sharded through
    ``LayeredPopulation.param_specs()``, the step is a jitted
    argument-donating ``lax.scan`` chunk (``--scan-steps``), train batches
    shard over the 'data' axis, and the loop runs through ``TrainRunner``
    (checkpoint cadence, straggler watchdog, sharded crash replay) with
    layout-carrying sharded checkpoints.

    ``--halving`` drives the successive-halving lifecycle (core.lifecycle,
    DESIGN.md §6): the run is split into rung segments; at each rung
    boundary the loop exits the donated scan chunk, evaluates under the
    training sharding, prunes to the best ``keep_frac`` of the survivors,
    COMPACTS them into a freshly bucketed layout, re-pads it to the mesh,
    device_puts the gathered state born-sharded, and re-jits the next
    segment's chunk against the physically smaller population.  Checkpoints
    carry the lifecycle (rung index + survivor→original member mapping), so
    ``--resume`` restores mid-ladder on the compacted layout and the
    leaderboard keeps reporting ORIGINAL member ids.

    The step itself is OPTIMIZER-GENERIC (core.deep.opt_step engine,
    DESIGN.md §8): ``--optimizer {sgd,momentum,adamw,adafactor}`` carries
    ``(params, opt_state)`` through the donated scan chunk, with the state
    born sharded through ``LayeredPopulation.opt_specs()``, compacted
    through halving rung boundaries (real moments, not just params), saved
    with every checkpoint (+ the optimizer config in ``meta["train"]``,
    validated on resume), and per-member hyperparameter vectors
    (``--per-member-lr``/``--per-member-momentum``/
    ``--per-member-weight-decay``) so members race heterogeneous training
    RECIPES, not just architectures.  Plain ``sgd`` reproduces the
    historical stateless trajectory bit-for-bit."""
    from repro.checkpoint import (latest_steps, layout_from_meta,
                                  lifecycle_from_meta, load_meta,
                                  population_meta, require_optimizer_match,
                                  restore_population, save_population)
    from repro.core import deep
    from repro.core.activations import PAPER_TEN
    from repro.core.lifecycle import (HalvingSchedule, compact,
                                      compact_factored, grow_params,
                                      refill_params, refill_state, survivors)
    from repro.core.population import LayeredPopulation, Population
    from repro.core.selection import evaluate_population, leaderboard
    from repro.data import DeferredMetrics, Prefetcher, TabularTask
    from repro.distributed import StragglerPolicy, TrainRunner
    from repro.distributed.sharding import (pop_axis_size,
                                            population_batch_shardings,
                                            population_opt_shardings,
                                            population_shardings)
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adafactor, adamw, sgd
    from repro.search import RefillController, SearchSpace

    schedule = HalvingSchedule.parse(args.halving) if args.halving else None

    # ---- slot-refill search controller (DESIGN.md §13): prune-then-refill
    # at every rung boundary.  "pbt" holds the population size constant
    # (refills adopt their slot's architecture — the zero-re-jit path);
    # "arch" resamples architectures from the space and grows the layout.
    refill_mode = args.refill
    space = SearchSpace.parse(args.search_space)
    controller = None
    if refill_mode != "off":
        if schedule is None:
            raise SystemExit("--refill needs --halving (rung boundaries "
                             "are where slots free up)")
        controller = RefillController(space, mode=refill_mode,
                                      seed=args.seed,
                                      exploit_frac=args.refill_exploit_frac)

    # ---- optimizer config (resolved before any state is materialised so
    # the resume path can validate it against the checkpoint's record)
    opt_name = args.optimizer or arch.optimizer
    grad_clip = args.grad_clip if args.grad_clip else None
    if opt_name not in ("sgd", "momentum", "adamw", "adafactor"):
        raise SystemExit(f"unknown optimizer {opt_name!r}")
    if args.per_member_momentum and opt_name != "momentum":
        raise SystemExit("--per-member-momentum needs --optimizer momentum")
    if args.per_member_weight_decay and opt_name not in ("adamw",
                                                         "adafactor"):
        raise SystemExit(
            "--per-member-weight-decay needs --optimizer adamw/adafactor")
    if args.per_member_weight_decay and args.weight_decay <= 0:
        raise SystemExit("--per-member-weight-decay scales --weight-decay; "
                         "set it > 0")
    if args.opt_state_dtype != "float32" and opt_name != "adamw":
        raise SystemExit(
            "--opt-state-dtype applies to --optimizer adamw only "
            "(sgd/momentum moments are f32; adafactor manages its own "
            "state dtypes) — it would be silently ignored here")
    # the record checkpoints carry under meta["train"]["optimizer"]: resume
    # must match it EXACTLY or fail loudly (require_optimizer_match) — a
    # state tree reinterpreted under different hyperparameters is silent
    # corruption
    opt_record = {
        "name": opt_name, "lr": float(arch.lr),
        "grad_clip": float(grad_clip or 0.0),
        "per_member_lr": bool(args.per_member_lr),
        "per_member_momentum": bool(args.per_member_momentum),
        "per_member_weight_decay": bool(args.per_member_weight_decay),
    }
    if opt_name == "momentum":
        opt_record["momentum"] = float(args.momentum)
    if opt_name in ("adamw", "adafactor"):
        opt_record["weight_decay"] = float(args.weight_decay)
    if opt_name == "adamw":
        opt_record["state_dtype"] = args.opt_state_dtype
    if (args.per_member_lr or args.per_member_momentum
            or args.per_member_weight_decay):
        # per-member vectors are pure functions of (seed, n0): resuming
        # under a different seed would silently redraw every member's
        # recipe beneath the restored moments, so the seed is part of the
        # optimizer config whenever a vector is in play
        opt_record["seed"] = int(args.seed)
    if refill_mode != "off":
        # a resumed refill run must re-plan future rungs identically (the
        # controller rng folds the seed) and must not reinterpret grown
        # recipe vectors under a different space or mode
        opt_record["refill"] = refill_mode
        opt_record["seed"] = int(args.seed)
        if args.search_space:
            opt_record["search_space"] = args.search_space

    if args.population_depths:
        widths = parse_depth_spec(args.population_depths)
        acts = tuple(a.strip() for a in args.population_acts.split(","))
        if acts == ("paper",):
            acts = PAPER_TEN
        lp = LayeredPopulation(
            args.population_features, args.population_classes,
            widths * args.population_repeats,
            tuple(acts[i % len(acts)]
                  for i in range(len(widths) * args.population_repeats)),
            block=args.population_block).sorted()
    else:
        model = arch.model
        lp = model.layered() if isinstance(model, Population) else model

    mesh = make_host_mesh()
    scan = max(args.scan_steps, 1)
    print(f"mesh={dict(mesh.shape)} devices={len(jax.devices())} "
          f"scan_steps={scan}")

    with set_mesh(mesh):
        start = 0
        rung = 0
        resuming = bool(args.resume and latest_steps(args.ckpt_dir))
        legacy_ckpt = False
        if resuming:
            # resolve the checkpoint's layout + lifecycle + optimizer
            # record from the META first: the per-member hyperparameter
            # vectors (drawn over n0) and the abstract optimizer state are
            # needed BEFORE the arrays can restore sharded
            meta, last = load_meta(args.ckpt_dir)
            stored = require_optimizer_match(meta, opt_record)
            legacy_ckpt = (stored is None
                           or meta["population"].get("schema",
                                                     "layered") == "single")
            if legacy_ckpt and opt_name != "sgd":
                raise SystemExit(
                    f"--resume: the checkpoint at step {last} predates the "
                    "stateful-optimizer engine (no optimizer state saved); "
                    "it can only resume with the stateless "
                    "'--optimizer sgd'")
            lp_meta = layout_from_meta(meta)
            rung, member_ids, n0 = lifecycle_from_meta(meta, lp_meta)
            start = last + 1
        else:
            lp_real, lp = lp, lp.shard_pad(pop_axis_size(mesh))
            n0 = lp_real.num_members
            member_ids = np.arange(n0)

        # ---- per-member hyperparameter vectors: each drawn ONCE over the
        # run's ORIGINAL n0 members — through the declarative search space
        # (search/space.py; the default space reproduces the historical
        # hardcoded ranges BIT-FOR-BIT) — and indexed down by the survivor
        # mapping (shard-pad fillers get the base value): a member keeps
        # its training recipe through every compaction and across resumes,
        # identically to a single-device run.  With --refill the vectors
        # are GROWABLE numpy arrays indexed by original id: every refilled
        # member appends its (perturbed or freshly sampled) recipe at its
        # fresh id, and the grown tails ride the checkpoint meta so a
        # resume never redraws them.
        lr0 = mom0 = wd0 = None
        if args.per_member_lr:
            lr0 = np.asarray(space.init_lr(args.seed, n0, arch.lr))
            print(f"per-member learning rates in "
                  f"[{arch.lr * space.lr_scale[0]:.4f}, "
                  f"{arch.lr * space.lr_scale[1]:.4f}]")
        if args.per_member_momentum:
            mom0 = np.asarray(space.init_momentum(args.seed, n0))
            print(f"per-member momentum in [{space.momentum_range[0]:.2f}, "
                  f"{space.momentum_range[1]:.2f}]")
        if args.per_member_weight_decay:
            wd0 = np.asarray(space.init_wd(args.seed, n0,
                                           args.weight_decay))
            print(f"per-member weight decay in "
                  f"[{args.weight_decay * space.wd_scale[0]:.5f}, "
                  f"{args.weight_decay * space.wd_scale[1]:.5f}]")

        # ---- lineage: original id → (parent id, birth rung); ids issued
        # from a monotone counter strictly above every id ever used, so a
        # member born at rung r can never alias a pruned seed's id
        next_id = int(n0)
        lineage = {}
        if resuming and refill_mode != "off":
            life = meta.get("lifecycle") or {}
            next_id = int(life.get("next_id", n0))
            lineage = {int(k): (int(v[0]), int(v[1]))
                       for k, v in (life.get("lineage") or {}).items()}
            if lr0 is not None and "lr_vec" in life:
                lr0 = np.asarray(life["lr_vec"], lr0.dtype)
            if mom0 is not None and "mom_vec" in life:
                mom0 = np.asarray(life["mom_vec"], mom0.dtype)
            if wd0 is not None and "wd_vec" in life:
                wd0 = np.asarray(life["wd_vec"], wd0.dtype)

        def member_vec(vec0, base, lp):
            v = jnp.asarray(vec0)[jnp.asarray(member_ids)]
            return jnp.concatenate(
                [v, jnp.full((lp.n_pad,), base, v.dtype)])

        def member_lr(lp):
            return arch.lr if lr0 is None else member_vec(lr0, arch.lr, lp)

        # bumped on every build_opt call: part of the chunk-cache key, so a
        # rebuilt optimizer (new baked momentum/decay trees) re-specializes
        # the chunk while an UNCHANGED (lp, opt) pair is a guaranteed
        # compile-cache hit — the constant-size refill's zero-re-jit path
        opt_epoch = 0

        def build_opt(lp):
            """The segment's optimizer: per-member hyper vectors indexed
            down through the survivor mapping and expanded to scale trees
            for THIS layout — rebuilt at every rung boundary that changes
            the layout or the baked recipe trees, exactly like the
            re-jitted chunk.  NOT rebuilt by a constant-size lr-only
            refill: lr is a runtime chunk argument, so mutating it needs
            no new optimizer and no re-trace."""
            nonlocal opt_epoch
            opt_epoch += 1
            mom = (args.momentum if mom0 is None else
                   deep.member_lr_tree(lp, member_vec(mom0, args.momentum,
                                                      lp)))
            wd = (args.weight_decay if wd0 is None else
                  deep.member_lr_tree(lp, member_vec(wd0, args.weight_decay,
                                                     lp)))
            if opt_name == "sgd":
                return sgd()
            if opt_name == "momentum":
                return sgd(momentum=mom)
            if opt_name == "adamw":
                return adamw(weight_decay=wd,
                             state_dtype=jnp.dtype(args.opt_state_dtype))
            return adafactor(weight_decay=wd)

        # ---- materialise (params, opt_state), born sharded either way
        if resuming:
            # the checkpoint's layout wins (it matches the stored params
            # and is already shard-padded for the mesh that wrote it);
            # restore straight onto THIS mesh through its param/opt specs.
            opt = build_opt(lp_meta)
            opt_state = None
            if legacy_ckpt:
                params, lp_ckpt, _ = restore_population(args.ckpt_dir,
                                                        mesh=mesh)
                if isinstance(lp_ckpt, Population):
                    # single-layer (parallel_mlp) checkpoint → depth-1
                    # layered params map one-to-one onto the unified engine
                    lp_ckpt = lp_ckpt.layered()
                    params = {"w_in": params["w1"], "b_in": params["b1"],
                              "mid": [],
                              "w_out": params["w2"], "b_out": params["b2"]}
            else:
                extra_like = jax.eval_shape(opt.init,
                                            deep.abstract_params(lp_meta))
                params, lp_ckpt, _, opt_state = restore_population(
                    args.ckpt_dir, extra_like=extra_like, mesh=mesh,
                    extra_specs=lp_meta.opt_specs(opt))
            if lp_ckpt != lp and lp_ckpt != lp.shard_pad(pop_axis_size(mesh)):
                print("note: resuming with the CHECKPOINT's layout "
                      f"({lp_ckpt.describe()})")
            lp = lp_ckpt
            if opt_state is None:
                # legacy checkpoint: no stored state; plain sgd's state is
                # just the step count, so a fresh init resumes exactly
                opt_state = jax.jit(
                    opt.init,
                    out_shardings=population_opt_shardings(lp, opt, mesh))(
                    params)
            print(f"resumed from step {last}"
                  + (f" (rung {rung}, {lp.num_real} survivors)"
                     if rung else ""))
        else:
            # shard-pad the layout to the population axis and initialise
            # born-sharded: the real members' params are BIT-IDENTICAL to a
            # single-device init (fillers draw from a folded key), and the
            # optimizer moments are born sharded alongside them (zeros —
            # identical padded or not).
            def born_sharded(key):
                p = deep.init_params(key, lp_real)
                return deep.pad_params(p, lp_real, lp,
                                       jax.random.fold_in(key, 1))
            params = jax.jit(
                born_sharded,
                out_shardings=population_shardings(lp, mesh))(
                jax.random.PRNGKey(args.seed))
            opt = build_opt(lp)
            opt_state = jax.jit(
                opt.init,
                out_shardings=population_opt_shardings(lp, opt, mesh))(
                params)
        print(f"population: {lp.describe()}  optimizer: {opt_name}"
              + (f" (grad clip {grad_clip})" if grad_clip else ""))

        # everything below depends on the RESOLVED layout (a resumed
        # checkpoint may change member count and feature/class dims)
        task = TabularTask(args.samples, lp.in_features,
                           n_classes=lp.out_features, seed=args.seed)
        (xtr, ytr), (xte, yte) = task.split()
        xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

        def lifecycle_meta():
            m = {"rung": rung, "n_members0": int(n0),
                 "member_ids": [int(i) for i in member_ids]}
            if refill_mode != "off":
                # refill state rides the lifecycle meta as extra keys (the
                # reader's .get() ignores them on old checkpoints): the id
                # counter, the lineage table, and the GROWN tails of the
                # per-member recipe vectors — a resume must reuse them, a
                # fresh draw would only cover the original n0
                m["next_id"] = int(next_id)
                m["lineage"] = {str(k): [int(p), int(b)]
                                for k, (p, b) in sorted(lineage.items())}
                if lr0 is not None:
                    m["lr_vec"] = [float(v) for v in lr0]
                if mom0 is not None:
                    m["mom_vec"] = [float(v) for v in mom0]
                if wd0 is not None:
                    m["wd_vec"] = [float(v) for v in wd0]
            return m

        train_meta = {"compute_dtype": args.compute_dtype,
                      "bd_impl": args.bd_impl, "act_impl": args.act_impl,
                      "optimizer": opt_record,
                      "lr_schedule": args.lr_schedule}

        # ---- LR schedule (PR-6 follow-up): a per-step multiplier threaded
        # through the scanned chunk as a carried global-step counter, so a
        # chunked run anneals identically to a per-step loop and --resume
        # re-enters the schedule at the right step.  The schedule composes
        # with --per-member-lr (it scales the member vector uniformly).
        lr_sched = (warmup_cosine(1.0, args.warmup, args.steps)
                    if args.lr_schedule == "warmup_cosine" else None)

        total = args.steps
        print_every = max(50 // scan, 1)
        stats = {}
        pipeline = args.pipeline == "on"
        pf = None          # ONE Prefetcher for the run, retargeted per rung
        pending = []       # the in-flight chunk's DeferredMetrics (≤ 1)
        # chunk programs keyed (layout, optimizer epoch): a rung boundary
        # that changes neither — the constant-size refill — reuses the
        # SAME traced callable, so its jitted executable is a guaranteed
        # compile-cache hit (zero re-jit; DESIGN.md §13).  Shrinking rungs
        # change lp and build fresh entries, exactly the historical path.
        chunk_cache = {}

        def train_segment(params, opt_state, lp, opt, seg_start, seg_end):
            """Global steps [seg_start, seg_end) under the CURRENT layout:
            jitted donated scan chunks carrying (params, opt_state),
            batches device_put sharded over the 'data' axis, TrainRunner
            replay/checkpoints against the layout's own param AND opt spec
            trees (the state key is 'extra' to match
            ``save_population``/``restore_population``'s on-disk schema).

            With ``--pipeline on`` (default) the segment runs through the
            streaming data plane (data/pipeline.py, DESIGN.md §11): a
            producer thread builds chunk c+1's slab into alternating host
            staging and device_puts it (sharded over 'data') while chunk c
            executes, the slab is DONATED into the chunk, and each chunk's
            host metric fetch is DEFERRED until the next chunk is already
            dispatched — the device queue never drains at the host
            boundary.  The trajectory is bit-identical to ``--pipeline
            off`` (same chunk index → same slab; tests/test_pipeline.py)."""
            nonlocal pf
            lr = member_lr(lp)
            chunk_key = (lp, opt_epoch)
            chunk_fn = chunk_cache.get(chunk_key)
            if chunk_fn is None:
                chunk_fn = chunk_cache[chunk_key] = \
                    deep.make_population_train_step(
                        lp, optimizer=opt, grad_clip=grad_clip,
                        m3_impl=args.m3_impl, bd_impl=args.bd_impl,
                        act_impl=args.act_impl, scan_steps=scan,
                        donate_batch=pipeline,
                        compute_dtype=args.compute_dtype,
                        lr_schedule=lr_sched)
                stats["chunk_builds"] = stats.get("chunk_builds", 0) + 1
            sh_x, sh_y = population_batch_shardings(mesh, args.batch)
            n_chunks = (seg_end - seg_start + scan - 1) // scan

            # one probe batch pins the staging dtypes/shapes (pure function
            # of the step index — building it twice changes nothing)
            bx0, by0 = task.batch(seg_start, args.batch)

            def make_staging():
                return (np.empty((scan,) + bx0.shape, bx0.dtype),
                        np.empty((scan,) + by0.shape, by0.dtype))

            def build_slab(c, staging):
                """Chunk c's (scan, B, ...) slab, staged on host and
                device_put sharded — the producer-thread body (also the
                synchronous path's builder, so both paths stage and copy
                identically).  The slab handed to device_put is a SNAPSHOT
                of the staging region: a sharded device_put of a numpy
                array may zero-copy ALIAS its memory (jax CPU backend
                does), so the reusable staging buffer itself must never
                become a device buffer — the snapshot is what the device
                owns, and nothing ever writes it again (DESIGN.md §11
                aliasing rule)."""
                sx, sy = staging
                g0 = seg_start + c * scan
                n = min(scan, seg_end - g0)
                task.batch_slab(g0, n, args.batch, out=(sx[:n], sy[:n]))
                return (jax.device_put(np.array(sx[:n]), sh_x),
                        jax.device_put(np.array(sy[:n]), sh_y))

            if pipeline:
                if pf is None:
                    pf = Prefetcher(build_slab, n_chunks,
                                    make_staging=make_staging,
                                    depth=args.prefetch_depth)
                else:
                    # rung-boundary flush: drop slabs staged for the OLD
                    # segment, re-aim the producer at this one — the
                    # signature lets retarget KEEP the staging buffers
                    # when the slab shapes are unchanged (every
                    # constant-population rung) instead of reallocating
                    sig = (((scan,) + bx0.shape, np.dtype(bx0.dtype).str),
                           ((scan,) + by0.shape, np.dtype(by0.dtype).str))
                    pf.retarget(build_slab, n_chunks,
                                make_staging=make_staging, signature=sig)
            sync_staging = None if pipeline else make_staging()

            def resolve_metrics(pers, gnorms, g0, n, c):
                """Host side of chunk c's metrics — runs at force() time,
                i.e. after chunk c+1 is dispatched (pipelined) or inline
                (sync).  Resolution happens in chunk order either way, so
                the stats and prints match the historical loop exactly."""
                def resolve():
                    # mean over REAL members only — shard-pad fillers train
                    # too but must not dilute the reported loss (a sharded
                    # run prints the same numbers as its single-device twin)
                    per = np.asarray(pers[:, :lp.num_real])
                    stats.setdefault("first_loss", float(per[0].mean()))
                    mean = float(per[-1].mean())
                    stats["last_loss"] = mean
                    metrics = {"loss": mean, "step": g0 + n - 1}
                    if gnorms is not None:
                        # pre-clip global grad norm, one per inner step —
                        # the chunk's last one rides the metrics log
                        metrics["grad_norm"] = float(np.asarray(gnorms)[n - 1])
                    if c % print_every == 0:
                        gn = (f"  grad norm {metrics['grad_norm']:.3f}"
                              if gnorms is not None else "")
                        print(f"step {g0 + n - 1:4d}  mean member loss "
                              f"{mean:.4f}{gn}")
                    return metrics
                return resolve

            def step_fn(state, c):
                g0 = seg_start + c * scan
                n = min(scan, seg_end - g0)
                xs, ys = (pf.get(c) if pipeline
                          else build_slab(c, sync_staging))
                # with a schedule, the chunk takes the chunk-start GLOBAL
                # step and carries it through the scan — g0 is derived from
                # the segment, so crash replay and --resume stay consistent
                sched_args = ((jnp.asarray(g0, jnp.int32),) if lr_sched
                              else ())
                p, st, _losses, pers, gnorms = chunk_fn(
                    state["params"], state["extra"], xs, ys, lr,
                    *sched_args)
                dm = DeferredMetrics(resolve_metrics(pers, gnorms, g0, n, c))
                if pipeline:
                    # chunk c is dispatched; NOW pay chunk c-1's host fetch
                    # while c runs (the final chunk resolves after run())
                    while pending:
                        pending.pop(0).force()
                    pending.append(dm)
                else:
                    dm.force()
                return {"params": p, "extra": st}, dm

            def on_restore(c):
                # crash replay: metrics queued for the abandoned trajectory
                # must not resolve (their chunks re-run); the prefetcher
                # re-seeks itself on the out-of-order get(c)
                pending.clear()

            def chunk_crosses_cadence(c):
                # chunk c covers global steps [g0, g1): checkpoint iff one
                # of them completes a --ckpt-every multiple (the per-step
                # loop's "(step+1) % every == 0" cadence, quantized up to
                # chunk end)
                if not args.ckpt_every:
                    return False
                g0 = seg_start + c * scan
                g1 = min(g0 + scan, seg_end)
                return g1 // args.ckpt_every > g0 // args.ckpt_every

            runner = TrainRunner(
                step_fn, {"params": params, "extra": opt_state},
                ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every,
                straggler=StragglerPolicy(timeout_s=args.straggler_timeout),
                ckpt_meta=population_meta(lp, params,
                                          lifecycle=lifecycle_meta(),
                                          train_meta=train_meta),
                ckpt_step_map=lambda c: min(seg_start + (c + 1) * scan,
                                            seg_end) - 1,
                ckpt_step_unmap=lambda g: (g + 1 - seg_start) // scan - 1,
                ckpt_save_pred=chunk_crosses_cadence,
                on_restore=on_restore,
                mesh=mesh, state_specs={"params": lp.param_specs(),
                                        "extra": lp.opt_specs(opt)})
            runner.run(n_chunks)
            # the segment's last chunk still owes its host fetch — resolve
            # it before the rung boundary / final eval reads stats
            while pending:
                pending.pop(0).force()
            # planned work, counted once per segment (a crash-replayed
            # chunk must not inflate the reported throughput)
            stats["member_steps"] = (stats.get("member_steps", 0)
                                     + lp.num_real * (seg_end - seg_start))
            return runner.state["params"], runner.state["extra"]

        def rewarm_adafactor_state(fresh, carried, lp_real, lp, opt):
            """Merge the carried params-shaped momentum + step count into a
            freshly initialised (born-sharded, all-zero) adafactor state on
            the padded layout.  The factored v_row/v_col stay at the fresh
            zeros — they reduce over the fused hidden axis, so survivors'
            statistics mix members and cannot be gathered; zeroing them
            costs the ~1/(1−b2)-step re-warm documented on --halving."""
            if carried["m"] is None:
                return {**fresh, "count": carried["count"]}
            m_pad = deep.pad_state(carried["m"], lp_real, lp)
            is_state_leaf = lambda x: isinstance(x, dict) and (
                "v" in x or "v_row" in x)
            o_sh = population_opt_shardings(lp, opt, mesh)
            m_pad = jax.device_put(
                m_pad, jax.tree.map(lambda sh: sh["m"], o_sh["leaves"],
                                    is_leaf=is_state_leaf))
            leaves = jax.tree.map(lambda st, m: {**st, "m": m},
                                  fresh["leaves"], m_pad,
                                  is_leaf=is_state_leaf)
            return {"count": jax.device_put(carried["count"],
                                            o_sh["count"]),
                    "leaves": leaves}

        server = None

        def publish_live(params, lp):
            """The PR-7 leftover driver hook: refresh the serving
            leaderboard from the LIVE run so the published member set
            tracks the halving ladder (rung boundaries + final state)."""
            nonlocal server
            from repro.launch.serve_population import PopulationServer
            n_cal = xte_j.shape[0]
            if args.rung_eval_batches:
                n_cal = min(n_cal, args.rung_eval_batches * args.batch)
            if server is None:
                server = PopulationServer(
                    params, lp, mesh=mesh, bd_impl=args.bd_impl,
                    act_impl=args.act_impl, batch=args.batch,
                    topk=min(4, lp.num_real))
            else:
                server.refresh(params, lp)
            server.publish(xte_j[:n_cal], yte_j[:n_cal])
            print(f"published: best1={server.published['best1']} "
                  f"topk={server.published['topk']}")
            return server

        # rung segments: [0, b0) prune [b0, b1) prune ... [b_last, total).
        # A resumed run re-enters the ladder at its checkpointed rung (the
        # boundaries before it are already applied to the layout).
        segments = schedule.segments(total) if schedule else ((total, None),)
        t0 = time.time()
        pos = start
        try:
            for i in range(min(rung, len(segments) - 1) if schedule else 0,
                           len(segments)):
                seg_end, keep_frac = segments[i]
                if pos < seg_end:
                    params, opt_state = train_segment(params, opt_state, lp,
                                                      opt, pos, seg_end)
                    pos = seg_end
                if keep_frac is None:
                    continue
                # ---- rung boundary: eval under the training sharding (on a
                # subsampled split when --rung-eval-batches asks for cheap
                # rungs — halving only needs rank fidelity at the cut line),
                # prune, compact PARAMS AND OPTIMIZER MOMENTS into a freshly
                # bucketed layout ON DEVICE (jitted static-index gather, no
                # host round-trip), re-pad to the mesh (zero filler moments),
                # device_put born-sharded; the next segment re-jits against the
                # physically smaller population with a rebuilt optimizer whose
                # per-member hyper trees follow the survivor mapping.
                n_eval = xte_j.shape[0]
                if args.rung_eval_batches:
                    n_eval = min(n_eval, args.rung_eval_batches * args.batch)
                losses, _ = evaluate_population(params, lp, xte_j[:n_eval],
                                                yte_j[:n_eval])
                n_before = lp.num_real
                rung_losses = np.asarray(losses)[:n_before]
                keep = survivors(rung_losses, keep_frac)
                rung = i + 1
                plan = None
                if controller is not None:
                    plan = controller.plan(
                        lp, rung_losses, keep, member_ids, rung=rung,
                        next_id=next_id, base_lr=arch.lr,
                        lr=None if lr0 is None else lr0[member_ids],
                        momentum=None if mom0 is None else mom0[member_ids],
                        wd=None if wd0 is None else wd0[member_ids],
                        base_momentum=args.momentum,
                        base_wd=args.weight_decay)
                    # refilled recipes append at their FRESH ids (plan
                    # order == id order), never overwriting a pruned
                    # member's entry — survivors' rows are untouched, so
                    # the no-refill prefix of every vector stays bit-exact
                    for f in plan.members:
                        lineage[f.member_id] = (f.parent_id, f.birth_rung)
                        if lr0 is not None:
                            lr0 = np.append(lr0, np.asarray(f.lr,
                                                            lr0.dtype))
                        if mom0 is not None:
                            mom0 = np.append(mom0, np.asarray(f.momentum,
                                                              mom0.dtype))
                        if wd0 is not None:
                            wd0 = np.append(wd0, np.asarray(f.wd,
                                                            wd0.dtype))
                    next_id += len(plan.members)
                    stats["refilled"] = (stats.get("refilled", 0)
                                         + len(plan.members))
                if refill_mode == "pbt":
                    # ---- constant-size refill: population size is held
                    # (prune k → refill k into the SAME slots), so the
                    # post-rung layout is IDENTICAL — no compact, no
                    # re-shard-pad, no device_put migration.  The boundary
                    # is one jitted on-device gather/scatter (exploit
                    # clones + fresh inits), a moment mask-zero, and a
                    # recipe rewrite; lr is a runtime chunk argument, so
                    # an lr-only mutation re-enters the SAME compiled
                    # chunk (zero re-jit, asserted via the chunk cache).
                    fresh = None
                    fm = plan.fresh_members
                    if fm:
                        fresh_lp = LayeredPopulation(
                            lp.in_features, lp.out_features,
                            tuple(f.widths for f in fm),
                            tuple(f.acts for f in fm), block=lp.block)
                        fresh = deep.init_params(
                            jax.random.fold_in(
                                jax.random.PRNGKey(args.seed),
                                5000 + rung), fresh_lp)
                    params = refill_params(lp, params, plan.assignments,
                                           fresh, gather="device")
                    opt_state = refill_state(opt_state, lp, plan.slots)
                    member_ids = member_ids.copy()
                    for f in plan.members:
                        member_ids[f.slot] = f.member_id
                    if mom0 is not None or wd0 is not None:
                        # baked momentum/decay trees changed → the chunk
                        # re-specializes (the documented cost of mutating
                        # trace-time recipe constants; lr-only runs skip
                        # this entirely)
                        opt = build_opt(lp)
                    hit = (lp, opt_epoch) in chunk_cache
                    n_ex = sum(1 for f in plan.members
                               if f.origin == "exploit")
                    print(f"rung {i} @ step {pos - 1}: pruned "
                          f"{n_before - len(keep)}/{n_before}, refilled in "
                          f"place ({n_ex} exploit, "
                          f"{len(plan.members) - n_ex} fresh) -> layout "
                          f"unchanged, chunk "
                          + ("cache-hit (zero re-jit)" if hit
                             else "rebuild"))
                else:
                    kept_ids = member_ids[keep]
                    if opt_name == "adafactor":
                        # factored second moments cannot ride the
                        # member-major gather — carry momentum + count,
                        # re-init v_row/v_col
                        lp_real, params_keep, fac_carry = compact_factored(
                            lp, params, opt_state, keep)
                        opt_keep = None
                    else:
                        lp_real, params_keep, opt_keep = compact(
                            lp, params, opt_state, keep)
                    member_ids = kept_ids
                    if refill_mode == "arch":
                        # ---- grow-layout refill: freshly sampled
                        # architectures splice into the compacted layout
                        # (the inverse of compact — survivors bit-exact,
                        # newborns fresh-init, zero moments), then the
                        # grown layout re-pads and re-jits as any
                        # shape-changing rung does.
                        widths_new = tuple(f.widths for f in plan.members)
                        acts_new = tuple(f.acts for f in plan.members)
                        positions = lp_real.grow_positions(widths_new,
                                                           acts_new)
                        lp_grown = lp_real.grow(widths_new, acts_new,
                                                positions)
                        fresh_lp = lp_grown.subset(tuple(sorted(positions)))
                        fresh = deep.init_params(
                            jax.random.fold_in(
                                jax.random.PRNGKey(args.seed),
                                5000 + rung), fresh_lp)
                        params_keep = grow_params(lp_real, lp_grown,
                                                  params_keep, positions,
                                                  fresh)
                        if opt_keep is not None:
                            opt_keep = deep.grow_state(opt_keep, lp_real,
                                                       lp_grown, positions)
                        elif fac_carry["m"] is not None:
                            mdt = jax.tree.leaves(fac_carry["m"])[0].dtype
                            zeros = jax.tree.map(
                                lambda s: jnp.zeros(s.shape, mdt),
                                deep.abstract_params(fresh_lp))
                            fac_carry = {**fac_carry, "m": grow_params(
                                lp_real, lp_grown, fac_carry["m"],
                                positions, zeros)}
                        new_ids = np.empty(lp_grown.num_real,
                                           member_ids.dtype)
                        pos_of = {p: j for j, p in enumerate(positions)}
                        oi = 0
                        for slot in range(lp_grown.num_real):
                            if slot in pos_of:
                                new_ids[slot] = \
                                    plan.members[pos_of[slot]].member_id
                            else:
                                new_ids[slot] = member_ids[oi]
                                oi += 1
                        member_ids = new_ids
                        lp_real = lp_grown
                    lp = lp_real.shard_pad(pop_axis_size(mesh))
                    fill = jax.random.fold_in(jax.random.PRNGKey(args.seed),
                                              1000 + rung)
                    params = jax.device_put(
                        deep.pad_params(params_keep, lp_real, lp, fill),
                        population_shardings(lp, mesh))
                    opt = build_opt(lp)
                    if opt_name == "adafactor":
                        fresh = jax.jit(
                            opt.init,
                            out_shardings=population_opt_shardings(
                                lp, opt, mesh))(params)
                        opt_state = rewarm_adafactor_state(fresh, fac_carry,
                                                           lp_real, lp, opt)
                    else:
                        opt_state = jax.device_put(
                            deep.pad_state(opt_keep, lp_real, lp),
                            population_opt_shardings(lp, opt, mesh))
                    if refill_mode == "arch":
                        print(f"rung {i} @ step {pos - 1}: kept "
                              f"{len(keep)}/{n_before}, grew "
                              f"{len(plan.members)} sampled archs -> "
                              f"{lp.describe()}")
                    else:
                        print(f"rung {i} @ step {pos - 1}: kept "
                              f"{len(keep)}/{n_before} members -> "
                              f"{lp.describe()}")
                if args.ckpt_every:
                    # force-save the COMPACTED state at the last COMPLETED step
                    # (pos-1 == the boundary step, except for catch-up prunes on
                    # a resume that was already past it), overwriting any
                    # cadence save of that step: the latest checkpoint always
                    # matches the live layout, so replay and --resume land on
                    # the new rung
                    save_population(args.ckpt_dir, pos - 1, params, lp,
                                    extra_state=opt_state,
                                    lifecycle=lifecycle_meta(),
                                    train_meta=train_meta)
                if args.serve_publish:
                    publish_live(params, lp)
        finally:
            if pf is not None:
                pf.close()
        dt = time.time() - t0

        steps_run = max(total - start, 0)
        if steps_run:
            loss0 = stats.get("first_loss", 0.0)
            loss = stats.get("last_loss", 0.0)
            member_steps = stats.get("member_steps",
                                     lp.num_real * steps_run)
            pop_desc = (f"{n0}->{lp.num_real}" if lp.num_real != n0
                        else f"{lp.num_real}")
            print(f"trained {pop_desc} MLPs × {steps_run} steps in "
                  f"{dt:.1f}s ({member_steps / max(dt, 1e-9):.0f} "
                  f"model-steps/s); loss {loss0:.4f} -> {loss:.4f}")
            if refill_mode != "off":
                # every id ever issued is a distinct model the search
                # visited — the bench's models-explored-per-second metric
                print(f"explored {next_id} models "
                      f"({stats.get('refilled', 0)} refilled) in {dt:.1f}s "
                      f"({next_id / max(dt, 1e-9):.2f} models/s); "
                      f"{stats.get('chunk_builds', 0)} chunk builds")
            if args.ckpt_every:
                # final checkpoint ONLY if the cadence didn't just write it
                # (steps % ckpt_every == 0 used to save the last step twice)
                saved = latest_steps(args.ckpt_dir)
                if not saved or saved[-1] != total - 1:
                    save_population(args.ckpt_dir, total - 1, params, lp,
                                    extra_state=opt_state,
                                    lifecycle=lifecycle_meta(),
                                    train_meta=train_meta)

        if args.serve_publish:
            # final refresh: the served set always matches the state the
            # run ended on (rung boundaries already published mid-ladder)
            publish_live(params, lp)

        losses, accs = evaluate_population(params, lp, xte_j, yte_j)
        print("leaderboard:")
        for row in leaderboard(lp, losses, accs, k=min(10, lp.num_real),
                               member_ids=member_ids,
                               lineage=lineage if refill_mode != "off"
                               else None):
            lin = ""
            if "lineage" in row:
                li = row["lineage"]
                lin = (f"  born r{li['born_rung']}"
                       + (f" of {li['parent']}" if li["parent"] >= 0
                          else " fresh" if li["born_rung"] else " seed"))
            print(f"  #{row['rank']:2d} member {row['member']:4d} "
                  f"hidden={row['hidden']} {row['activation']:11s} "
                  f"loss={row['loss']:.4f} acc={row['acc']:.3f}{lin}")
        return params, lp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="laptop-scale family config (smoke/CI)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--num-micro", type=int, default=1)
    ap.add_argument("--grad-clip", type=float, default=None,
                    help="global-norm gradient clip; LM default 1.0, "
                         "population default OFF (0 disables; when set, "
                         "the pre-clip norm is logged per step)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--straggler-timeout", type=float, default=1e9)
    # population-engine flags (kind == "population")
    ap.add_argument("--population-depths", default=None,
                    help='heterogeneous-depth spec, e.g. "64,32,16;13,5;7" '
                         "(members by ';', per-layer widths by ',')")
    ap.add_argument("--population-acts", default="relu",
                    help="comma list cycled over members, or 'paper' for "
                         "the ten paper activations")
    ap.add_argument("--population-repeats", type=int, default=1)
    ap.add_argument("--population-features", type=int, default=20)
    ap.add_argument("--population-classes", type=int, default=2)
    ap.add_argument("--population-block", type=int, default=8)
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--m3-impl", default="bucketed",
                    choices=["scatter", "onehot", "bucketed", "pallas"])
    ap.add_argument("--bd-impl", default="einsum",
                    choices=["einsum", "pallas", "fused"],
                    help="mid-layer projection: per-bucket einsum, the "
                         "block-diag Pallas kernel, or the FUSED kernel "
                         "(projection + bias + activation in one pass, "
                         "DESIGN.md §7)")
    ap.add_argument("--compute-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="mixed-precision policy: matmul operands in this "
                         "dtype, f32 accumulators/params/loss/eval "
                         "(DESIGN.md §7)")
    ap.add_argument("--rung-eval-batches", type=int, default=0,
                    help="halving rungs: evaluate only this many --batch-"
                         "sized eval batches at each rung boundary (0 = "
                         "full split; the FINAL leaderboard eval always "
                         "runs the full split) — successive halving only "
                         "needs rank fidelity at the cut line")
    ap.add_argument("--act-impl", default="sliced",
                    choices=["sliced", "masked", "pallas"],
                    help="per-layer activation dispatch: contiguous XLA "
                         "slices, branchless masking, or the seg_act "
                         "Pallas kernel")
    ap.add_argument("--scan-steps", type=int, default=8,
                    help="population path: optimizer steps fused into one "
                         "jitted lax.scan chunk (donated params, one "
                         "dispatch per chunk)")
    ap.add_argument("--pipeline", default="on", choices=["on", "off"],
                    help="population path: the streaming data plane "
                         "(DESIGN.md §11) — a producer thread stages the "
                         "NEXT chunk's batch slab into alternating host "
                         "buffers and device_puts it (sharded over 'data', "
                         "donated into the chunk) while the current chunk "
                         "runs, with per-chunk metric fetches deferred "
                         "until the next chunk is dispatched.  "
                         "Bit-identical trajectory to 'off' (the "
                         "synchronous build-then-dispatch loop)")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="--pipeline on: producer queue bound — how many "
                         "chunks the data plane may run ahead before "
                         "backpressure blocks it (2 = double buffering)")
    ap.add_argument("--serve-publish", action="store_true",
                    help="population path: refresh a PopulationServer "
                         "leaderboard (launch/serve_population.py) from "
                         "the LIVE run at every halving rung boundary and "
                         "after the final step, so the published member "
                         "set tracks the ladder")
    ap.add_argument("--per-member-lr", action="store_true",
                    help="paper §7: every member gets its own step size")
    ap.add_argument("--lr-schedule", default="constant",
                    choices=["constant", "warmup_cosine"],
                    help="population path: per-step LR multiplier threaded "
                         "through the scanned chunk as a carried global-"
                         "step counter (warmup over --warmup steps, cosine "
                         "decay to 10%% over --steps).  Composes with "
                         "--per-member-lr; 'constant' keeps the historical "
                         "schedule-free chunk bit-exact")
    ap.add_argument("--optimizer", default=None,
                    choices=["sgd", "momentum", "adamw", "adafactor"],
                    help="population path: the stateful-optimizer engine "
                         "(DESIGN.md §8).  sgd = the paper's plain SGD "
                         "(stateless, bit-exact vs the historical step); "
                         "momentum = SGD + heavy-ball momentum; "
                         "adamw / adafactor as in repro.optim.  Optimizer "
                         "state is born sharded, compacted through halving "
                         "rungs, checkpointed, and validated on --resume. "
                         "Default: the arch's optimizer (sgd for "
                         "parallelmlp)")
    ap.add_argument("--momentum", type=float, default=0.9,
                    help="--optimizer momentum: heavy-ball coefficient")
    ap.add_argument("--weight-decay", type=float, default=0.0,
                    help="--optimizer adamw/adafactor: decoupled weight "
                         "decay (population default 0 — the paper's task "
                         "has no regularisation)")
    ap.add_argument("--opt-state-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="--optimizer adamw: moment (m/v) storage dtype — "
                         "bfloat16 halves optimizer HBM; moment MATH stays "
                         "f32 either way (DESIGN.md §8)")
    ap.add_argument("--per-member-momentum", action="store_true",
                    help="--optimizer momentum: sample one heavy-ball "
                         "coefficient per member (uniform [0.5, 0.99], "
                         "drawn once over the original population like "
                         "--per-member-lr)")
    ap.add_argument("--per-member-weight-decay", action="store_true",
                    help="--optimizer adamw/adafactor: sample one decay "
                         "per member (log-uniform around --weight-decay)")
    ap.add_argument("--halving", default=None,
                    help='successive-halving rungs "STEP:KEEP,..." (e.g. '
                         '"500:0.5,1000:0.5,2000:0.25"): after each listed '
                         "global step, keep the best fraction of surviving "
                         "members and COMPACT the fused layout (rungs at or "
                         "past --steps never fire; resume with the same "
                         "spec to continue a ladder mid-run).  With "
                         "--optimizer adafactor, the factored v_row/v_col "
                         "statistics are re-initialised to zero per member "
                         "at each rung boundary (they reduce over the "
                         "fused hidden axis and cannot be gathered "
                         "member-major); momentum and the step count carry "
                         "over, and the second moment re-warms in "
                         "~1/(1-b2) steps (~100 at the default b2=0.99)")
    ap.add_argument("--refill", default="off",
                    choices=["off", "pbt", "arch"],
                    help="slot-refill search at --halving rung boundaries "
                         "(DESIGN.md §13): after pruning, refill the freed "
                         "slots instead of shrinking.  'pbt' holds the "
                         "population size constant — exploit/explore clones "
                         "of same-arch survivors with perturbed recipes "
                         "(fresh init when no arch matches); the layout "
                         "never changes, so the rung boundary is one "
                         "on-device gather/scatter with ZERO re-jit.  "
                         "'arch' samples fresh architectures from "
                         "--search-space and GROWS the layout (inverse of "
                         "compaction).  'off' (default) is the historical "
                         "halving driver, bit-identical")
    ap.add_argument("--search-space", default=None,
                    help="declarative search-space spec for --refill, "
                         "';'-separated, e.g. \"widths=64,32|16,8;"
                         "acts=relu,tanh;lr=0.3..3;momentum=0.5..0.99;"
                         "wd=0.3..3;lr_perturb=0.8,1.25;"
                         "momentum_jitter=0.05\".  Unset keys keep the "
                         "defaults, which reproduce the historical "
                         "hardcoded per-member ranges bit-for-bit")
    ap.add_argument("--refill-exploit-frac", type=float, default=0.5,
                    help="--refill pbt: truncation-selection fraction — "
                         "exploit clones draw uniformly from the best "
                         "FRAC of the slot-arch-matching survivors")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch, reduced=args.reduced)
    if arch.kind == "population":
        return run_population(arch, args)
    mesh = make_host_mesh()
    print(f"arch={args.arch} mesh={dict(mesh.shape)} "
          f"devices={len(jax.devices())}")
    if arch.kind in ("lm", "encdec"):
        run_lm(arch, args, mesh)
    else:
        raise SystemExit(f"unknown arch kind {arch.kind!r}")


if __name__ == "__main__":
    main()
