"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs REAL training at whatever scale the current device set supports
(reduced configs on CPU; the full configs on an actual pod — the code path
is identical, only the mesh differs).  Wires together:

  data (step-indexed, restart-safe) → train_step (jit, sharded) →
  TrainRunner (checkpoint/restart, straggler watchdog) → metrics log

Flags exercise every distributed feature: --compress-grads (int8 cross-pod
all-reduce), --ckpt-every / --resume, --population (the paper's fused
population training for LM population runs see examples/quickstart.py).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_steps, restore
from repro.configs import get_arch
from repro.data import TabularTask, TokenTask
from repro.distributed import TrainRunner, StragglerPolicy
from repro.distributed.sharding import logical_to_sharding
from repro.launch.cells import build_optimizer
from repro.launch.mesh import make_host_mesh
from repro.models import encdec, lm
from repro.optim import warmup_cosine


def _init_sharded(init_fn, specs_fn, mesh):
    """jit the initializer with out_shardings so parameters are BORN sharded
    (no host-side full materialisation)."""
    abs_p, specs = specs_fn()
    sh = logical_to_sharding(specs, mesh, abs_p)
    return jax.jit(init_fn, out_shardings=sh)(jax.random.PRNGKey(0)), sh


def run_lm(arch, args, mesh):
    cfg = arch.model
    is_encdec = arch.kind == "encdec"
    mod = encdec if is_encdec else lm
    with jax.set_mesh(mesh):
        params, p_sh = _init_sharded(
            lambda k: mod.init_params(k, cfg)[0],
            lambda: mod.abstract_params(cfg), mesh)
        opt = build_optimizer(arch)
        o_specs = opt.state_specs(mod.abstract_params(cfg)[1],
                                  mod.abstract_params(cfg)[0])
        abs_o = jax.eval_shape(opt.init, params)
        o_sh = logical_to_sharding(o_specs, mesh, abs_o)
        opt_state = jax.jit(opt.init, out_shardings=o_sh)(params)

        lr_fn = warmup_cosine(arch.lr, args.warmup, args.steps)
        step_fn_raw = mod.make_train_step(
            cfg, opt, lr_fn, num_micro=args.num_micro, mesh=mesh,
            grad_clip=args.grad_clip)
        jit_step = jax.jit(step_fn_raw, donate_argnums=(0, 1))

        task = TokenTask(vocab=cfg.vocab, seed=args.seed)

        def make_batch(step):
            b = task.batch(step, args.batch, args.seq)
            if is_encdec:
                rng = np.random.default_rng([args.seed, step])
                b["frames"] = rng.normal(
                    0, 1, (args.batch, args.seq, cfg.d_model)
                ).astype(np.float32)
            elif cfg.frontend == "embeds":
                rng = np.random.default_rng([args.seed, step])
                b["embeds"] = rng.normal(
                    0, 1, (args.batch, args.seq, cfg.d_model)
                ).astype(np.float32)
                del b["tokens"]
            return b

        state = {"params": params, "opt": opt_state}

        def step_fn(state, step):
            batch = make_batch(step)
            p, o, metrics = jit_step(state["params"], state["opt"], batch,
                                     jnp.asarray(step, jnp.int32))
            return {"params": p, "opt": o}, {
                k: float(v) for k, v in metrics.items()}

        runner = TrainRunner(
            step_fn, state, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
            straggler=StragglerPolicy(timeout_s=args.straggler_timeout))
        start = 0
        if args.resume and latest_steps(args.ckpt_dir):
            runner.state, last = restore(args.ckpt_dir, runner.state)
            start = last + 1
            print(f"resumed from step {last}")
        t0 = time.time()
        runner.run(args.steps, start_step=start)
        dt = time.time() - t0
        losses = [m["loss"] for _, m in runner.metrics_log]
        print(f"done: {len(losses)} steps in {dt:.1f}s; "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
        return runner


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="laptop-scale family config (smoke/CI)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--num-micro", type=int, default=1)
    ap.add_argument("--grad-clip", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--straggler-timeout", type=float, default=1e9)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch, reduced=args.reduced)
    mesh = make_host_mesh()
    print(f"arch={args.arch} mesh={dict(mesh.shape)} "
          f"devices={len(jax.devices())}")
    if arch.kind in ("lm", "encdec"):
        run_lm(arch, args, mesh)
    else:
        raise SystemExit("population training: use examples/quickstart.py")


if __name__ == "__main__":
    main()
