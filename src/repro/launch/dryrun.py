import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and extract the roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh single            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun                      # the full sweep

Per cell this prints/records:
  * compiled.memory_analysis()  — per-device bytes (proves it fits 16 GB)
  * compiled.cost_analysis()    — HLO flops / bytes accessed
  * collective payload bytes parsed from the post-SPMD HLO
  * the three roofline terms against TPU v5e constants
Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the framework — the sweep exits nonzero if any cell fails."""
# (no __future__ import: the XLA_FLAGS lines above must stay first)
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.compat import set_mesh
from repro.configs import ALL_ARCH_IDS, ALL_SHAPES, get_arch, shape
from repro.launch.cells import make_cell
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.hlo_stats import op_histogram
from repro.launch.mesh import make_production_mesh, mesh_num_devices

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link (≈ per-chip usable collective bw)


def roofline_terms(flops_per_dev, bytes_per_dev, coll_bytes_per_dev):
    return {
        "compute_s": flops_per_dev / PEAK_FLOPS,
        "memory_s": bytes_per_dev / HBM_BW,
        "collective_s": coll_bytes_per_dev / ICI_BW,
    }


def _decode_eff(cell, sh, chips, bytes_dev):
    if sh.kind != "decode" or not bytes_dev:
        return None
    ideal = (2.0 * cell.meta.get("active_params", 0)
             + cell.meta.get("kv_cache_bytes", 0)) / chips
    return ideal / bytes_dev


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False) -> dict:
    arch = get_arch(arch_id)
    sh = shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_num_devices(mesh)
    t0 = time.time()
    with set_mesh(mesh):
        cell = make_cell(arch, sh, mesh)
        lowered = cell.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        from repro.compat import cost_analysis_dict
        cost = cost_analysis_dict(compiled)
        hlo = compiled.as_text()
    # loop-aware static profile (XLA's cost_analysis counts while bodies
    # once — see hlo_cost.py); raw XLA numbers kept for reference
    prof = hlo_analyze(hlo)
    flops_dev = float(prof["flops"])
    bytes_dev = float(prof["hbm_bytes"])
    coll_dev = float(prof["total_collective_bytes"])
    terms = roofline_terms(flops_dev, bytes_dev, coll_dev)
    dominant = max(terms, key=terms.get)
    model_flops_dev = cell.model_flops / chips
    out = {
        "cell": cell.name,
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes),
        },
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective": {
            "per_device_bytes": prof["collective_bytes"],
            "counts": prof["collective_count"],
            "total_per_device_bytes": coll_dev,
        },
        "loops": prof["loops"],
        "xla_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "roofline": terms,
        "dominant": dominant,
        "model_flops_total": cell.model_flops,
        "model_flops_per_dev": model_flops_dev,
        "useful_flops_ratio": (model_flops_dev / flops_dev
                               if flops_dev else 0.0),
        "roofline_fraction": (model_flops_dev / PEAK_FLOPS
                              / max(sum(terms.values()), 1e-30)),
        "bound_fraction": (model_flops_dev / PEAK_FLOPS
                           / max(max(terms.values()), 1e-30)),
        "meta": cell.meta,
        # decode cells are HBM-bound by construction (one token against
        # params+cache); the honest efficiency metric is ideal-read-time /
        # modelled-memory-time, not a flops fraction
        "decode_mem_efficiency": _decode_eff(cell, sh, chips, bytes_dev),
        "op_histogram": op_histogram(hlo),
    }
    if keep_hlo:
        out["hlo_text"] = hlo
    return out


def cell_list(archs, shapes):
    for aid in archs:
        arch = get_arch(aid)
        for s in shapes:
            if arch.runs(s):
                yield aid, s
            else:
                yield aid, s  # skipped cells are still reported


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default=None, help="results dir (JSON per cell)")
    ap.add_argument("--hlo-dir", default=None, help="dump compiled HLO here")
    args = ap.parse_args(argv)

    archs = list(ALL_ARCH_IDS) if args.all else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for aid in archs:
        arch = get_arch(aid)
        for sname in shapes:
            if not arch.runs(sname):
                rec = {"cell": f"{aid}:{sname}", "arch": aid, "shape": sname,
                       "status": "skipped", "reason": arch.skip_reason}
                print(f"[skip] {aid}:{sname} — {arch.skip_reason}")
                if args.out:
                    _write(args.out, f"{aid}_{sname}_skip.json", rec)
                continue
            for mp in meshes:
                tag = "2x16x16" if mp else "16x16"
                label = f"{aid}:{sname}:{tag}"
                try:
                    rec = run_cell(aid, sname, mp, keep_hlo=bool(args.hlo_dir))
                    rec["status"] = "ok"
                    if args.hlo_dir:
                        hlo = rec.pop("hlo_text")
                        os.makedirs(args.hlo_dir, exist_ok=True)
                        with open(os.path.join(
                                args.hlo_dir,
                                f"{aid}_{sname}_{tag}.hlo"), "w") as f:
                            f.write(hlo)
                    peak_gb = rec["memory"]["peak_bytes"] / 2**30
                    print(f"[ok]   {label}  compile={rec['compile_s']:.0f}s "
                          f"peak={peak_gb:.2f}GiB "
                          f"dom={rec['dominant']} "
                          f"roofline={rec['roofline_fraction']:.3f}")
                    sys.stdout.flush()
                except Exception as e:  # noqa: BLE001
                    failures.append(label)
                    rec = {"cell": label, "arch": aid, "shape": sname,
                           "mesh": tag, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    print(f"[FAIL] {label}: {type(e).__name__}: {e}")
                    sys.stdout.flush()
                if args.out:
                    _write(args.out, f"{aid}_{sname}_{tag}.json", rec)
    if failures:
        print(f"\n{len(failures)} FAILED cells: {failures}")
        return 1
    return 0


def _write(outdir, name, rec):
    os.makedirs(outdir, exist_ok=True)
    rec = dict(rec)
    rec.pop("hlo_text", None)
    with open(os.path.join(outdir, name.replace(":", "_")), "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    sys.exit(main())
