"""Population serving engine: batched ensemble inference (DESIGN.md §10).

``python -m repro.launch.serve_population --ckpt-dir /tmp/pop_ck_fused``

The population counterpart of ``launch/serve.py``'s prefill/decode driver.
Request lifecycle:

  1. requests land in a HOST staging buffer (two of them, alternating, so
     requests for flush k+1 stage while flush k's device slab is in flight);
  2. the buffer flushes to device when it fills to ``batch`` — or when the
     max-latency timer for its oldest request fires first (a partial slab,
     zero-padded to keep the jit cache at one entry per mode);
  3. ONE jitted step per ensemble mode runs the forward-only fused path
     (``deep.forward(infer=True)``: depth+1 launches, no residuals, the
     request slab DONATED so XLA reuses its device buffer across flushes)
     and reduces the (B, P, O) member outputs on device
     (``core.ensemble``): best-member routing, top-k soft-vote, or
     all-members soft-vote, each with disagreement uncertainty;
  4. per-request latency = flush wait + step wall; the driver reports
     p50/p99 and req/s per mode (BENCH_serve.json rows).

The served member set comes from ``selection.leaderboard`` over a
calibration split evaluated with the SAME infer-path kernels
(``publish``): rank-0 becomes ``best1``'s route, the top-k slots become
``topk``'s vote — refreshing it mid-training at rung boundaries is just
calling ``publish`` again.  Shard-pad fillers can never be published or
reduced over (``core.ensemble`` validates; regression in
tests/test_infer_path.py).
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.core.ensemble import ENSEMBLE_MODES, ensemble_predict, real_slots
from repro.core.selection import evaluate_population, leaderboard
from repro.launch.launch_count import (count_pallas_launches,
                                       fused_infer_budget, max_eqn_outputs)


class PopulationServer:
    """Batched ensemble serving over a trained (possibly sharded)
    population.  ``modes``: any of ``("best1", "topk", "all")``."""

    def __init__(self, params, layout, *, mesh=None, bd_impl: str = "fused",
                 act_impl: str = "pallas", compute_dtype=None,
                 weights_dtype=None, batch: int = 32, topk: int = 4,
                 max_latency_ms: float = 5.0):
        self.params = params
        self.layout = layout
        self.mesh = mesh
        self.batch = int(batch)
        self.topk = int(topk)
        self.max_latency_ms = float(max_latency_ms)
        self.weights_dtype = weights_dtype
        self._fw = dict(bd_impl=bd_impl, act_impl=act_impl,
                        compute_dtype=compute_dtype, infer=True)
        if weights_dtype is not None:
            self._fw["weights_dtype"] = weights_dtype
        # int8 serve copy (DESIGN.md §12): built lazily, once, from the
        # restored/refreshed master weights — after that the server holds
        # ONLY the quantized tree (the f32 masters are released)
        self._quantized = weights_dtype is None
        # donated double buffers: two host staging slabs alternate so the
        # next flush stages while the previous device slab is in flight,
        # and the device copy is donated into the jitted step
        self._host = [np.zeros((self.batch, layout.in_features), np.float32)
                      for _ in range(2)]
        self._flip = 0
        self._steps: dict[str, object] = {}
        self.board = None
        self.published: dict = {"all": None}

    # ----------------------------------------------------------------- #
    # published member set                                              #
    # ----------------------------------------------------------------- #

    def refresh(self, params, layout):
        """Re-target the server at a LIVE training run's current state —
        the rung-boundary driver hook (launch/train.py --serve-publish).
        Halving compaction changes the layout (member count, fused width),
        so everything keyed on it resets: the per-mode jit cache (layouts
        are jit constants), the leaderboard and published sets (old member
        slots no longer exist), and the host staging slabs if the feature
        width changed.  Call :meth:`publish` after to re-derive the served
        member set on the new population."""
        if layout.in_features != self.layout.in_features:
            self._host = [
                np.zeros((self.batch, layout.in_features), np.float32)
                for _ in range(2)]
        self.params = params
        self.layout = layout
        # a halving rung may shrink the population below the served top-k
        self.topk = max(1, min(self.topk, real_slots(layout)))
        self._steps.clear()
        self.board = None
        self.published = {"all": None}
        self._quantized = self.weights_dtype is None   # re-quantize fresh
        return self

    def _ensure_quantized(self):
        """Replace the master weights with the int8 serve copy, once per
        refresh — every consumer of ``self.params`` (publish, the per-mode
        steps, check_budget) funnels through here, so after the first call
        the server never holds an f32/bf16 weight copy again."""
        if self._quantized:
            return
        from repro.quant import quantize_population
        self.params = jax.block_until_ready(
            jax.jit(quantize_population, static_argnums=1)(
                self.params, self.layout))
        self._quantized = True

    def publish(self, x_calib, y_calib, task: str = "classification",
                sort_by: str = "loss"):
        """Refresh the served member set from a leaderboard over a
        calibration split — scored with the SAME forward-only kernels the
        serve steps run (under ``weights_dtype="int8"`` that includes the
        fused-dequant kernels, so the board ranks what is actually
        served).  Returns the leaderboard rows."""
        self._ensure_quantized()
        losses, accs = evaluate_population(
            self.params, self.layout, x_calib, y_calib, task=task,
            **self._fw)
        k = max(self.topk, 1)
        self.board = leaderboard(self.layout, losses, accs, k=k,
                                 sort_by=sort_by)
        self.published = {
            "best1": [self.board[0]["slot"]],
            "topk": [r["slot"] for r in self.board[:self.topk]],
            "all": None,                  # every real member, sliced on device
        }
        self._steps.clear()               # member sets are jit constants
        return self.board

    # ----------------------------------------------------------------- #
    # jitted per-mode step                                              #
    # ----------------------------------------------------------------- #

    def _step(self, mode: str):
        if mode not in ENSEMBLE_MODES:
            raise ValueError(f"unknown mode {mode!r} (have {ENSEMBLE_MODES})")
        if mode not in self._steps:
            if mode != "all" and mode not in self.published:
                raise ValueError(f"mode {mode!r} needs a published member "
                                 "set — call publish() first")
            self._ensure_quantized()
            ids = self.published.get(mode)
            lp, fw = self.layout, self._fw

            def step(params, xb):
                from repro.core.deep import forward
                logits = forward(params, xb, lp, **fw)
                return ensemble_predict(logits, lp, mode, member_ids=ids,
                                        with_uncertainty=True)

            self._steps[mode] = jax.jit(step, donate_argnums=(1,))
        return self._steps[mode]

    # ----------------------------------------------------------------- #
    # request loop                                                      #
    # ----------------------------------------------------------------- #

    def run(self, xs, mode: str = "all", warmup: bool = True) -> dict:
        """Serve ``xs`` (N, F) through the batching loop → per-request
        predictions + latency stats.  Closed-loop: all requests are queued
        at t=0, so full slabs flush on fill and only the trailing partial
        slab flushes on its max-latency timer (its requests pay that wait
        in their recorded latency).  ``warmup`` runs one zero slab before
        the clock starts so p50/p99 measure serving, not compilation."""
        step = self._step(mode)
        n = int(xs.shape[0])
        xs = np.asarray(xs, np.float32)
        lat = np.zeros(n)
        preds = np.zeros(n, np.int64)
        unc = np.zeros(n, np.float32)
        if warmup:
            jax.block_until_ready(step(
                self.params,
                jnp.zeros((self.batch, self.layout.in_features),
                          jnp.float32))["pred"])
        t0 = time.perf_counter()
        i = 0
        while i < n:
            nb = min(self.batch, n - i)
            buf = self._host[self._flip]
            self._flip ^= 1
            buf[:nb] = xs[i:i + nb]
            if nb < self.batch:               # max-latency flush: timer fired
                buf[nb:] = 0.0
            out = step(self.params, jnp.asarray(buf))
            pred = np.asarray(
                jax.block_until_ready(out["pred"]))[:nb]
            mi = np.asarray(out["mutual_information"])[:nb]
            done = time.perf_counter() - t0
            # every request in the slab completes at the flush's done time;
            # a timer-fired partial slab waited out max_latency first
            lat[i:i + nb] = done + (self.max_latency_ms / 1e3
                                    if nb < self.batch else 0.0)
            preds[i:i + nb] = pred
            unc[i:i + nb] = mi
            i += nb
        wall = time.perf_counter() - t0
        return {
            "mode": mode,
            "members_served": (real_slots(self.layout)
                               if self.published.get(mode) is None
                               else len(self.published[mode])),
            "requests": n,
            "pred": preds,
            "mutual_information": unc,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "req_per_s": n / max(wall, 1e-9),
            "wall_s": wall,
        }

    # ----------------------------------------------------------------- #
    # invariants                                                        #
    # ----------------------------------------------------------------- #

    def check_budget(self):
        """Loud-fail §10 invariants on the traced serve forward: exactly
        depth+1 Pallas launches and every one single-output (no residual
        buffers can exist in a serving program)."""
        self._ensure_quantized()
        lp, fw = self.layout, self._fw
        xb = jnp.zeros((self.batch, lp.in_features), jnp.float32)

        def fwd(params):
            from repro.core.deep import forward
            return forward(params, xb, lp, **fw)

        budget = fused_infer_budget(lp.depth)
        got = count_pallas_launches(fwd, self.params)
        if got != budget["total"]:
            raise SystemExit(f"serve forward dispatches {got} launches, "
                             f"budget is {budget['total']} (depth+1)")
        worst = max_eqn_outputs(fwd, self.params)
        if worst > 1:
            raise SystemExit(f"serve forward emits a {worst}-output "
                             "pallas_call — a residual buffer survived")
        return {"launches": got, "budget": budget["total"]}

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, step: int | None = None,
                        mesh=None, **kw):
        from repro.checkpoint.checkpoint import restore_population
        params, layout, step = restore_population(ckpt_dir, step=step,
                                                  mesh=mesh)
        return cls(params, layout, mesh=mesh, **kw), step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--step", type=int, default=None)
    ap.add_argument("--modes", nargs="+", default=list(ENSEMBLE_MODES),
                    choices=list(ENSEMBLE_MODES))
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--topk", type=int, default=4)
    ap.add_argument("--max-latency-ms", type=float, default=5.0)
    ap.add_argument("--calib-samples", type=int, default=512)
    ap.add_argument("--sharded", action="store_true",
                    help="restore + serve on the host mesh (population "
                    "axis sharded across devices)")
    ap.add_argument("--bd-impl", default="fused")
    ap.add_argument("--act-impl", default="pallas")
    ap.add_argument("--compute-dtype", default=None)
    ap.add_argument("--weights-dtype", default=None, choices=["int8"],
                    help="int8: quantize the restored weights once "
                    "(quant.quantize_population) and serve ONLY the int8 "
                    "copy through the fused-dequant kernels — ~4x params "
                    "HBM vs f32 (DESIGN.md §12)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    mesh = None
    if args.sharded:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    server, step = PopulationServer.from_checkpoint(
        args.ckpt_dir, step=args.step, mesh=mesh, batch=args.batch,
        topk=args.topk, max_latency_ms=args.max_latency_ms,
        bd_impl=args.bd_impl, act_impl=args.act_impl,
        compute_dtype=args.compute_dtype,
        weights_dtype=args.weights_dtype)
    lp = server.layout
    print(f"restored step {step}: {real_slots(lp)} members "
          f"(+{lp.num_members - real_slots(lp)} fillers), "
          f"F={lp.in_features} O={lp.out_features} depth={lp.depth}")

    from repro.data.synthetic import TabularTask
    task = TabularTask(args.calib_samples + args.requests, lp.in_features,
                       n_classes=lp.out_features, seed=0)
    (xc, yc), (xr, _) = task.split(
        frac=args.calib_samples / (args.calib_samples + args.requests))

    with (set_mesh(mesh) if mesh is not None
          else contextlib.nullcontext()):
        if args.bd_impl == "fused":
            print("launch budget:", server.check_budget())
        board = server.publish(xc, yc)
        print(f"published: best1={server.published['best1']} "
              f"topk={server.published['topk']}")
        for row in board[:3]:
            print("  ", row)
        results = {}
        for mode in args.modes:
            r = server.run(xr[:args.requests], mode)
            results[mode] = {k: v for k, v in r.items()
                             if k not in ("pred", "mutual_information")}
            print(f"{mode:6s} members={r['members_served']:3d} "
                  f"p50={r['p50_ms']:.2f}ms p99={r['p99_ms']:.2f}ms "
                  f"{r['req_per_s']:.0f} req/s")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"step": step, "board": board, "serve": results}, f,
                      indent=2, default=str)
        print("wrote", args.json_out)


if __name__ == "__main__":
    main()
