"""Serving driver: batched prefill + decode.

``python -m repro.launch.serve --arch qwen3-1.7b --reduced --tokens 64``

Implements the standard two-phase inference flow: prefill the prompt batch
(builds ring-buffer KV caches / SSM states), then step the greedy decode
loop under jit with donated caches.  At full scale the same code lowers
onto the production mesh (decode cells of the dry-run ARE this serve_step).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs import get_arch
from repro.distributed.sharding import logical_to_sharding
from repro.launch.mesh import make_host_mesh
from repro.models import encdec, lm


def generate_lm(arch, prompts, max_new: int, mesh, greedy: bool = True,
                temperature: float = 1.0, seed: int = 0):
    """prompts: (B, S) int32 -> (B, S+max_new) tokens + timing dict."""
    cfg = arch.model
    with set_mesh(mesh):
        params, _ = lm.init_params(jax.random.PRNGKey(0), cfg)
        b, s = prompts.shape
        max_len = s + max_new
        t0 = time.time()
        logits, caches = jax.jit(
            lambda p, t: lm.prefill(p, cfg, {"tokens": t}, max_len=max_len,
                                    mesh=mesh))(params, prompts)
        t_prefill = time.time() - t0
        serve_step = jax.jit(lm.make_serve_step(cfg, mesh),
                             donate_argnums=(1,))
        out = [prompts]
        key = jax.random.PRNGKey(seed)
        tok = _pick(logits, greedy, temperature, key)
        t0 = time.time()
        for i in range(max_new):
            out.append(tok)
            if i == max_new - 1:
                break
            pos = jnp.full((b,), s + i, jnp.int32)
            logits, caches = serve_step(params, caches, {"tokens": tok}, pos)
            key, sub = jax.random.split(key)
            tok = _pick(logits, greedy, temperature, sub)
        t_decode = time.time() - t0
        tokens = jnp.concatenate(out, axis=1)
        return tokens, {"prefill_s": t_prefill, "decode_s": t_decode,
                        "tok_per_s": b * max_new / max(t_decode, 1e-9)}


def _pick(logits, greedy, temperature, key):
    if greedy:
        return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    p = logits[:, -1] / temperature
    return jax.random.categorical(key, p, axis=-1)[:, None].astype(jnp.int32)


def generate_encdec(arch, frames, max_new: int, mesh, seed: int = 0):
    cfg = arch.model
    with set_mesh(mesh):
        params, _ = encdec.init_params(jax.random.PRNGKey(0), cfg)
        b = frames.shape[0]
        t0 = time.time()
        caches = jax.jit(
            lambda p, f: encdec.prepare_serve_caches(
                p, cfg, f, max_len=max_new))(params, frames)
        t_prefill = time.time() - t0
        serve_step = jax.jit(encdec.make_serve_step(cfg, mesh),
                             donate_argnums=(1,))
        tok = jnp.zeros((b, 1), jnp.int32)        # BOS
        out = []
        t0 = time.time()
        for i in range(max_new):
            out.append(tok)
            logits, caches = serve_step(params, caches, {"tokens": tok},
                                        jnp.full((b,), i, jnp.int32))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_decode = time.time() - t0
        return jnp.concatenate(out, axis=1), {
            "prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": b * max_new / max(t_decode, 1e-9)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--sample", action="store_true")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch, reduced=args.reduced)
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    if arch.kind == "encdec":
        frames = rng.normal(0, 1, (args.batch, args.prompt_len,
                                   arch.model.d_model)).astype(np.float32)
        toks, stats = generate_encdec(arch, jnp.asarray(frames), args.tokens,
                                      mesh)
    else:
        prompts = jnp.asarray(rng.integers(
            0, arch.model.vocab, (args.batch, args.prompt_len)), jnp.int32)
        toks, stats = generate_lm(arch, prompts, args.tokens, mesh,
                                  greedy=not args.sample)
    print(f"generated {toks.shape} tokens; {stats}")
    print(np.asarray(toks[:2, -16:]))


if __name__ == "__main__":
    main()
