"""Launch layer: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: repro.launch.dryrun pins XLA_FLAGS at import — import it only in a
dedicated process (python -m repro.launch.dryrun); everything else here is
import-safe."""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
