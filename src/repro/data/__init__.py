"""Deterministic synthetic data pipelines (restart-safe, step-indexed)."""
from repro.data.pipeline import (DeferredMetrics, PrefetchError,
                                 Prefetcher, staging_signature)
from repro.data.synthetic import TabularTask, TokenTask, lm_batch

__all__ = ["TabularTask", "TokenTask", "lm_batch",
           "Prefetcher", "PrefetchError", "DeferredMetrics",
           "staging_signature"]
