"""Synthetic data substrate.

Two generators:

  * ``TabularTask`` — the paper's controlled datasets (§4.3): N samples ×
    F features, Gaussian cluster-per-class with class-dependent means, so
    MLPs of different capacity separate measurably.  Deterministic in seed.

  * ``TokenTask`` — LM token streams for the assigned architectures: a
    fixed-seed Markov-ish stream (nontrivial bigram structure so loss
    actually falls during the end-to-end examples).

Batching is STEP-INDEXED: ``batch(step)`` is a pure function of
(seed, step), so a restarted/elastically-rescaled job consumes identical
data without any iterator state in the checkpoint — the fault-tolerance
design's data half (DESIGN.md §5)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TabularTask:
    n_samples: int
    n_features: int
    n_classes: int = 2
    seed: int = 0
    noise: float = 1.0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # class means on a scaled simplex + random rotation → linearly
        # separable-ish but benefits from nonlinearity via noise mixing
        means = rng.normal(0, 2.0, (self.n_classes, self.n_features))
        rot = np.linalg.qr(rng.normal(
            0, 1, (self.n_features, self.n_features)))[0]
        y = rng.integers(0, self.n_classes, self.n_samples)
        x = means[y] + self.noise * rng.normal(
            0, 1, (self.n_samples, self.n_features))
        x = (x @ rot).astype(np.float32)
        # nonlinear warp so identity-activation members underfit
        x[:, ::2] = np.tanh(x[:, ::2])
        self.x, self.y = x, y.astype(np.int32)

    def batch(self, step: int, batch_size: int):
        """Deterministic without-replacement epoch shuffling by step index."""
        n = self.n_samples
        per_epoch = max(n // batch_size, 1)
        epoch, k = divmod(step, per_epoch)
        order = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch])).permutation(n)
        idx = order[(k * batch_size) % n: (k * batch_size) % n + batch_size]
        if len(idx) < batch_size:  # wrap
            idx = np.concatenate([idx, order[:batch_size - len(idx)]])
        return self.x[idx], self.y[idx]

    def batch_slab(self, start: int, n_steps: int, batch_size: int,
                   out=None):
        """``n_steps`` consecutive batches as one ``(n_steps, ...)`` slab —
        VALUE-IDENTICAL to stacking ``batch(step)`` for ``step`` in
        ``[start, start + n_steps)`` (tests/test_pipeline.py pins this).

        This is the §11 producer-granularity build: ``batch`` must stay a
        pure random-access function of ``step``, so every call re-derives
        its epoch's n-sample permutation; a slab builder knows its steps
        are consecutive and derives each epoch order ONCE (single-entry
        cache, so consecutive slabs inside one epoch pay only the row
        gathers).  ``out=(xs, ys)`` writes into caller-owned staging
        buffers (the prefetcher's alternating pair) instead of
        allocating."""
        n = self.n_samples
        per_epoch = max(n // batch_size, 1)
        if out is not None:
            xs, ys = out
        else:
            xs = np.empty((n_steps, batch_size, self.n_features), np.float32)
            ys = np.empty((n_steps, batch_size), np.int32)
        for j in range(n_steps):
            epoch, k = divmod(start + j, per_epoch)
            cached = getattr(self, "_epoch_order", None)
            if cached is None or cached[0] != epoch:
                cached = (epoch, np.random.default_rng(
                    np.random.SeedSequence([self.seed, epoch])).permutation(n))
                self._epoch_order = cached
            order = cached[1]
            idx = order[(k * batch_size) % n: (k * batch_size) % n
                        + batch_size]
            if len(idx) < batch_size:  # wrap, as batch() does
                idx = np.concatenate([idx, order[:batch_size - len(idx)]])
            xs[j], ys[j] = self.x[idx], self.y[idx]
        return xs, ys

    def split(self, frac: float = 0.8):
        k = int(self.n_samples * frac)
        return (self.x[:k], self.y[:k]), (self.x[k:], self.y[k:])


@dataclasses.dataclass
class TokenTask:
    vocab: int
    seed: int = 0
    order: int = 1          # bigram structure strength

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse-ish bigram preference table (vocab capped for the table)
        v = min(self.vocab, 4096)
        self._v = v
        self._jump = rng.integers(1, v - 1, size=v)

    def batch(self, step: int, batch_size: int, seq_len: int):
        """tokens[t+1] is a deterministic function of tokens[t] with noise —
        learnable structure, pure function of (seed, step)."""
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        v = self._v
        toks = np.empty((batch_size, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, batch_size)
        noise = rng.random((batch_size, seq_len)) < 0.15
        rand = rng.integers(0, v, (batch_size, seq_len))
        for t in range(seq_len):
            nxt = (toks[:, t] + self._jump[toks[:, t] % v]) % v
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def lm_batch(task: TokenTask, step: int, batch_size: int, seq_len: int):
    return task.batch(step, batch_size, seq_len)
