"""Streaming data plane: double-buffered async host->device prefetch
(DESIGN.md §11).

The population train loop is device-bound arithmetic wrapped in host-bound
glue: every scan chunk waits while the driver generates batches, stacks
them, and ``device_put``s the slab, and every per-chunk metric fetch
(``np.asarray`` on per-member losses / grad norms) drains the dispatch
pipeline before the next chunk can launch.  "On the Performance of Network
Parallel Training in Artificial Neural Networks" (PAPERS.md) measures
exactly this failure mode — data movement, not FLOPs, bounding parallel
ANN training.  This module closes the seam with two pieces:

  * :class:`Prefetcher` — a background producer thread that materialises
    the NEXT chunk's ``(scan_steps, B, ...)`` batch slab into one of two
    alternating host staging buffers and ``device_put``s it (sharded by
    ``distributed.sharding.population_batch_shardings``) while the current
    chunk executes on device.  The promoted, reusable form of the
    double-buffer pattern ``launch.serve_population.PopulationServer``
    already uses for request slabs.  A bounded queue (default depth 2 —
    double buffering) gives backpressure; ``seek`` re-synchronises after a
    crash replay; ``retarget`` flushes and re-aims the producer when a
    halving rung boundary re-shard-pads the layout and re-jits the chunk;
    ``close`` shuts the thread down even when it is blocked mid-``put``.
    Producer exceptions are captured and re-raised on the consumer thread
    (``get``) — a dead producer can never hang the train loop.

  * :class:`DeferredMetrics` — a chunk's metrics as a lazy mapping over
    the live device arrays: the host transfer happens on FIRST ACCESS, so
    the driver resolves chunk N's metrics after chunk N+1 is already
    dispatched and the device queue never drains for a ``float()``.

Bit-exactness contract: the prefetcher changes WHEN a batch is built and
copied, never WHAT is built — ``produce(chunk_idx, staging)`` is required
to be a pure function of the chunk index (the repo's step-indexed data
rule), so a pipelined run's trajectory is bit-identical to the synchronous
driver's (tests/test_pipeline.py)."""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Mapping, Optional


class PrefetchError(RuntimeError):
    """Producer-thread failure, re-raised on the consumer thread with the
    original exception chained (``raise ... from err``)."""


def staging_signature(staging):
    """Shape/dtype signature of a staging buffer — nested tuples mirroring
    the buffer's structure with each numpy array replaced by
    ``(shape, dtype.str)``.  This is the equality key
    :meth:`Prefetcher.retarget` uses to decide whether the existing
    staging buffers can be REUSED across a rung boundary (constant-
    population refill keeps every slab shape identical) instead of being
    discarded and reallocated; callers that know the next segment's shapes
    can build the signature by hand without allocating anything."""
    if staging is None:
        return None
    if isinstance(staging, (tuple, list)):
        return tuple(staging_signature(s) for s in staging)
    if not (hasattr(staging, "shape") and hasattr(staging, "dtype")):
        # non-array leaf (e.g. a test double): opaque by type — never
        # claims shape equality, so retarget falls back to a rebuild
        return ("opaque", type(staging).__name__)
    import numpy as np
    return (tuple(staging.shape), np.dtype(staging.dtype).str)


class DeferredMetrics(Mapping):
    """A metrics dict whose values stay on device until first access.

    ``resolve()`` is called once, lazily; its result (a plain dict) is
    cached.  Everything mapping-like (``metrics["loss"]``, ``dict(m)``,
    iteration, ``len``) forces resolution — so code that stores the object
    (``TrainRunner.metrics_log``) costs nothing, and code that reads it
    pays one host sync at read time, ideally after the NEXT chunk is in
    flight."""

    __slots__ = ("_resolve", "_value")

    def __init__(self, resolve: Callable[[], dict]):
        self._resolve = resolve
        self._value: Optional[dict] = None

    @property
    def resolved(self) -> bool:
        return self._value is not None

    def force(self) -> dict:
        if self._value is None:
            self._value = dict(self._resolve())
        return self._value

    def __getitem__(self, key):
        return self.force()[key]

    def __iter__(self) -> Iterator:
        return iter(self.force())

    def __len__(self) -> int:
        return len(self.force())

    def __repr__(self) -> str:
        if self._value is None:
            return "DeferredMetrics(<unresolved>)"
        return f"DeferredMetrics({self._value!r})"


class Prefetcher:
    """Bounded async producer of per-chunk device slabs.

    Parameters
    ----------
    produce : ``(chunk_idx, staging) -> slab``
        Runs ON THE PRODUCER THREAD.  Builds chunk ``chunk_idx``'s batches
        into ``staging`` (one of two alternating host buffers from
        ``make_staging``, or ``None``) and returns the device slab —
        typically the ``jax.device_put(..., sharding)`` of the staged
        arrays.  Must be a pure function of ``chunk_idx`` (step-indexed
        data) so replays and the synchronous path agree bit-for-bit.
    n_chunks : total chunks in the current target (exclusive end).
    make_staging : optional zero-arg factory for ONE host staging buffer;
        called twice so consecutive chunks alternate buffers — chunk k+1
        stages while chunk k's device slab is still in flight.  ALIASING
        RULE: a sharded ``jax.device_put`` of a numpy array may ZERO-COPY
        alias its memory (the jax CPU backend does), so ``produce`` must
        never hand a staging buffer itself to the device — snapshot the
        staged region (``np.array``) and device_put the snapshot, which
        nothing ever writes again (DESIGN.md §11).
    depth : queue bound (default 2 = double buffering): the producer runs
        at most ``depth`` chunks ahead, then blocks (backpressure) until
        the consumer drains one.
    """

    _END = object()

    def __init__(self, produce: Callable[[int, Any], Any], n_chunks: int,
                 *, make_staging: Optional[Callable[[], Any]] = None,
                 depth: int = 2, start: int = 0, name: str = "prefetch"):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._depth = depth
        self._name = name
        self._produce = produce
        self._make_staging = make_staging
        self._staging = ([make_staging(), make_staging()]
                         if make_staging else [None, None])
        self._signature = staging_signature(self._staging[0])
        self._n_chunks = int(n_chunks)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None
        self._next = int(start)          # next chunk the consumer expects
        self._start_thread(int(start))

    # ----------------------------------------------------------------- #
    # producer                                                          #
    # ----------------------------------------------------------------- #

    def _start_thread(self, start: int):
        self._stop.clear()
        self._error = None
        self._q = queue.Queue(maxsize=self._depth)
        self._thread = threading.Thread(
            target=self._run, args=(start,), daemon=True, name=self._name)
        self._thread.start()

    def _run(self, start: int):
        flip = 0
        try:
            for c in range(start, self._n_chunks):
                if self._stop.is_set():
                    return
                slab = self._produce(c, self._staging[flip])
                flip ^= 1
                if not self._put((c, slab)):
                    return
            self._put(self._END)
        except BaseException as e:       # noqa: BLE001 — surface on get()
            self._error = e
            self._put(self._END)

    def _put(self, item) -> bool:
        """Bounded put with condition-variable backpressure: a blocked
        producer parks on the queue's internal ``not_full`` condition and
        wakes IMMEDIATELY when the consumer ``get``s a slab (no polling
        interval — tests assert <10 ms).  ``close``/``retarget`` unblock a
        full-queue put the same way: ``_halt`` sets the stop flag and then
        drains the queue, each drained item notifying ``not_full``; the
        post-wake stop check discards the stale hand-off (the queue object
        is rebuilt on restart, so a raced-in item can never leak into the
        next target's stream)."""
        if self._stop.is_set():
            return False
        self._q.put(item)
        return not self._stop.is_set()

    # ----------------------------------------------------------------- #
    # consumer                                                          #
    # ----------------------------------------------------------------- #

    def get(self, chunk_idx: int, timeout: float = 600.0):
        """The device slab for ``chunk_idx``.  Consecutive calls must walk
        the chunk range in order; an out-of-order index (a crash replay
        restarting mid-segment, or a resume skipping ahead) triggers an
        implicit :meth:`seek` — queued slabs for the abandoned position are
        discarded and the producer restarts at ``chunk_idx``."""
        if chunk_idx != self._next:
            self.seek(chunk_idx)
        deadline = timeout
        while True:
            try:
                item = self._q.get(timeout=min(deadline, 0.5))
            except queue.Empty:
                deadline -= 0.5
                if self._error is not None:
                    self._raise()
                if not self._thread.is_alive():
                    raise PrefetchError(
                        f"{self._name}: producer thread died without "
                        f"delivering chunk {chunk_idx}")
                if deadline <= 0:
                    raise TimeoutError(
                        f"{self._name}: chunk {chunk_idx} not produced "
                        f"within {timeout}s")
                continue
            if item is self._END:
                if self._error is not None:
                    self._raise()
                raise PrefetchError(
                    f"{self._name}: chunk {chunk_idx} requested past the "
                    f"end of the target ({self._n_chunks} chunks)")
            c, slab = item
            if c != chunk_idx:           # stale slab from before a seek
                continue
            self._next = chunk_idx + 1
            return slab

    def _raise(self):
        err = self._error
        raise PrefetchError(
            f"{self._name}: producer thread failed while building a "
            f"batch slab: {err!r}") from err

    def seek(self, chunk_idx: int):
        """Flush and restart the producer at ``chunk_idx`` (crash-replay
        re-synchronisation: ``TrainRunner`` restores a checkpoint and the
        loop re-enters at an earlier chunk)."""
        self._halt()
        self._next = int(chunk_idx)
        self._start_thread(int(chunk_idx))

    def retarget(self, produce: Callable[[int, Any], Any], n_chunks: int,
                 *, make_staging: Optional[Callable[[], Any]] = None,
                 signature=None, start: int = 0):
        """Flush the pipeline and aim it at a NEW chunk source — the rung-
        boundary protocol: in-flight slabs for the old segment are always
        dropped and the producer restarts against the next segment's
        ``produce`` (chunk indices re-base on the new segment, so a stale
        slab can never be served), but the STAGING buffers are reused when
        ``signature`` (:func:`staging_signature` of the next segment's
        buffers, buildable from shapes alone) matches the current one —
        the constant-population refill keeps every slab shape identical
        across the rung, so no host buffer is discarded or reallocated
        there.  A shrinking rung changes the signature and takes the full
        rebuild path as before; omitting ``signature`` while passing
        ``make_staging`` also forces the rebuild (the conservative
        pre-refill behaviour)."""
        self._halt()
        self._produce = produce
        self._n_chunks = int(n_chunks)
        if make_staging is not None:
            self._make_staging = make_staging
            if signature is None or signature != self._signature:
                self._staging = [make_staging(), make_staging()]
                self._signature = staging_signature(self._staging[0])
        self._next = int(start)
        self._start_thread(int(start))

    def _halt(self):
        """Stop the producer thread and drain the queue (dropping slabs)."""
        self._stop.set()
        while True:                      # unblock a producer stuck in put()
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            if self._thread.is_alive():  # pragma: no cover — defensive
                raise RuntimeError(
                    f"{self._name}: producer thread failed to stop")
        self._thread = None

    def close(self):
        """Shut the producer down; idempotent, never hangs (``_halt``'s
        queue drain wakes a producer blocked in ``put`` via the queue's
        ``not_full`` condition, and the producer re-checks the stop flag
        after every wake)."""
        if self._thread is not None:
            self._halt()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
