"""Optimizers + schedules (optax-free, sharding-aware, per-member-lr
capable: pass a pytree of per-leaf scales as ``lr``)."""
from repro.optim.optimizers import (OPTIMIZERS, SCHEDULES, Optimizer,
                                    adafactor, adamw, apply_updates,
                                    broadcast_lr, broadcast_scale,
                                    clip_by_global_norm, constant_lr,
                                    global_norm, hyper_on, make_optimizer,
                                    scale_member_moments, sgd, tree_cast,
                                    tree_zeros_like, warmup_cosine)

__all__ = [
    "OPTIMIZERS", "SCHEDULES", "Optimizer", "adafactor", "adamw",
    "apply_updates", "broadcast_lr", "broadcast_scale",
    "clip_by_global_norm", "constant_lr", "global_norm", "hyper_on",
    "make_optimizer", "scale_member_moments", "sgd", "tree_cast",
    "tree_zeros_like", "warmup_cosine",
]
