"""Optimizers — functional, tree-based, sharding-preserving.

No optax in this environment; these are minimal-but-production
implementations with the features the assigned scales require:

  * ``sgd``        — momentum optional; the paper trains with plain SGD.
  * ``adamw``      — decoupled weight decay; *state dtype policy* (m/v can be
    bf16 — halves optimizer HBM, needed ≥70B params on 16 GB v5e chips).
  * ``adafactor``  — factored second moment (row/col statistics, O(n+m) per
    matrix) with bf16 momentum; what makes nemotron-4-340b's optimizer state
    fit 256×16 GB.

API: ``Optimizer(init, update, state_specs)``.
  init(params) -> state
  update(grads, state, params, lr) -> (updates, new_state)   # updates: deltas
  state_specs(param_specs, abstract_params) -> spec tree matching state

``lr`` may be a scalar OR a pytree of per-leaf scale arrays matching the
param tree (broadcastable against each leaf) — this is how per-member
learning rates reach fused populations: ``core.deep.member_lr_tree``
expands a (P,) vector into exactly such a tree, and every optimizer here
applies it leaf-wise (the paper's §7 "parallelise the learning rate too").

The same generalisation applies to the *stateful* hyperparameters: SGD's
``momentum`` and AdamW/Adafactor's ``weight_decay`` accept a scalar OR a
per-leaf scale tree (``member_lr_tree`` over a per-member vector), so a
fused population can race heterogeneous training RECIPES, not just
architectures (DESIGN.md §8).  Tree hyperparameters are bound at
construction — the optimizer closes over them, and the population driver
rebuilds the optimizer whenever the layout changes (halving rung
boundaries re-index the per-member vectors through the survivor mapping).

``state_specs`` needs the *abstract* params (shapes) because adafactor's
state structure depends on each leaf's rank.  Every state leaf inherits its
sharding from the param leaf it tracks (factored leaves drop the reduced
dim's axis), so FSDP-sharded params get FSDP-sharded optimizer state — ZeRO
for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_zeros_like(tree, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _is_spec(x):
    return isinstance(x, P)


def broadcast_scale(val, tree, name: str = "scale"):
    """Normalise a scalar-or-scale-tree hyperparameter to a pytree matching
    ``tree``.

    Scalars (python numbers / 0-d arrays) are replicated to every leaf; a
    pytree (e.g. from ``core.deep.member_lr_tree``) is passed through after a
    structure check, so mismatches fail loudly here instead of deep inside a
    tree.map.  A raw per-member (P,) vector is rejected for the same reason —
    expand it with ``core.deep.member_lr_tree`` first."""
    if isinstance(val, (dict, list, tuple)):
        if jax.tree_util.tree_structure(val) != \
                jax.tree_util.tree_structure(tree):
            raise ValueError(f"{name} pytree structure does not match params")
        return val
    if getattr(val, "ndim", 0) != 0:
        raise ValueError(
            f"{name} must be a scalar or a pytree of per-leaf scales, got an "
            f"array of shape {val.shape}; expand per-member vectors with "
            f"core.deep.member_lr_tree(layout, {name}) first")
    flat, tdef = jax.tree.flatten(tree)
    return tdef.unflatten([val] * len(flat))


def broadcast_lr(lr, tree):
    return broadcast_scale(lr, tree, "lr")


def hyper_on(h) -> bool:
    """Is a scalar-or-tree hyperparameter active?  Scalars by truthiness
    (``momentum=0.0`` means plain SGD, no state); a scale TREE is always
    active — a per-member vector that happens to contain zeros still needs
    the state allocated for the other members."""
    if isinstance(h, (dict, list, tuple)):
        return True
    return bool(h)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple]
    state_specs: Callable[[Any, Any], Any]


# --------------------------------------------------------------------- #
# SGD                                                                   #
# --------------------------------------------------------------------- #

def sgd(momentum=0.0, nesterov: bool = False) -> Optimizer:
    """``momentum`` may be a scalar or a per-leaf scale tree (per-member
    momentum through ``core.deep.member_lr_tree``); a scalar 0 keeps the
    stateless plain-SGD fast path (state is just the step count)."""
    stateful = hyper_on(momentum)

    def init(params):
        st = {"count": jnp.zeros((), jnp.int32)}
        if stateful:
            st["mu"] = tree_zeros_like(params, jnp.float32)
        return st

    def update(grads, state, params, lr):
        lrs = broadcast_lr(lr, grads)
        if not stateful:
            upd = jax.tree.map(lambda g, l: -l * g.astype(jnp.float32),
                               grads, lrs)
            return upd, {"count": state["count"] + 1}
        moms = broadcast_scale(momentum, grads, "momentum")
        mu = jax.tree.map(
            lambda mo, m, g: mo * m + g.astype(jnp.float32),
            moms, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(
                lambda mo, m, g, l: -l * (mo * m + g.astype(jnp.float32)),
                moms, mu, grads, lrs)
        else:
            upd = jax.tree.map(lambda m, l: -l * m, mu, lrs)
        return upd, {"count": state["count"] + 1, "mu": mu}

    def state_specs(param_specs, abstract_params):
        st = {"count": P()}
        if stateful:
            st["mu"] = param_specs
        return st

    return Optimizer(init, update, state_specs)


# --------------------------------------------------------------------- #
# AdamW                                                                 #
# --------------------------------------------------------------------- #

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay=0.1, state_dtype=jnp.float32) -> Optimizer:
    """state_dtype=bf16 halves m/v HBM; the moment math stays f32.
    ``weight_decay`` may be a scalar or a per-leaf scale tree (per-member
    decay through ``core.deep.member_lr_tree``)."""
    decoupled = hyper_on(weight_decay)

    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "m": tree_zeros_like(params, state_dtype),
                "v": tree_zeros_like(params, state_dtype)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        cf = c.astype(jnp.float32)
        bc1 = 1.0 - b1 ** cf
        bc2 = 1.0 - b2 ** cf

        def leaf(g, m, v, p, l, wd):
            gf = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
            if wd is not None:
                step = step + wd * p.astype(jnp.float32)
            return -l * step, m32.astype(state_dtype), v32.astype(state_dtype)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_lr = tdef.flatten_up_to(broadcast_lr(lr, grads))
        flat_wd = (tdef.flatten_up_to(
            broadcast_scale(weight_decay, grads, "weight_decay"))
            if decoupled else [None] * len(flat_g))
        out = [leaf(g, m, v, p, l, wd) for g, m, v, p, l, wd in zip(
            flat_g, tdef.flatten_up_to(state["m"]),
            tdef.flatten_up_to(state["v"]), tdef.flatten_up_to(params),
            flat_lr, flat_wd)]
        return (tdef.unflatten([o[0] for o in out]),
                {"count": c,
                 "m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out])})

    def state_specs(param_specs, abstract_params):
        return {"count": P(), "m": param_specs, "v": param_specs}

    return Optimizer(init, update, state_specs)


# --------------------------------------------------------------------- #
# Adafactor (factored v, optional bf16 momentum)                        #
# --------------------------------------------------------------------- #

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def adafactor(b2: float = 0.99, eps: float = 1e-30, momentum: float = 0.9,
              momentum_dtype=jnp.bfloat16, weight_decay=0.0,
              clip_threshold: float = 1.0) -> Optimizer:
    """``weight_decay`` may be a scalar or a per-leaf scale tree, like
    :func:`adamw`.  ``momentum`` stays a scalar (it is an EMA coefficient
    folded into the bf16 state, not a per-member race knob)."""
    decoupled = hyper_on(weight_decay)

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                st = {"v_row": jnp.zeros(p.shape[:-1], jnp.float32),
                      "v_col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                         jnp.float32)}
            else:
                st = {"v": jnp.zeros(p.shape, jnp.float32)}
            if momentum:
                st["m"] = jnp.zeros(p.shape, momentum_dtype)
            return st
        return {"count": jnp.zeros((), jnp.int32),
                "leaves": jax.tree.map(leaf, params)}

    def update(grads, state, params, lr):
        c = state["count"] + 1

        def leaf(g, st, p, l, wd):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            new_st = {}
            if "v" in st:
                v = b2 * st["v"] + (1 - b2) * g2
                u = gf * jax.lax.rsqrt(v + eps)
                new_st["v"] = v
            else:
                v_row = b2 * st["v_row"] + (1 - b2) * g2.mean(-1)
                v_col = b2 * st["v_col"] + (1 - b2) * g2.mean(-2)
                r = v_row / jnp.maximum(v_row.mean(-1, keepdims=True), eps)
                u = gf * jax.lax.rsqrt(
                    r[..., None] * v_col[..., None, :] + eps)
                new_st["v_row"], new_st["v_col"] = v_row, v_col
            u_rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, u_rms / clip_threshold)
            if momentum:
                m = momentum * st["m"].astype(jnp.float32) + (1 - momentum) * u
                new_st["m"] = m.astype(momentum_dtype)
                u = m
            if wd is not None:
                u = u + wd * p.astype(jnp.float32)
            return -l * u, new_st

        flat_g, tdef = jax.tree.flatten(grads)
        is_state_leaf = lambda x: isinstance(x, dict) and (
            "v" in x or "v_row" in x)
        flat_st = jax.tree.flatten(state["leaves"], is_leaf=is_state_leaf)[0]
        flat_wd = (tdef.flatten_up_to(
            broadcast_scale(weight_decay, grads, "weight_decay"))
            if decoupled else [None] * len(flat_g))
        out = [leaf(g, s, p, l, wd) for g, s, p, l, wd in
               zip(flat_g, flat_st, tdef.flatten_up_to(params),
                   tdef.flatten_up_to(broadcast_lr(lr, grads)), flat_wd)]
        return (tdef.unflatten([o[0] for o in out]),
                {"count": c, "leaves": tdef.unflatten([o[1] for o in out])})

    def state_specs(param_specs, abstract_params):
        def leaf(spec, p):
            if not _is_spec(spec):
                spec = P()
            axes = list(spec) + [None] * (len(p.shape) - len(spec))
            st = {}
            if _factored(p.shape):
                st["v_row"] = P(*axes[:-1])
                st["v_col"] = P(*(axes[:-2] + axes[-1:]))
            else:
                st["v"] = P(*axes)
            if momentum:
                st["m"] = P(*axes)
            return st
        return {"count": P(),
                "leaves": jax.tree.map(leaf, param_specs, abstract_params,
                                       is_leaf=_is_spec)}

    return Optimizer(init, update, state_specs)


OPTIMIZERS = {"sgd": sgd, "adamw": adamw, "adafactor": adafactor}


def make_optimizer(name: str, **kw) -> Optimizer:
    return OPTIMIZERS[name](**kw)


def apply_updates(params, updates):
    """params += updates (updates f32; cast back to the param dtype)."""
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def scale_member_moments(state, ref, scale_tree):
    """Multiply every params-shaped moment in an optimizer state by a
    params-STRUCTURED tree of broadcastable masks/scales (each mask leaf
    broadcasts against its param leaf along the member-major axes) —
    the in-place twin of re-initialising a member's moments, used by the
    constant-size slot refill (``lifecycle.refill_state``) to zero the
    refilled slots without touching survivors' bytes or the layout.

    Schema-aware across all four optimizers: scalar leaves (step counts)
    pass through; sgd ``mu`` / adamw ``m``+``v`` are scaled per subtree
    with moment dtype preserved; adafactor's ``leaves`` tree is walked
    per-param — ``m`` and unfactored ``v`` are scaled, while the factored
    ``v_row``/``v_col`` statistics mix members along the reduced axis and
    pass through untouched (stale; they re-warm in ~1/(1−b2) steps).
    ``ref`` is the live/abstract params tree for the CURRENT layout."""
    def scale_leaf(mom, mk):
        return mom * jnp.asarray(mk, mom.dtype)

    if isinstance(state, dict) and "leaves" in state:       # adafactor
        is_state_leaf = lambda x: isinstance(x, dict) and (
            "v" in x or "v_row" in x)
        flat_st, tdef = jax.tree.flatten(state["leaves"],
                                         is_leaf=is_state_leaf)
        flat_mk = jax.tree.leaves(scale_tree)
        if len(flat_mk) != len(flat_st):
            raise ValueError("scale_member_moments: scale tree does not "
                             "match the adafactor state's param structure")
        out = []
        for st, mk in zip(flat_st, flat_mk):
            new = dict(st)
            if "v" in st:
                new["v"] = scale_leaf(st["v"], mk)
            if "m" in st:
                new["m"] = scale_leaf(st["m"], mk)
            out.append(new)
        return {**state, "leaves": tdef.unflatten(out)}

    from repro.core.deep import map_params_subtrees
    return map_params_subtrees(
        state, ref,
        lambda node: jax.tree.map(scale_leaf, node, scale_tree),
        op="scale_member_moments")


# --------------------------------------------------------------------- #
# LR schedules                                                          #
# --------------------------------------------------------------------- #

def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup_steps, warm, cos)
    return lr


def constant_lr(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


SCHEDULES = {"warmup_cosine": warmup_cosine, "constant": constant_lr}
