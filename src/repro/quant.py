"""Symmetric int8 quantization: the shared scale math and the serving-plane
weight packer (DESIGN.md §12).

Two consumers share the three primitives here:

  * ``distributed.compression`` — int8 gradient all-reduce with error
    feedback (one scale per gradient leaf).  Its ``quantize_int8`` is the
    original proof of the scale math; it now composes these helpers with a
    bit-identical op sequence (regression-tested).
  * ``quantize_population`` — the serve-copy packer: converts a published
    population's f32/bf16 weights into int8 with per-member-per-tile
    symmetric scales, laid out exactly as the forward-only Pallas kernels
    consume them (pre-packed tile arrays, identity tile appended), so the
    serving plane never holds — or streams — an f32 weight copy
    (kernels/fused_input.py, fused_layer.py, infer_head.py int8 twins).

Scale granularity (why "per-member-per-tile"): every mid-layer weight tile
belongs to exactly one member, so a per-tile scale IS a per-member scale at
the finest granularity the kernel grid can index without extra metadata —
one f32 scalar rides each (blk, blk) int8 tile through the existing
scalar-prefetched step layout.  The input layer scales per hidden row
block (each owned by one member), the head per hidden tile (each owned by
one member's output rows).  Pass-through slots have no parameters: the
shared identity tile is appended UNQUANTIZED-in-effect (0/1 entries are
exact at scale 1.0).  Shard-pad fillers hold identity weights — quantized
like any member (also exact at their own scale), so a padded layout serves
unchanged.

What stays f32: biases (added to the f32 accumulator in the kernel
epilogues, never a matmul operand), the per-tile scales themselves, and
the training masters (quantization happens on a COPY at publish time —
``launch.serve_population.PopulationServer``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def symmetric_scale(x: jax.Array, axis=None, keepdims: bool = False):
    """``max|x|/127 + 1e-12`` over ``axis`` — the symmetric int8 scale.
    The 1e-12 floor keeps all-zero groups finite (they quantize to exact
    zeros)."""
    return jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims) / 127.0 + 1e-12


def quantize(x: jax.Array, scale) -> jax.Array:
    """Round-to-nearest symmetric int8 in [-127, 127]."""
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def dequantize(q: jax.Array, scale) -> jax.Array:
    return q.astype(jnp.float32) * scale


# --------------------------------------------------------------------- #
# serve-copy packer                                                     #
# --------------------------------------------------------------------- #

def _input_f_pad(f: int) -> int:
    """The feature padding the fused input kernel uses (ops.py: whole-F
    lane register when small, 128-lane reduction tiles when large) — the
    packed ``w_in`` is stored pre-padded so the serve forward never pads
    weight bytes per call."""
    fmult = 8 if f <= 128 else 128
    return f + ((-f) % fmult)


def quantize_population(params, lp):
    """The int8 serve copy of a population's parameters.

    Returns a pytree the ``weights_dtype="int8"`` forward consumes
    directly (``deep.forward(infer=True, weights_dtype="int8")``):

      w_in        (H0, F_pad) int8 — pre-padded input weight
      w_in_scale  (H0/blk,)   f32  — one scale per hidden row block
      mid[l].wb     (n_param_blocks+1, blk, blk) int8 — PRE-PACKED tile
                    array (``pack_weight_tiles`` layout) with the shared
                    pass-through identity tile already appended
      mid[l].scale  (n_param_blocks+1,) f32 — per-tile scales, 1.0 for the
                    identity tile (0/1 entries quantize exactly)
      w_out       (O, H_last) int8
      w_out_scale (H_last/blk,) f32 — one scale per hidden tile
      b_in / mid[l].b / b_out — f32, untouched (bias adds run on the f32
                    accumulator in the kernel epilogues)

    Heterogeneous buckets, pass-through slots, and shard_pad fillers all
    ride the existing layout metadata — the packer only changes the bytes
    each tile stores, never which tile a step loads."""
    from repro.core.deep import pack_weight_tiles  # lazy: deep imports pallas
    blk = lp.block
    f32 = jnp.float32

    w_in = params["w_in"].astype(f32)
    h0, f = w_in.shape
    s_in = symmetric_scale(w_in.reshape(h0 // blk, blk * f), axis=1)
    q_in = quantize(w_in, jnp.repeat(s_in, blk)[:, None])
    f_pad = _input_f_pad(f)
    if f_pad != f:                       # zero columns are exact under int8
        q_in = jnp.pad(q_in, ((0, 0), (0, f_pad - f)))

    out = {"w_in": q_in, "w_in_scale": s_in,
           "b_in": params["b_in"].astype(f32), "mid": []}
    eye = jnp.eye(blk, dtype=jnp.int8)[None]
    for l in range(lp.depth - 1):
        wb = pack_weight_tiles(
            [w.astype(f32) for w in params["mid"][l]["w"]], lp, l)
        s = symmetric_scale(wb.reshape(wb.shape[0], -1), axis=1)
        q = quantize(wb, s[:, None, None])
        out["mid"].append({
            "wb": jnp.concatenate([q, eye], axis=0),
            "scale": jnp.concatenate([s, jnp.ones((1,), f32)]),
            "b": params["mid"][l]["b"].astype(f32)})

    w_out = params["w_out"].astype(f32)
    o, h_last = w_out.shape
    s_out = symmetric_scale(w_out.reshape(o, h_last // blk, blk),
                            axis=(0, 2))
    out["w_out"] = quantize(w_out, jnp.repeat(s_out, blk)[None, :])
    out["w_out_scale"] = s_out
    out["b_out"] = params["b_out"].astype(f32)
    return out


def unpack_weight_tiles(wb, lp, l: int):
    """Inverse of ``deep.pack_weight_tiles``: flat (n_param_blocks, blk,
    blk) tiles → the per-bucket (n, hout, hin) arrays.  Test/reference
    helper for the quantized serve copy."""
    blk = lp.block
    out, off = [], 0
    for (m0, n, hin, hout, off_in, off_out, real) in lp.proj_buckets(l):
        if not real:
            continue
        ob, ib = hout // blk, hin // blk
        cnt = n * ob * ib
        out.append(wb[off:off + cnt].reshape(n, ob, ib, blk, blk)
                   .transpose(0, 1, 3, 2, 4).reshape(n, hout, hin))
        off += cnt
    return out


def dequantize_population(qparams, lp):
    """The f32 params tree a quantized serve copy REPRESENTS — the exact
    numerics reference for the fused-dequant kernels: running this tree
    through the standard forward must match the int8 forward to normal
    kernel tolerance (independent of how large the quantization error
    is)."""
    blk = lp.block
    f = lp.in_features
    w_in = dequantize(qparams["w_in"][:, :f],
                      jnp.repeat(qparams["w_in_scale"], blk)[:, None])
    out = {"w_in": w_in, "b_in": qparams["b_in"], "mid": []}
    for l in range(lp.depth - 1):
        n_p = lp.bd_layout(l).n_param_blocks
        wb = dequantize(qparams["mid"][l]["wb"][:n_p],
                        qparams["mid"][l]["scale"][:n_p, None, None])
        out["mid"].append({"w": unpack_weight_tiles(wb, lp, l),
                           "b": qparams["mid"][l]["b"]})
    out["w_out"] = dequantize(qparams["w_out"],
                              jnp.repeat(qparams["w_out_scale"], blk)[None, :])
    out["b_out"] = qparams["b_out"]
    return out


def serve_copy_bytes(tree) -> int:
    """Total HBM bytes a params tree pins (the tracked serve-copy size)."""
    return int(sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(tree)))
