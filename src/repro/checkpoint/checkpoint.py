"""Sharded, atomic, async-capable checkpointing (tensorstore-free).

Layout:  <dir>/step_<N>/
           arrays.npz     — flattened leaves keyed by tree path
           tree.json      — pytree structure + dtype/shape manifest
           META.ok        — commit marker (atomicity: written LAST)

Restore is ELASTIC by construction: leaves are stored as full host arrays
and re-sharded onto whatever mesh the restoring job has (different chip
count, different pod count) via ``jax.device_put`` with the current spec
tree.  On a real multi-host pod each host would write its addressable
shards (``save`` already iterates addressable_shards); the npz container is
the single-process degenerate case of that layout.

``AsyncCheckpointer`` moves serialisation+IO off the training thread —
device→host copies happen synchronously (cheap), compression+write happen
in a worker thread, so the step loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(directory: str, step: int, state_tree, keep_last: int = 3,
         meta: dict | None = None) -> str:
    """Atomic synchronous save.  Returns the committed path.

    ``meta``: optional JSON-serialisable dict stored alongside the manifest —
    population checkpoints use it to persist the fused layout so restore can
    rebuild the parameter tree without the original code path."""
    tgt = os.path.join(directory, f"step_{step:08d}")
    tmp = tgt + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten_with_paths(state_tree)
    host = {}
    manifest = {}
    for key, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        manifest[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        if arr.dtype.kind == "V" or str(arr.dtype) not in np.sctypeDict:
            # custom dtypes (bfloat16, fp8) → store the raw bit pattern
            arr = arr.view(f"u{arr.dtype.itemsize}")
        host[key] = arr
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in host.items()})
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump({"step": step, "manifest": manifest,
                   "meta": meta or {}}, f)
    with open(os.path.join(tmp, "META.ok"), "w") as f:
        f.write(str(time.time()))
    if os.path.exists(tgt):
        shutil.rmtree(tgt)
    os.rename(tmp, tgt)
    _gc(directory, keep_last)
    return tgt


def _gc(directory: str, keep_last: int):
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name, "META.ok")):
            out.append(int(name[5:]))
    return sorted(out)


def restore(directory: str, like_tree, shardings=None, step: int | None = None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching tree of jax.sharding.Sharding — leaves
    are device_put directly onto the restoring job's mesh (elastic re-mesh:
    the stored host arrays don't care what mesh wrote them).
    Returns (state_tree, step)."""
    steps = latest_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "tree.json")) as f:
        manifest = json.load(f)["manifest"]
    leaves, treedef = _flatten_with_paths(like_tree)
    out = {}
    for key, proto in leaves.items():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        want = tuple(proto.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want}")
        stored_dtype = manifest[key]["dtype"]
        if str(arr.dtype) != stored_dtype:
            # custom dtype stored as raw bits → reinterpret, don't cast
            arr = arr.view(np.dtype(stored_dtype))
        proto_dtype = np.dtype(proto.dtype)
        if arr.dtype != proto_dtype:
            arr = arr.astype(proto_dtype)
        out[key] = arr
    flat_restored = []
    sh_leaves = None
    if shardings is not None:
        sh_flat, _ = _flatten_with_paths(shardings)
        sh_leaves = sh_flat
    for key, proto in leaves.items():
        arr = out[key]
        if sh_leaves is not None and key in sh_leaves:
            flat_restored.append(jax.device_put(arr, sh_leaves[key]))
        else:
            flat_restored.append(jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_tree),
        flat_restored)
    return tree, step


def load_meta(directory: str, step: int | None = None) -> tuple:
    """The ``meta`` dict stored with a checkpoint → (meta, step)."""
    steps = latest_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints under {directory}")
    step = steps[-1] if step is None else step
    with open(os.path.join(directory, f"step_{step:08d}", "tree.json")) as f:
        return json.load(f).get("meta", {}), step


# --------------------------------------------------------------------- #
# fused-population checkpoints (layout travels WITH the parameters)     #
# --------------------------------------------------------------------- #

def _layout_meta(layout, params, lifecycle: dict | None = None,
                 train_meta: dict | None = None) -> dict:
    from repro.core.population import LayeredPopulation, Population
    if isinstance(layout, Population):
        layout = layout.layered()
    if not isinstance(layout, LayeredPopulation):
        raise TypeError(f"not a population layout: {type(layout)}")
    # two parameter schemas share the layout format: the layered engine
    # (core.deep: w_in/b_in/mid/w_out/b_out) and the single-layer module
    # (core.parallel_mlp: w1/b1/w2/b2) — recorded so restore rebuilds the
    # matching tree.
    if "w_in" in params:
        schema = "layered"
    elif "w1" in params:
        schema = "single"
    else:
        raise TypeError(f"unrecognised population params: {sorted(params)}")
    dtype = str(jax.tree.leaves(params)[0].dtype)
    meta = {"population": {
        "in_features": layout.in_features,
        "out_features": layout.out_features,
        "widths": [list(w) for w in layout.widths],
        "activations": [list(a) for a in layout.activations],
        "block": layout.block,
        "n_pad": layout.n_pad,
        "schema": schema,
        "dtype": dtype,
    }}
    if lifecycle is not None:
        meta["lifecycle"] = dict(lifecycle)
    if train_meta is not None:
        meta["train"] = dict(train_meta)
    return meta


def population_meta(layout, params, lifecycle: dict | None = None,
                    train_meta: dict | None = None) -> dict:
    """Public alias of the layout-meta builder — what a caller (e.g.
    ``TrainRunner``'s checkpointer) attaches so its generic saves stay
    ``restore_population``-compatible.

    ``lifecycle``: optional successive-halving state stored alongside the
    layout (schema, DESIGN.md §6): ``rung`` (boundaries already applied),
    ``member_ids`` (survivor→ORIGINAL member id, one per real member) and
    ``n_members0`` (the run's original real member count) — what lets
    ``--resume`` restore mid-ladder on the compacted layout and keep
    reporting original ids.

    ``train_meta``: optional run policy (e.g. the ``--compute-dtype``
    mixed-precision setting) recorded under ``meta["train"]`` — parameters
    are always saved as their f32 masters, so the policy is informational
    for resumes, not a restore-time requirement."""
    return _layout_meta(layout, params, lifecycle=lifecycle,
                        train_meta=train_meta)


def lifecycle_from_meta(meta: dict, layout) -> tuple:
    """Lifecycle state from a checkpoint ``meta`` → ``(rung, member_ids,
    n_members0)``.  Checkpoints written before (or without) the halving
    lifecycle default to rung 0 with an identity member mapping over the
    layout's real members."""
    num_real = getattr(layout, "num_real", layout.num_members)
    life = meta.get("lifecycle") or {}
    rung = int(life.get("rung", 0))
    member_ids = np.asarray(life.get("member_ids", range(num_real)),
                            dtype=np.int64)
    if member_ids.shape[0] != num_real:
        raise ValueError(
            f"lifecycle meta carries {member_ids.shape[0]} member ids for a "
            f"layout with {num_real} real members")
    return rung, member_ids, int(life.get("n_members0", num_real))


def optimizer_from_meta(meta: dict):
    """The optimizer record stored under ``meta["train"]["optimizer"]``
    (None for checkpoints written before the stateful-optimizer engine —
    those carry no optimizer state and may only resume stateless)."""
    return (meta.get("train") or {}).get("optimizer")


def require_optimizer_match(meta: dict, record: dict):
    """Fail LOUDLY when a resume would reinterpret a stored optimizer state
    tree under a different training recipe: the checkpoint's optimizer
    record (name + hyperparameters + state dtype + per-member flags) must
    EQUAL the requested one — AdamW moments restored as momentum buffers,
    or bf16 moments reinterpreted as f32, silently corrupt the run.

    Returns the stored record; ``None`` means a legacy checkpoint with no
    optimizer meta (the caller decides whether a stateless resume is
    acceptable)."""
    stored = optimizer_from_meta(meta)
    if stored is None or stored == record:
        return stored
    diff = {k: {"checkpoint": stored.get(k), "requested": record.get(k)}
            for k in sorted(set(stored) | set(record))
            if stored.get(k) != record.get(k)}
    raise ValueError(
        "resume: optimizer config mismatch — the checkpoint's state tree "
        f"was written by optimizer {stored.get('name')!r} and cannot be "
        f"reinterpreted under the requested config; differing fields: {diff}")


def layout_from_meta(meta: dict):
    from repro.core.population import LayeredPopulation
    p = meta["population"]
    return LayeredPopulation(
        int(p["in_features"]), int(p["out_features"]),
        tuple(tuple(int(h) for h in w) for w in p["widths"]),
        tuple(tuple(a) for a in p["activations"]),
        block=int(p["block"]), n_pad=int(p.get("n_pad", 0)))


def save_population(directory: str, step: int, params, layout,
                    keep_last: int = 3, extra_state=None,
                    lifecycle: dict | None = None,
                    train_meta: dict | None = None) -> str:
    """Checkpoint fused population parameters WITH their static layout
    (widths, per-layer activations, block, param schema, dtype), so
    ``restore_population`` reconstructs both without the constructing code.
    ``extra_state`` (e.g. optimizer state) is stored under its own subtree;
    ``lifecycle`` (see ``population_meta``) rides in the meta so halving
    runs resume mid-ladder."""
    tree = {"params": params}
    if extra_state is not None:
        tree["extra"] = extra_state
    return save(directory, step, tree, keep_last=keep_last,
                meta=_layout_meta(layout, params, lifecycle=lifecycle,
                                  train_meta=train_meta))


def restore_population(directory: str, step: int | None = None,
                       extra_like=None, mesh=None, extra_specs=None):
    """→ (params, layout, step[, extra_state]).

    The parameter tree is rebuilt from the stored layout, schema, and dtype —
    no live params needed.  The returned layout MATCHES the params: a
    ``LayeredPopulation`` for layered-engine checkpoints, a ``Population``
    for single-layer (parallel_mlp) ones, so (params, layout) always works
    together in forward/selection.  Pass ``extra_like`` (matching the
    ``extra_state`` given to ``save_population`` — abstract
    ShapeDtypeStructs are fine, e.g. ``jax.eval_shape(opt.init, ...)``) to
    restore it too.

    ``mesh``: restore SHARDED — parameters are device_put straight onto the
    mesh through the layout's ``param_specs()`` (elastic: any device count;
    non-dividing axes replicate).  Extra state restores replicated unless
    ``extra_specs`` (a PartitionSpec tree matching ``extra_like``, e.g.
    ``layout.opt_specs(opt)``) is given — then optimizer moments land
    sharded alongside their parameters."""
    import jax.numpy as jnp
    meta, step = load_meta(directory, step)
    if "population" not in meta:
        raise ValueError(f"{directory} step {step}: not a population "
                         "checkpoint (no layout meta)")
    lp = layout_from_meta(meta)
    pmeta = meta["population"]
    # string → jax dtype (handles bfloat16, which numpy's dtype() doesn't)
    dtype = jnp.zeros((), pmeta.get("dtype", "float32")).dtype
    layout = lp
    if pmeta.get("schema", "layered") == "single":
        from repro.core import parallel_mlp
        from repro.core.population import Population
        layout = Population(lp.in_features, lp.out_features,
                            tuple(w[0] for w in lp.widths),
                            tuple(a[0] for a in lp.activations),
                            block=lp.block)
        abstract = jax.eval_shape(
            lambda k: parallel_mlp.init_params(k, layout, dtype),
            jax.random.PRNGKey(0))
    else:
        from repro.core.deep import abstract_params
        abstract = abstract_params(lp, dtype)
    like = {"params": abstract}
    if extra_like is not None:
        like["extra"] = extra_like
    shardings = None
    if mesh is not None:
        from repro.distributed.sharding import logical_to_sharding
        shardings = {"params": logical_to_sharding(
            layout.param_specs(), mesh, abstract)}
        if extra_like is not None and extra_specs is not None:
            shardings["extra"] = logical_to_sharding(extra_specs, mesh,
                                                     extra_like)
    tree, step = restore(directory, like, shardings=shardings, step=step)
    if extra_like is not None:
        return tree["params"], layout, step, tree["extra"]
    return tree["params"], layout, step


class AsyncCheckpointer:
    """Off-thread commit: ``maybe_save`` snapshots to host synchronously
    (fast) and hands serialisation to a worker; ``wait`` joins in-flight
    writes (call before exit / before restore).

    ``meta`` is attached to every save (population runs pass the layout
    meta so the files stay ``restore_population``-compatible);
    ``step_map`` translates the caller's step counter into the RECORDED
    step (a scanned train loop counts chunks but checkpoints must carry
    global step numbers so resume cadence survives a ``--scan-steps``
    change); ``save_pred`` replaces the ``step % every`` cadence with an
    arbitrary predicate on the caller's step counter (a scanned loop fires
    when a chunk CROSSES a global-step cadence boundary, so ``ckpt_every``
    keeps meaning global steps, not chunks)."""

    def __init__(self, directory: str, every: int = 100, keep_last: int = 3,
                 meta: dict | None = None, step_map=None, save_pred=None):
        self.directory = directory
        self.every = every
        self.keep_last = keep_last
        self.meta = meta
        self.step_map = step_map or (lambda s: s)
        self.save_pred = save_pred
        self._thread: threading.Thread | None = None
        self.saved = []

    def maybe_save(self, step: int, state_tree) -> bool:
        if self.save_pred is not None:
            if not self.save_pred(step):
                return False
        elif not self.every or step % self.every:
            return False
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 state_tree)
        rec_step = self.step_map(step)

        def work():
            p = save(self.directory, rec_step, host_tree, self.keep_last,
                     meta=self.meta)
            self.saved.append(p)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
