"""Sharded, atomic, async-capable checkpointing (tensorstore-free).

Layout:  <dir>/step_<N>/
           arrays.npz     — flattened leaves keyed by tree path
           tree.json      — pytree structure + dtype/shape manifest
           META.ok        — commit marker (atomicity: written LAST)

Restore is ELASTIC by construction: leaves are stored as full host arrays
and re-sharded onto whatever mesh the restoring job has (different chip
count, different pod count) via ``jax.device_put`` with the current spec
tree.  On a real multi-host pod each host would write its addressable
shards (``save`` already iterates addressable_shards); the npz container is
the single-process degenerate case of that layout.

``AsyncCheckpointer`` moves serialisation+IO off the training thread —
device→host copies happen synchronously (cheap), compression+write happen
in a worker thread, so the step loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(directory: str, step: int, state_tree, keep_last: int = 3) -> str:
    """Atomic synchronous save.  Returns the committed path."""
    tgt = os.path.join(directory, f"step_{step:08d}")
    tmp = tgt + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten_with_paths(state_tree)
    host = {}
    manifest = {}
    for key, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        manifest[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        if arr.dtype.kind == "V" or str(arr.dtype) not in np.sctypeDict:
            # custom dtypes (bfloat16, fp8) → store the raw bit pattern
            arr = arr.view(f"u{arr.dtype.itemsize}")
        host[key] = arr
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in host.items()})
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump({"step": step, "manifest": manifest}, f)
    with open(os.path.join(tmp, "META.ok"), "w") as f:
        f.write(str(time.time()))
    if os.path.exists(tgt):
        shutil.rmtree(tgt)
    os.rename(tmp, tgt)
    _gc(directory, keep_last)
    return tgt


def _gc(directory: str, keep_last: int):
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name, "META.ok")):
            out.append(int(name[5:]))
    return sorted(out)


def restore(directory: str, like_tree, shardings=None, step: int | None = None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching tree of jax.sharding.Sharding — leaves
    are device_put directly onto the restoring job's mesh (elastic re-mesh:
    the stored host arrays don't care what mesh wrote them).
    Returns (state_tree, step)."""
    steps = latest_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "tree.json")) as f:
        manifest = json.load(f)["manifest"]
    leaves, treedef = _flatten_with_paths(like_tree)
    out = {}
    for key, proto in leaves.items():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        want = tuple(proto.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want}")
        stored_dtype = manifest[key]["dtype"]
        if str(arr.dtype) != stored_dtype:
            # custom dtype stored as raw bits → reinterpret, don't cast
            arr = arr.view(np.dtype(stored_dtype))
        proto_dtype = np.dtype(proto.dtype)
        if arr.dtype != proto_dtype:
            arr = arr.astype(proto_dtype)
        out[key] = arr
    flat_restored = []
    sh_leaves = None
    if shardings is not None:
        sh_flat, _ = _flatten_with_paths(shardings)
        sh_leaves = sh_flat
    for key, proto in leaves.items():
        arr = out[key]
        if sh_leaves is not None and key in sh_leaves:
            flat_restored.append(jax.device_put(arr, sh_leaves[key]))
        else:
            flat_restored.append(jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_tree),
        flat_restored)
    return tree, step


class AsyncCheckpointer:
    """Off-thread commit: ``maybe_save`` snapshots to host synchronously
    (fast) and hands serialisation to a worker; ``wait`` joins in-flight
    writes (call before exit / before restore)."""

    def __init__(self, directory: str, every: int = 100, keep_last: int = 3):
        self.directory = directory
        self.every = every
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self.saved = []

    def maybe_save(self, step: int, state_tree) -> bool:
        if step % self.every:
            return False
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 state_tree)

        def work():
            p = save(self.directory, step, host_tree, self.keep_last)
            self.saved.append(p)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
