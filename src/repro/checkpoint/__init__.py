"""Atomic sharded checkpointing with async commit + elastic restore."""
from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_steps,
                                         restore, save)

__all__ = ["AsyncCheckpointer", "latest_steps", "restore", "save"]
