"""Atomic sharded checkpointing with async commit + elastic restore, plus
layout-carrying fused-population checkpoints."""
from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_steps,
                                         layout_from_meta, lifecycle_from_meta,
                                         load_meta, optimizer_from_meta,
                                         population_meta,
                                         require_optimizer_match, restore,
                                         restore_population, save,
                                         save_population)

__all__ = ["AsyncCheckpointer", "latest_steps", "layout_from_meta",
           "lifecycle_from_meta", "load_meta", "optimizer_from_meta",
           "population_meta", "require_optimizer_match", "restore",
           "restore_population", "save", "save_population"]
