"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE splits the head_dim/2 frequency bands into (temporal, height, width)
sections; each section rotates by its own position stream.  For pure text the
three streams coincide and M-RoPE == RoPE (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def _rotate(x, cos, sin):
    # x (..., d); pairs are (even, odd) interleaved as two halves
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(q, k, positions, head_dim: int, theta: float = 1e4):
    """q (B,S,Hq,d), k (B,S,Hk,d), positions (B,S) int32."""
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), jnp.float32)  # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs               # (B,S,d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return (_rotate(q.astype(jnp.float32), cos, sin).astype(q.dtype),
            _rotate(k.astype(jnp.float32), cos, sin).astype(k.dtype))


def apply_mrope(q, k, positions3, head_dim: int, theta: float = 1e6,
                sections=(16, 24, 24)):
    """Qwen2-VL M-RoPE. positions3 (3,B,S): temporal/height/width streams.

    ``sections`` partitions the d/2 frequency bands; section j's bands take
    their rotation angle from position stream j."""
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), jnp.float32)  # (d/2,)
    # angle per stream then select per band section
    ang_streams = positions3.astype(jnp.float32)[..., None] * freqs      # (3,B,S,d/2)
    sec_id = np.repeat(np.arange(3), sections)                           # (d/2,)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_streams, 0, -1),                                # (B,S,d/2,3)
        jnp.asarray(sec_id)[None, None, :, None], axis=-1)[..., 0]       # (B,S,d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return (_rotate(q.astype(jnp.float32), cos, sin).astype(q.dtype),
            _rotate(k.astype(jnp.float32), cos, sin).astype(k.dtype))
