"""Feed-forward layers: gated/non-gated dense FFNs and Mixture-of-Experts.

The MoE layer is where the paper's technique becomes a first-class framework
feature (DESIGN.md §4): expert computation is the row-segment dual of M3 —
tokens grouped by expert, each group multiplying its own weights, results
scattered back to token order with gradients flowing only through each
token's own experts.  Two interchangeable implementations:

  * ``moe_apply_dense``      — capacity-padded scatter/gather formulation,
    auto-shardable by GSPMD, runs anywhere (smoke tests, single host).
  * ``moe_apply_shard_map``  — explicit SP+EP formulation: tokens
    sequence-sharded over the 'model' axis for routing, expert buffers
    exchanged with ``lax.all_to_all``, experts sharded over 'model'
    (expert parallelism).  This is the production path; the all-to-all pair
    is visible in the dry-run HLO for the roofline's collective term.

On TPU runtime the per-expert matmuls can route through the Pallas grouped
GEMM (kernels/moe_gemm.py); under XLA:CPU and in the dry-run they lower to
batched einsums (same math — asserted in tests).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.nn.common import FFN_ACTS, dense_init


# --------------------------------------------------------------------- #
# dense FFN                                                             #
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class FFNConfig:
    d_model: int
    d_ff: int
    act: str = "silu"       # silu|gelu|relu2|relu
    gated: bool = True      # SwiGLU/GeGLU when True
    bias: bool = False


def ffn_init(key, cfg: FFNConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    params, specs = {}, {}
    p, s = dense_init(k1, cfg.d_model, cfg.d_ff, dtype, P("data", "model"),
                      bias=cfg.bias)
    params["w_up"], specs["w_up"] = p, s
    if cfg.gated:
        p, s = dense_init(k2, cfg.d_model, cfg.d_ff, dtype, P("data", "model"),
                          bias=cfg.bias)
        params["w_gate"], specs["w_gate"] = p, s
    p, s = dense_init(k3, cfg.d_ff, cfg.d_model, dtype, P("model", "data"),
                      bias=cfg.bias, stddev=cfg.d_ff ** -0.5)
    params["w_down"], specs["w_down"] = p, s
    return params, specs


def ffn_apply(p, cfg: FFNConfig, x):
    act = FFN_ACTS[cfg.act]
    up = x @ p["w_up"]["w"]
    up = _tp_inner(up)
    if cfg.bias:
        up = up + p["w_up"]["b"]
    if cfg.gated:
        gate = x @ p["w_gate"]["w"]
        gate = _tp_inner(gate)
        if cfg.bias:
            gate = gate + p["w_gate"]["b"]
        h = act(gate) * up
    else:
        h = act(up)
    y = h @ p["w_down"]["w"]
    if cfg.bias:
        y = y + p["w_down"]["b"]
    return y


def _tp_inner(h):
    """Pin the FFN inner dim to the 'model' axis (Megatron TP).

    Without this, the SP residual (S on 'model') propagates into the layer
    and the inner activations stay model-REPLICATED on the F dim — the
    backward then builds FULL (D,F) weight grads and all-reduces them at
    full size (nemotron: 5.06 GiB dW buffers + 12.9 GiB/layer all-reduces
    in the baseline dry-run).  Constraining h makes dW born (D, F/tp):
    §Perf hillclimb iteration 1.  Width-gated (TP_INNER_MIN_COLS): for
    narrow layers the AG/RS transitions cost more than the dW savings."""
    from repro.distributed.sharding import (BATCH_AXES, TP_INNER_MIN_COLS,
                                            constrain)
    if h.ndim == 3 and h.shape[-1] >= TP_INNER_MIN_COLS:
        return constrain(h, P(BATCH_AXES, None, "model"))
    return h


# --------------------------------------------------------------------- #
# MoE                                                                   #
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_expert: int
    num_experts: int
    top_k: int
    num_shared: int = 0          # always-on shared experts (DeepSeek-MoE)
    renorm_topk: bool = True     # Mixtral renormalises top-k gates
    capacity_factor: float = 1.25
    act: str = "silu"
    aux_loss_coef: float = 0.01
    first_k_dense: int = 0       # leading layers use a dense FFN instead
    dense_ff: int = 0            # width of those dense layers
    sharding: str = "ep"         # 'ep': experts over 'model' (all-to-all);
                                 # 'tp': expert F-dim over 'model' (E < mesh,
                                 #       e.g. mixtral's 8 experts on 16 chips)


def moe_init(key, cfg: MoEConfig, dtype):
    kr, ke, ks = jax.random.split(key, 3)
    d, f, e = cfg.d_model, cfg.d_expert, cfg.num_experts
    params = {"router": jax.random.normal(kr, (d, e), jnp.float32) * d ** -0.5}
    specs = {"router": P(None, None)}
    kg, ku, kd = jax.random.split(ke, 3)
    # experts stacked on a leading E axis -> EP over 'model'
    std = d ** -0.5
    params["experts"] = {
        "w_gate": jax.random.normal(kg, (e, d, f), dtype) * std,
        "w_up": jax.random.normal(ku, (e, d, f), dtype) * std,
        "w_down": jax.random.normal(kd, (e, f, d), dtype) * f ** -0.5,
    }
    if cfg.sharding == "ep":
        specs["experts"] = {
            "w_gate": P("model", "data", None),
            "w_up": P("model", "data", None),
            "w_down": P("model", None, "data"),
        }
    else:  # 'tp': shard the expert inner dim; experts replicated over EP
        specs["experts"] = {
            "w_gate": P(None, "data", "model"),
            "w_up": P(None, "data", "model"),
            "w_down": P(None, "model", "data"),
        }
    if cfg.num_shared:
        shared_cfg = FFNConfig(d, cfg.d_expert * cfg.num_shared, act=cfg.act)
        p, s = ffn_init(ks, shared_cfg, dtype)
        params["shared"], specs["shared"] = p, s
    return params, specs


def _route(router_w, cfg: MoEConfig, xf):
    """xf (T, D) -> gates (T, k), expert ids (T, k), aux load-balance loss."""
    logits = (xf.astype(jnp.float32) @ router_w)                 # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, cfg.top_k)            # (T, k)
    if cfg.renorm_topk:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss
    me = probs.mean(0)                                           # (E,)
    ce = jnp.zeros((cfg.num_experts,)).at[eidx.reshape(-1)].add(
        1.0 / eidx.size)
    aux = cfg.num_experts * jnp.sum(me * ce) * cfg.aux_loss_coef
    return gate_vals.astype(xf.dtype), eidx, aux


def _expert_ffn(experts, cfg: MoEConfig, buf):
    """buf (E, C, D) -> (E, C, D), SwiGLU per expert (batched einsum)."""
    act = FFN_ACTS[cfg.act]
    h = act(jnp.einsum("ecd,edf->ecf", buf, experts["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, experts["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, experts["w_down"])


def _dispatch_combine(p, cfg: MoEConfig, xf, capacity: int):
    """Capacity-padded dispatch -> expert FFN -> combine.  xf (T, D)."""
    t, d = xf.shape
    gates, eidx, aux = _route(p["router"], cfg, xf)
    flat_e = eidx.reshape(-1)                                     # (T*k,)
    # position of each (token, expert-slot) within its expert's buffer
    onehot = jax.nn.one_hot(flat_e, cfg.num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1                 # (T*k, E)
    pos = pos.max(axis=-1)                                        # (T*k,)
    dst = jnp.where(pos < capacity, flat_e * capacity + pos,
                    cfg.num_experts * capacity)                   # drop slot
    src = jnp.repeat(jnp.arange(t), cfg.top_k)
    buf = jnp.zeros((cfg.num_experts * capacity + 1, d), xf.dtype)
    buf = buf.at[dst].set(xf[src], mode="drop")
    out = _expert_ffn(p["experts"], cfg,
                      buf[:-1].reshape(cfg.num_experts, capacity, d))
    out = out.reshape(-1, d)
    picked = jnp.where((dst < cfg.num_experts * capacity)[:, None],
                       out[jnp.minimum(dst, cfg.num_experts * capacity - 1)],
                       0.0)
    y = (picked.reshape(t, cfg.top_k, d)
         * gates[..., None]).sum(axis=1)                          # (T, D)
    return y, aux


def moe_apply_dense(p, cfg: MoEConfig, x):
    """Auto-shardable MoE. x (B, S, D) -> (B, S, D), plus aux loss."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    capacity = int(np.ceil(b * s * cfg.top_k / cfg.num_experts
                           * cfg.capacity_factor))
    capacity = max(8, -(-capacity // 8) * 8)
    y, aux = _dispatch_combine(p, cfg, xf, capacity)
    if cfg.num_shared:
        shared_cfg = FFNConfig(d, cfg.d_expert * cfg.num_shared, act=cfg.act)
        y = y + ffn_apply(p["shared"], shared_cfg, xf)
    return y.reshape(b, s, d), aux


def moe_apply_tp_shard_map(p, cfg: MoEConfig, x, mesh, *, tp_axis="model",
                           sp_axis="data"):
    """Tensor-parallel experts — the E < mesh_axis case (mixtral: 8 experts
    on a 16-way 'model' axis, so EP cannot shard them).

    Megatron pattern: tokens are ALL-GATHERED over tp (in_spec demands full
    S per rank), every rank dispatches identically (routing is cheap and
    replicated), computes its F/tp slice of every expert it hosts, and the
    partial down-projections are REDUCE-SCATTERED back to the S-sharded
    residual (psum_scatter) — one AG + one RS of (tokens × d_model) per MoE
    layer, the classic TP collective pair, visible in the dry-run HLO."""
    assert cfg.num_shared == 0, "tp expert sharding: shared experts unused"
    b, s, d = x.shape
    sp_axes = (sp_axis,) if isinstance(sp_axis, str) else tuple(sp_axis)
    tp = mesh.shape[tp_axis]
    assert s % tp == 0, (s, tp)

    def local_fn(xl, router_w, experts):
        bl, sl, _ = xl.shape                      # sl == s (full, gathered)
        xf = xl.reshape(bl * sl, d)
        tloc = bl * sl
        capacity = int(np.ceil(tloc * cfg.top_k / cfg.num_experts
                               * cfg.capacity_factor))
        capacity = max(8, -(-capacity // 8) * 8)
        gates, eidx, aux = _route(router_w, cfg, xf)
        flat_e = eidx.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, cfg.num_experts, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot - 1).max(axis=-1)
        dst = jnp.where(pos < capacity, flat_e * capacity + pos,
                        cfg.num_experts * capacity)
        src = jnp.repeat(jnp.arange(tloc), cfg.top_k)
        buf = jnp.zeros((cfg.num_experts * capacity + 1, d), xf.dtype)
        buf = buf.at[dst].set(xf[src], mode="drop")[:-1]
        buf = buf.reshape(cfg.num_experts, capacity, d)
        # F/tp slice of every expert on this rank
        out = _expert_ffn(experts, cfg, buf)      # partial over F slices
        out = out.reshape(cfg.num_experts * capacity, d)
        picked = jnp.where((dst < cfg.num_experts * capacity)[:, None],
                           out[jnp.minimum(dst, cfg.num_experts * capacity - 1)],
                           0.0)
        y = (picked.reshape(tloc, cfg.top_k, d) * gates[..., None]).sum(axis=1)
        y = y.reshape(bl, sl, d)
        # partial sums over F → reduce-scatter along S back to the residual
        y = jax.lax.psum_scatter(y, tp_axis, scatter_dimension=1, tiled=True)
        aux = jax.lax.pmean(aux, sp_axes)
        return y, aux

    experts_spec = {"w_gate": P(None, None, tp_axis),
                    "w_up": P(None, None, tp_axis),
                    "w_down": P(None, tp_axis, None)}
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(sp_axes, None, None), P(None, None), experts_spec),
        out_specs=(P(sp_axes, tp_axis, None), P()),
        check=False)
    return fn(x, p["router"], p["experts"])


def moe_apply_shard_map(p, cfg: MoEConfig, x, mesh, *, ep_axis="model",
                        sp_axis="data"):
    """Production MoE: sequence-parallel routing + expert-parallel compute.

    Token dispatch happens per (sp, ep) shard; expert buffers are exchanged
    with a pair of all_to_alls over the EP axis.  Inside the shard_map the
    code is per-device SPMD — exactly what a hand-written distributed MoE
    runtime does, but in five lines of jax.lax collectives.
    """
    b, s, d = x.shape
    ep = mesh.shape[ep_axis]
    assert cfg.num_experts % ep == 0, (cfg.num_experts, ep)
    sp_axes = (sp_axis,) if isinstance(sp_axis, str) else tuple(sp_axis)

    def local_fn(xl, router_w, experts, shared):
        bl, sl, _ = xl.shape
        xf = xl.reshape(bl * sl, d)
        tloc = bl * sl
        capacity = int(np.ceil(tloc * cfg.top_k / cfg.num_experts
                               * cfg.capacity_factor))
        capacity = max(8, -(-capacity // 8) * 8)
        gates, eidx, aux = _route(router_w, cfg, xf)
        flat_e = eidx.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, cfg.num_experts, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot - 1).max(axis=-1)
        dst = jnp.where(pos < capacity, flat_e * capacity + pos,
                        cfg.num_experts * capacity)
        src = jnp.repeat(jnp.arange(tloc), cfg.top_k)
        buf = jnp.zeros((cfg.num_experts * capacity + 1, d), xf.dtype)
        buf = buf.at[dst].set(xf[src], mode="drop")[:-1]
        buf = buf.reshape(cfg.num_experts, capacity, d)
        # EP exchange: (E, C, D) -> (E/ep, C*ep, D)
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)
        out = _expert_ffn(experts, cfg, buf)
        # and back: (E/ep, C*ep, D) -> (E, C, D)
        out = jax.lax.all_to_all(out, ep_axis, split_axis=1, concat_axis=0,
                                 tiled=True)
        out = out.reshape(cfg.num_experts * capacity, d)
        picked = jnp.where((dst < cfg.num_experts * capacity)[:, None],
                           out[jnp.minimum(dst, cfg.num_experts * capacity - 1)],
                           0.0)
        y = (picked.reshape(tloc, cfg.top_k, d) * gates[..., None]).sum(axis=1)
        if cfg.num_shared:
            shared_cfg = FFNConfig(d, cfg.d_expert * cfg.num_shared, act=cfg.act)
            y = y + ffn_apply(shared, shared_cfg, xf)
        aux = jax.lax.pmean(aux, sp_axes + (ep_axis,))
        return y.reshape(bl, sl, d), aux

    experts_local_spec = {
        "w_gate": P(ep_axis, None, None),
        "w_up": P(ep_axis, None, None),
        "w_down": P(ep_axis, None, None),
    }
    shared = p.get("shared", {})
    shared_spec = jax.tree.map(lambda _: P(None), shared)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(sp_axes, ep_axis, None), P(None, None),
                  experts_local_spec, shared_spec),
        out_specs=(P(sp_axes, ep_axis, None), P()),
        check=False)
    return fn(x, p["router"], p["experts"], shared)
