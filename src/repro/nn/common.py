"""Shared NN building blocks (functional, param-trees as nested dicts).

Every init function returns ``(params, specs)`` where ``specs`` mirrors the
param tree with ``jax.sharding.PartitionSpec`` leaves — the distribution layer
consumes the spec tree directly, so sharding is declared where parameters are
born instead of via path-regex guessing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def truncated_normal_init(key, shape, dtype, stddev):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) \
        .astype(dtype) * stddev


def dense_init(key, in_dim: int, out_dim: int, dtype, spec: P,
               stddev: float | None = None, bias: bool = False,
               bias_spec: P | None = None):
    """Weight (in, out) + optional bias (out,)."""
    stddev = stddev if stddev is not None else in_dim ** -0.5
    w = truncated_normal_init(key, (in_dim, out_dim), dtype, stddev)
    params, specs = {"w": w}, {"w": spec}
    if bias:
        params["b"] = jnp.zeros((out_dim,), dtype)
        specs["b"] = bias_spec if bias_spec is not None else P(spec[-1])
    return params, specs


def dense_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# --------------------------------------------------------------------- #
# norms                                                                 #
# --------------------------------------------------------------------- #

def norm_init(dim: int, dtype, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}, {"scale": P(None)}
    elif kind == "layernorm":
        return ({"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
                {"scale": P(None), "bias": P(None)})
    raise ValueError(kind)


def norm_apply(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(x.dtype)
    var = (xf ** 2).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head qk-norm (Qwen3): normalise the last (head_dim) axis."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------- #
# embedding                                                             #
# --------------------------------------------------------------------- #

def embed_init(key, vocab: int, dim: int, dtype):
    w = truncated_normal_init(key, (vocab, dim), dtype, 1.0)
    return {"embedding": w}, {"embedding": P("model", "data")}


def embed_apply(p, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def embed_attend(p, x):
    """Tied readout: logits = x @ E^T."""
    return x @ p["embedding"].T


# --------------------------------------------------------------------- #
# misc                                                                  #
# --------------------------------------------------------------------- #

def sinusoidal_positions(seq: int, dim: int, dtype=jnp.float32):
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, dtype)


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


FFN_ACTS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu2": squared_relu,
    "relu": jax.nn.relu,
}
