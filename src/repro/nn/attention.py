"""Grouped-query attention with sliding-window, qk-norm, KV cache, and a
memory-efficient chunked (online-softmax) path for long sequences.

Layout conventions (TP-friendly):
  * heads live on the 'model' mesh axis; all attention einsums keep the kv-head
    axis as a batch dimension (GQA is computed grouped — KV is never repeated
    to query-head count, saving Hq/Hkv × KV memory traffic);
  * the output projection contracts the sharded head axis → GSPMD inserts the
    single Megatron-style all-reduce per layer.

The chunked path is a lax.scan over KV blocks with running (max, denom)
accumulators — flash-attention restructured for XLA:TPU (the MXU consumes the
per-chunk (Sq × Ck) score tiles; VMEM never holds the full S×S matrix). It is
exact (tested against the dense path) and is what makes prefill_32k lowerable
at 32k and SWA archs at 500k context.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.nn.common import dense_init, rms_head_norm
from repro.nn.rope import apply_mrope, apply_rope

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qk_norm: bool = False
    bias: bool = False                  # bias on ALL projections (whisper)
    qkv_bias: bool = False              # bias on q/k/v only (qwen2-vl)
    sliding_window: int | None = None   # None = full attention
    softmax_scale: float | None = None
    rope_kind: str = "rope"             # 'rope' | 'mrope' | 'none'
    rope_theta: float = 1e4
    mrope_sections: tuple = (16, 24, 24)


def _apply_pos_emb(cfg: AttnConfig, q, k, positions):
    """positions: (B,S) for rope, (3,B,S) for mrope.  q (B,S,Hkv,G,dh)."""
    if cfg.rope_kind == "none":
        return q, k
    b, s, hkv, g, dh = q.shape
    qf = q.reshape(b, s, hkv * g, dh)
    if cfg.rope_kind == "rope":
        qf, k = apply_rope(qf, k, positions, dh, cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        qf, k = apply_mrope(qf, k, positions, dh, cfg.rope_theta,
                            cfg.mrope_sections)
    else:
        raise ValueError(cfg.rope_kind)
    return qf.reshape(b, s, hkv, g, dh), k


def attn_init(key, cfg: AttnConfig, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    params, specs = {}, {}
    in_bias = cfg.bias or cfg.qkv_bias
    for name, k, od in (("wq", kq, hq * dh), ("wk", kk, hkv * dh),
                        ("wv", kv, hkv * dh)):
        p, s = dense_init(k, d, od, dtype, P("data", "model"), bias=in_bias)
        params[name], specs[name] = p, s
    p, s = dense_init(ko, hq * dh, d, dtype, P("model", "data"), bias=cfg.bias,
                      stddev=(hq * dh) ** -0.5)
    params["wo"], specs["wo"] = p, s
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((dh,), dtype)
        params["k_norm"] = jnp.ones((dh,), dtype)
        specs["q_norm"] = P(None)
        specs["k_norm"] = P(None)
    return params, specs


def qkv_project(p, cfg: AttnConfig, x):
    """x (B,S,D) -> q (B,S,Hkv,G,dh), k,v (B,S,Hkv,dh).

    The flat projection outputs are pinned to the 'model' axis (head/TP
    sharding) BEFORE the head reshape so the backward builds (D, H·dh/tp)
    weight grads instead of full matrices + full-size all-reduces
    (§Perf hillclimb iteration 2; same reasoning as ffn._tp_inner)."""
    b, s, _ = x.shape
    g = cfg.n_heads // cfg.n_kv_heads
    q = _tp_cols(x @ p["wq"]["w"], s) \
        .reshape(b, s, cfg.n_kv_heads, g, cfg.d_head)
    k = _tp_cols(x @ p["wk"]["w"], s) \
        .reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = _tp_cols(x @ p["wv"]["w"], s) \
        .reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.bias or cfg.qkv_bias:
        q = q + p["wq"]["b"].reshape(cfg.n_kv_heads, g, cfg.d_head)
        k = k + p["wk"]["b"].reshape(cfg.n_kv_heads, cfg.d_head)
        v = v + p["wv"]["b"].reshape(cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    return q, k, v


def out_project(p, cfg: AttnConfig, o):
    """o (B,S,Hkv,G,dh) -> (B,S,D)."""
    b, s = o.shape[:2]
    of = _tp_cols(o.reshape(b, s, cfg.n_heads * cfg.d_head), s)
    y = of @ p["wo"]["w"]
    if cfg.bias:
        y = y + p["wo"]["b"]
    return y


def _tp_cols(h, s):
    """Pin flat head columns to 'model' (training/prefill only — decode's
    1-token projections stay replicated to keep the KV cache C-sharded).
    Width-gated like ffn._tp_inner: narrow projections (small models, GQA
    K/V) don't amortise the resharding."""
    from repro.distributed.sharding import (BATCH_AXES, TP_INNER_MIN_COLS,
                                            constrain)
    if s == 1 or h.shape[-1] < TP_INNER_MIN_COLS:
        return h
    from jax.sharding import PartitionSpec
    return constrain(h, PartitionSpec(BATCH_AXES, None, "model"))


def _mask_bias(q_pos, kv_pos, causal: bool, window, dtype):
    """(Sq, Sk) additive mask from absolute positions.

    ``window`` may be None (full), a static int, or a *traced* int32 scalar
    where <= 0 means full attention — the traced form is what lets a scan
    over layers mix SWA and global layers (hymba) under one stacked body.
    Negative kv positions are UNIVERSALLY invalid (chunk/ring padding)."""
    dpos = q_pos[:, None] - kv_pos[None, :]
    ok = kv_pos[None, :] >= 0
    if causal:
        ok &= dpos >= 0
    if window is not None:
        if isinstance(window, (int, np.integer)):
            if window > 0:
                ok &= dpos < window
        else:  # traced scalar
            ok &= jnp.where(window > 0, dpos < window, True)
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


def attend_dense(q, k, v, q_pos, kv_pos, *, causal: bool, window: int | None,
                 scale: float):
    """Reference/short-seq path. q (B,Sq,Hkv,G,dh), k/v (B,Sk,Hkv,dh)."""
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) * scale
    scores = scores.astype(jnp.float32) + _mask_bias(
        q_pos, kv_pos, causal, window, jnp.float32)[None, None, None]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", w, v)


def attend_chunked(q, k, v, q_pos, kv_pos, *, causal: bool, window: int | None,
                   scale: float, chunk: int = 1024):
    """Exact online-softmax attention, scanned over KV chunks.

    Memory: O(Sq · chunk) score tile instead of O(Sq · Sk)."""
    b, sq, hkv, g, dh = q.shape
    sk = k.shape[1]
    if sk % chunk:
        pad = (-sk) % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)  # < 0: invalid
        sk += pad
    nc = sk // chunk
    kc = k.reshape(b, nc, chunk, hkv, dh)
    vc = v.reshape(b, nc, chunk, hkv, dh)
    pc = kv_pos.reshape(nc, chunk)

    qf = q.astype(jnp.float32)

    def step(carry, inputs):
        m, l, acc = carry                     # (B,Hkv,G,Sq), same, (B,Sq,Hkv,G,dh)
        kb, vb, pb = inputs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb.astype(jnp.float32)) * scale
        s = s + _mask_bias(q_pos, pb, causal, window, jnp.float32)[None, None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p_ = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p_.sum(axis=-1)
        acc = acc * jnp.moveaxis(alpha, 3, 1)[..., None] + jnp.einsum(
            "bhgqk,bkhd->bqhgd", p_, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, g, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc))
    l = jnp.maximum(l, 1e-30)
    out = acc / jnp.moveaxis(l, 3, 1)[..., None]
    return out.astype(q.dtype)


_USE_CFG = object()


def attention(p, cfg: AttnConfig, x, positions, *, causal: bool = True,
              chunked_threshold: int = 2048, kv_override=None,
              kv_positions=None, window=_USE_CFG, return_kv: bool = False):
    """Full-sequence attention (training / prefill / cross-attention).

    ``kv_override=(k, v)`` turns this into cross-attention (whisper decoder):
    q comes from x, kv from the encoder output projections.
    ``window`` may be a traced scalar (hybrid archs mix SWA/global layers
    under one scanned block) — default uses cfg.sliding_window.
    ``return_kv=True`` also returns the post-rope (k, v) — the prefill path
    turns them into the decode cache."""
    scale = cfg.softmax_scale or cfg.d_head ** -0.5
    if window is _USE_CFG:
        window = cfg.sliding_window
    q, k, v = qkv_project(p, cfg, x)
    if kv_override is not None:
        k, v = kv_override
    else:
        q, k = _apply_pos_emb(cfg, q, k, positions)
    mpos = positions[0] if cfg.rope_kind == "mrope" else positions
    q_pos = mpos[0]                       # mask positions, shared across batch
    kv_pos = kv_positions if kv_positions is not None else q_pos
    if k.shape[1] > chunked_threshold:
        o = attend_chunked(q, k, v, q_pos, kv_pos, causal=causal,
                           window=window, scale=scale)
    else:
        o = attend_dense(q, k, v, q_pos, kv_pos, causal=causal,
                         window=window, scale=scale)
    y = out_project(p, cfg, o)
    if return_kv:
        return y, (k, v)
    return y


# --------------------------------------------------------------------- #
# decode with KV cache                                                  #
# --------------------------------------------------------------------- #

def init_kv_cache(cfg: AttnConfig, batch: int, max_len: int, dtype):
    """Cache length for SWA layers is bounded by the window (ring buffer)."""
    clen = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, clen, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.full((batch, clen), -1, jnp.int32)}


def decode_step(p, cfg: AttnConfig, x, cache, cur_pos, window=_USE_CFG):
    """One-token decode. x (B,1,D); cur_pos (B,) absolute position.

    Ring-buffer insert at cur_pos % cache_len; the stored absolute positions
    drive the mask, so SWA and full attention share one code path."""
    scale = cfg.softmax_scale or cfg.d_head ** -0.5
    if window is _USE_CFG:
        window = cfg.sliding_window
    q, k_new, v_new = qkv_project(p, cfg, x)
    if cfg.rope_kind == "mrope":
        rope_pos = jnp.broadcast_to(cur_pos[None, :, None], (3, x.shape[0], 1))
    else:
        rope_pos = cur_pos[:, None]
    q, k_new = _apply_pos_emb(cfg, q, k_new, rope_pos)
    clen = cache["k"].shape[1]
    slot = (cur_pos % clen).astype(jnp.int32)                     # (B,)
    bidx = jnp.arange(x.shape[0])
    # NOTE: XLA:CPU float-normalises bf16 scatter/DUS through f32 (visible
    # as a full-cache convert round-trip in dry-run HLO); XLA:TPU executes
    # bf16 cache updates natively — EXPERIMENTS.md §Dry-run quantifies the
    # delta.  A one-hot select variant measured strictly worse on CPU.
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])
    pos = cache["pos"].at[bidx, slot].set(cur_pos)
    # scores over the whole ring buffer; invalid slots have pos == -1.
    # K stays in cache dtype with f32 ACCUMULATION (preferred_element_type):
    # upcasting the ring would chain an f32 copy of the whole cache through
    # the layer-scan carry (observed as a 9 GiB convert+DUS in the dry-run).
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale     # (B,Hkv,G,1,C)
    dpos = cur_pos[:, None] - pos                                  # (B,C)
    ok = (pos >= 0) & (dpos >= 0)
    if window is not None:
        if isinstance(window, (int, np.integer)):
            if window > 0:
                ok &= dpos < window
        else:  # traced scalar; <= 0 means full attention
            ok &= jnp.where(window > 0, dpos < window, True)
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(x.dtype), v)
    return out_project(p, cfg, o), {"k": k, "v": v, "pos": pos}
