"""Mamba2 — state-space duality (SSD), chunked (arXiv 2405.21060 §6).

The SSD algorithm computes a selective-SSM scan as: quadratic attention-like
matmuls *within* chunks + a low-rank state recurrence *between* chunks.  On
TPU this is the right decomposition for the same reason the paper's M3 is
(DESIGN.md §2): everything becomes dense MXU matmuls over chunk-sized tiles,
with the only sequential dependency carried through an (H, P, N) state —
O(S/Q) scan steps instead of O(S).

Shapes: x (B,S,H,P) heads×headdim, A (H,) decay rates, B̃/C̃ (B,S,G,N)
state projections (G groups broadcast to H heads), dt (B,S,H) step sizes.
Decode keeps a recurrent state (B,H,P,N) + a depthwise-conv ring buffer —
constant memory at 500k context, which is why mamba2/hymba own `long_500k`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.nn.common import dense_init, norm_apply


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def proj_dim(self) -> int:
        # [z (gate), x, B, C, dt]
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads


def ssm_init(key, cfg: SSMConfig, dtype):
    kin, kconv, kdt, ka, kout = jax.random.split(key, 5)
    params, specs = {}, {}
    p, s = dense_init(kin, cfg.d_model, cfg.proj_dim, dtype, P("data", "model"))
    params["in_proj"], specs["in_proj"] = p, s
    params["conv_w"] = jax.random.normal(
        kconv, (cfg.d_conv, cfg.conv_dim), dtype) * cfg.d_conv ** -0.5
    params["conv_b"] = jnp.zeros((cfg.conv_dim,), dtype)
    specs["conv_w"], specs["conv_b"] = P(None, "model"), P("model")
    # dt bias: softplus^-1 of uniform [dt_min, dt_max] (mamba2 init)
    u = jax.random.uniform(kdt, (cfg.n_heads,), jnp.float32)
    dt0 = jnp.exp(u * (np.log(cfg.dt_max) - np.log(cfg.dt_min)) + np.log(cfg.dt_min))
    params["dt_bias"] = (dt0 + jnp.log(-jnp.expm1(-dt0))).astype(jnp.float32)
    specs["dt_bias"] = P("model")
    params["A_log"] = jnp.log(jax.random.uniform(ka, (cfg.n_heads,), jnp.float32,
                                                 1.0, 16.0))
    params["D"] = jnp.ones((cfg.n_heads,), jnp.float32)
    specs["A_log"], specs["D"] = P("model"), P("model")
    params["norm_scale"] = jnp.ones((cfg.d_inner,), dtype)
    specs["norm_scale"] = P("model")
    p, s = dense_init(kout, cfg.d_inner, cfg.d_model, dtype, P("model", "data"),
                      stddev=cfg.d_inner ** -0.5)
    params["out_proj"], specs["out_proj"] = p, s
    return params, specs


def _segsum(x):
    """x (..., L) -> (..., L, L) with out[i,j] = sum_{j<k<=i} x[k], -inf above diag."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = np.tril(np.ones((l, l), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_scan(x, dt, a, b, c, chunk: int, initial_state=None):
    """Chunked SSD.  x (B,S,H,P), dt (B,S,H) (post-softplus), a (H,) negative,
    b/c (B,S,G,N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bs, s, h, p_ = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xf = (x * dt[..., None]).astype(jnp.float32)           # dt-weighted input
    adt = (a[None, None, :] * dt).astype(jnp.float32)      # (B,S,H)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    # chunked views
    xc = xf.reshape(bs, nc, chunk, h, p_)
    ac = adt.reshape(bs, nc, chunk, h).transpose(0, 3, 1, 2)   # (B,H,C,L)
    bc = bf.reshape(bs, nc, chunk, g, n)
    cc = cf.reshape(bs, nc, chunk, g, n)
    # broadcast groups to heads
    bch = jnp.repeat(bc, rep, axis=3)                           # (B,C,L,H,N)
    cch = jnp.repeat(cc, rep, axis=3)

    a_cs = jnp.cumsum(ac, axis=-1)                              # (B,H,C,L)

    # 1. intra-chunk (quadratic, attention-like)
    ldecay = jnp.exp(_segsum(ac))                               # (B,H,C,L,L)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        cch, bch, ldecay, xc)

    # 2. chunk states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)               # (B,H,C,L)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", bch, decay_states, xc)

    # 3. inter-chunk recurrence over chunk states
    if initial_state is None:
        initial_state = jnp.zeros((bs, h, p_, n), jnp.float32)
    states = jnp.concatenate([initial_state[:, None], states], axis=1)
    # (B, C+1, H, P, N)
    chunk_sum = a_cs[..., -1]                                   # (B,H,C)
    decay_chunk = jnp.exp(_segsum(jnp.pad(chunk_sum, ((0, 0), (0, 0), (1, 0)))))
    decay_chunk = jnp.where(jnp.isfinite(decay_chunk), decay_chunk, 0.0)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    states_in, final_state = new_states[:, :-1], new_states[:, -1]

    # 4. state -> output within chunk
    state_decay_out = jnp.exp(a_cs)                             # (B,H,C,L)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", cch, states_in,
                       state_decay_out)

    y = (y_diag + y_off).reshape(bs, s, h, p_).astype(x.dtype)
    return y, final_state


def _split_proj(cfg: SSMConfig, zxbcdt):
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + cfg.conv_dim]
    dt = zxbcdt[..., di + cfg.conv_dim:]
    return z, xbc, dt


def _split_xbc(cfg: SSMConfig, xbc, batch_shape):
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    x = xbc[..., :di].reshape(*batch_shape, cfg.n_heads, cfg.head_dim)
    b = xbc[..., di: di + gn].reshape(*batch_shape, cfg.n_groups, cfg.d_state)
    c = xbc[..., di + gn:].reshape(*batch_shape, cfg.n_groups, cfg.d_state)
    return x, b, c


def ssm_apply(p, cfg: SSMConfig, u, *, return_cache: bool = False):
    """Full-sequence Mamba2 mixer. u (B,S,D) -> (B,S,D).

    ``return_cache=True`` additionally returns the decode cache after the
    last position (prefill: final SSM state + conv ring tail)."""
    bs, s, _ = u.shape
    z, xbc_raw, dt = _split_proj(cfg, u @ p["in_proj"]["w"])
    # causal depthwise conv over seq
    xbc_pad = jnp.pad(xbc_raw, ((0, 0), (cfg.d_conv - 1, 0), (0, 0)))
    conv = sum(xbc_pad[:, i: i + s] * p["conv_w"][i][None, None, :]
               for i in range(cfg.d_conv)) + p["conv_b"]
    xbc = jax.nn.silu(conv)
    x, b, c = _split_xbc(cfg, xbc, (bs, s))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    pad = (-s) % cfg.chunk
    if pad:
        # pad seq to a chunk multiple with dt=0 — exp(a·0)=1 and x·dt=0, so
        # padded steps are exact identities on the state (prefill stays exact)
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bp = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cp = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, final_state = ssd_scan(xp, dtp, a, bp, cp, cfg.chunk)
        y = y[:, :s]
    else:
        y, final_state = ssd_scan(x, dt, a, b, c, cfg.chunk)
    y = y + x * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bs, s, cfg.d_inner)
    # gated RMSNorm (mamba2: norm(y * silu(z)))
    y = norm_apply({"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    out = y @ p["out_proj"]["w"]
    if return_cache:
        cache = {"conv": xbc_pad[:, s: s + cfg.d_conv - 1]
                 if s >= cfg.d_conv - 1 else xbc_pad[:, -(cfg.d_conv - 1):],
                 "state": final_state}
        return out, cache
    return out


# --------------------------------------------------------------------- #
# decode                                                                #
# --------------------------------------------------------------------- #

def init_ssm_cache(cfg: SSMConfig, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
        "state": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                           jnp.float32),
    }


def ssm_decode_step(p, cfg: SSMConfig, u, cache):
    """One token. u (B,1,D).  O(1) state update — no KV growth."""
    bs = u.shape[0]
    z, xbc_new, dt = _split_proj(cfg, u[:, 0] @ p["in_proj"]["w"])
    window = jnp.concatenate([cache["conv"], xbc_new[:, None]], axis=1)  # (B,K,C)
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv)
    x, b, c = _split_xbc(cfg, xbc, (bs,))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    a = -jnp.exp(p["A_log"])                                      # (H,)
    rep = cfg.n_heads // cfg.n_groups
    bh = jnp.repeat(b, rep, axis=1).astype(jnp.float32)           # (B,H,N)
    ch = jnp.repeat(c, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(a[None] * dt)                                 # (B,H)
    xdt = x.astype(jnp.float32) * dt[..., None]                   # (B,H,P)
    state = cache["state"] * decay[..., None, None] + \
        jnp.einsum("bhp,bhn->bhpn", xdt, bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, ch).astype(u.dtype)
    y = y + x * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(bs, cfg.d_inner)
    y = norm_apply({"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    out = (y @ p["out_proj"]["w"])[:, None]
    return out, {"conv": window[:, 1:], "state": state}
