"""Hymba-style hybrid block: attention heads and SSM (Mamba2) heads run in
PARALLEL on the same input and their outputs are fused.

This is the paper's construction applied inside one transformer layer
(DESIGN.md §4.3): the attention path and the SSM path are two *independent
sub-networks sharing an input*, exactly like two members of a ParallelMLP
population.  Their parameters receive gradients only through their own
output — fusing them costs nothing in correctness and buys one pass over
the input activations (the paper's locality argument).

Fusion follows Hymba (arXiv 2411.13676 §2.1): each path's output is
RMS-normalised (so magnitudes are comparable) and combined with learned
per-path scalars β:

    y = β_attn · norm(attn_path(x)) + β_ssm · norm(ssm_path(x))

(each path includes its own output projection).

The attention sub-path reuses repro.nn.attention (GQA + SWA + cache); the
SSM sub-path reuses repro.nn.ssm (chunked SSD).  Both caches live side by
side in the layer cache — the SWA ring buffer is bounded and the SSM state
is O(1), which is what makes hymba a `long_500k` arch.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import attention as attn_lib
from repro.nn import ssm as ssm_lib
from repro.nn.attention import AttnConfig
from repro.nn.ssm import SSMConfig


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    attn: AttnConfig
    ssm: SSMConfig

    @property
    def d_model(self) -> int:
        return self.attn.d_model


def _headnorm(scale, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def hybrid_init(key, cfg: HybridConfig, dtype):
    ka, ks = jax.random.split(key)
    pa, sa = attn_lib.attn_init(ka, cfg.attn, dtype)
    ps, ss = ssm_lib.ssm_init(ks, cfg.ssm, dtype)
    params = {
        "attn": pa, "ssm": ps,
        "attn_out_norm": jnp.ones((cfg.d_model,), dtype),
        "ssm_out_norm": jnp.ones((cfg.d_model,), dtype),
        "beta": jnp.ones((2,), jnp.float32),
    }
    specs = {
        "attn": sa, "ssm": ss,
        "attn_out_norm": P(None), "ssm_out_norm": P(None),
        "beta": P(None),
    }
    return params, specs


def hybrid_apply(p, cfg: HybridConfig, x, positions, *, window=attn_lib._USE_CFG):
    """Full-sequence mixer. x (B,S,D) -> (B,S,D)."""
    ya = attn_lib.attention(p["attn"], cfg.attn, x, positions, window=window)
    ys = ssm_lib.ssm_apply(p["ssm"], cfg.ssm, x)
    beta = p["beta"].astype(jnp.float32)
    out = (beta[0] * _headnorm(p["attn_out_norm"], ya).astype(jnp.float32)
           + beta[1] * _headnorm(p["ssm_out_norm"], ys).astype(jnp.float32))
    return out.astype(x.dtype)


def init_hybrid_cache(cfg: HybridConfig, batch: int, max_len: int, dtype):
    return {
        "attn": attn_lib.init_kv_cache(cfg.attn, batch, max_len, dtype),
        "ssm": ssm_lib.init_ssm_cache(cfg.ssm, batch, dtype),
    }


def hybrid_decode_step(p, cfg: HybridConfig, x, cache, cur_pos,
                       window=attn_lib._USE_CFG):
    """One-token decode through both paths. x (B,1,D)."""
    ya, attn_cache = attn_lib.decode_step(p["attn"], cfg.attn, x, cache["attn"],
                                          cur_pos, window=window)
    ys, ssm_cache = ssm_lib.ssm_decode_step(p["ssm"], cfg.ssm, x, cache["ssm"])
    beta = p["beta"].astype(jnp.float32)
    out = (beta[0] * _headnorm(p["attn_out_norm"], ya).astype(jnp.float32)
           + beta[1] * _headnorm(p["ssm_out_norm"], ys).astype(jnp.float32))
    return out.astype(x.dtype), {"attn": attn_cache, "ssm": ssm_cache}
