"""Model definitions: unified decoder LM, encoder-decoder (whisper),
population model (the paper's ParallelMLPs lives in repro.core)."""
from repro.models import encdec, lm
from repro.models.encdec import EncDecConfig
from repro.models.lm import LayerSpec, LMConfig

__all__ = ["lm", "encdec", "LMConfig", "LayerSpec", "EncDecConfig"]
