"""Whisper-style encoder–decoder (audio arch, per assignment).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, d_model); sinusoidal positions are
added here (whisper's encoder uses sinusoidal embeddings post-conv).  The
decoder uses a learned position table sized by ``max_target`` — whisper's
natural 448 for real use, 32k for the assigned decode_32k dry-run cell
(documented in DESIGN.md).

Layers: encoder = [self-attn (non-causal) + FFN]; decoder = [causal
self-attn + cross-attn + FFN]; LayerNorm + biases everywhere (whisper).
Both stacks are single lax.scans over stacked params.  Cross-attention
K/V are projected ONCE from the encoder output per decoder layer and act
as a static cache during decode.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ACT_RESIDUAL, BATCH_AXES, constrain, stack_spec
from repro.nn import attention as attn_lib
from repro.nn.attention import AttnConfig
from repro.nn.common import (dense_init, embed_init, norm_apply, norm_init,
                             sinusoidal_positions, truncated_normal_init)
from repro.nn.ffn import FFNConfig, ffn_apply, ffn_init

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    vocab: int
    d_model: int
    n_enc_layers: int
    n_dec_layers: int
    attn: AttnConfig
    ffn: FFNConfig
    max_target: int = 448
    param_dtype: str = "bfloat16"
    vocab_pad_to: int = 128

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab + m - 1) // m) * m

    def num_params(self) -> int:
        abs_p, _ = abstract_params(self)
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abs_p))

    def num_active_params(self) -> int:
        return self.num_params()


def _enc_layer_init(key, cfg: EncDecConfig):
    ka, kf = jax.random.split(key)
    params, specs = {}, {}
    for nm in ("norm1", "norm2"):
        params[nm], specs[nm] = norm_init(cfg.d_model, cfg.dtype, "layernorm")
    params["attn"], specs["attn"] = attn_lib.attn_init(ka, cfg.attn, cfg.dtype)
    params["ffn"], specs["ffn"] = ffn_init(kf, cfg.ffn, cfg.dtype)
    return params, specs


def _dec_layer_init(key, cfg: EncDecConfig):
    ka, kc, kf = jax.random.split(key, 3)
    params, specs = {}, {}
    for nm in ("norm1", "norm2", "norm3"):
        params[nm], specs[nm] = norm_init(cfg.d_model, cfg.dtype, "layernorm")
    params["self_attn"], specs["self_attn"] = attn_lib.attn_init(
        ka, cfg.attn, cfg.dtype)
    params["cross_attn"], specs["cross_attn"] = attn_lib.attn_init(
        kc, cfg.attn, cfg.dtype)
    params["ffn"], specs["ffn"] = ffn_init(kf, cfg.ffn, cfg.dtype)
    return params, specs


def init_params(key, cfg: EncDecConfig):
    ke, kd, kt, kp = jax.random.split(key, 4)
    params, specs = {}, {}
    ekeys = jax.random.split(ke, cfg.n_enc_layers)
    params["encoder"] = jax.vmap(
        lambda k: _enc_layer_init(k, cfg)[0])(ekeys)
    specs["encoder"] = stack_spec(_enc_layer_init(ke, cfg)[1])
    dkeys = jax.random.split(kd, cfg.n_dec_layers)
    params["decoder"] = jax.vmap(
        lambda k: _dec_layer_init(k, cfg)[0])(dkeys)
    specs["decoder"] = stack_spec(_dec_layer_init(kd, cfg)[1])
    p, s = embed_init(kt, cfg.padded_vocab, cfg.d_model, cfg.dtype)
    params["embed"], specs["embed"] = p, s          # tied readout (whisper)
    params["dec_pos"] = truncated_normal_init(
        kp, (cfg.max_target, cfg.d_model), cfg.dtype, 0.02)
    specs["dec_pos"] = P(None, None)
    for nm in ("enc_norm", "dec_norm"):
        params[nm], specs[nm] = norm_init(cfg.d_model, cfg.dtype, "layernorm")
    return params, specs


def abstract_params(cfg: EncDecConfig):
    box = {}

    def build(key):
        p, s = init_params(key, cfg)
        box["specs"] = s
        return p

    abs_p = jax.eval_shape(build, jax.random.PRNGKey(0))
    return abs_p, box["specs"]


# --------------------------------------------------------------------- #
# forward                                                               #
# --------------------------------------------------------------------- #

def encode(params, cfg: EncDecConfig, frames):
    """frames (B,S,D) stub embeddings -> encoder states (B,S,D)."""
    b, s, _ = frames.shape
    x = frames.astype(cfg.dtype) + sinusoidal_positions(
        s, cfg.d_model, cfg.dtype)[None]
    x = constrain(x, ACT_RESIDUAL)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(xc, lp):
        xc = constrain(xc, ACT_RESIDUAL)
        h = norm_apply(lp["norm1"], xc)
        xc = xc + attn_lib.attention(lp["attn"], cfg.attn, h, positions,
                                     causal=False, window=None)
        xc = xc + ffn_apply(lp["ffn"], cfg.ffn, norm_apply(lp["norm2"], xc))
        return xc, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder"])
    return norm_apply(params["enc_norm"], x)


def cross_kv(params, cfg: EncDecConfig, enc_out):
    """Project per-decoder-layer cross K/V from encoder states (the static
    half of the decode cache).  -> (L,B,Senc,hkv,dh) ×2."""
    def one(lp):
        b, s, _ = enc_out.shape
        k = (enc_out @ lp["cross_attn"]["wk"]["w"]).reshape(
            b, s, cfg.attn.n_kv_heads, cfg.attn.d_head)
        v = (enc_out @ lp["cross_attn"]["wv"]["w"]).reshape(
            b, s, cfg.attn.n_kv_heads, cfg.attn.d_head)
        if cfg.attn.bias:
            k = k + lp["cross_attn"]["wk"]["b"].reshape(
                cfg.attn.n_kv_heads, cfg.attn.d_head)
            v = v + lp["cross_attn"]["wv"]["b"].reshape(
                cfg.attn.n_kv_heads, cfg.attn.d_head)
        return k, v

    return jax.lax.map(lambda lp: one(lp), params["decoder"])


def _decoder_stack(params, cfg: EncDecConfig, x, positions, enc_out, enc_pos):
    """Shared by train forward (full target sequence)."""
    def body(xc, lp):
        xc = constrain(xc, ACT_RESIDUAL)
        h = norm_apply(lp["norm1"], xc)
        xc = xc + attn_lib.attention(lp["self_attn"], cfg.attn, h, positions,
                                     causal=True, window=None)
        h = norm_apply(lp["norm2"], xc)
        k, v = _layer_cross_kv(lp, cfg, enc_out)
        xc = xc + attn_lib.attention(lp["cross_attn"], cfg.attn, h, positions,
                                     causal=False, window=None,
                                     kv_override=(k, v), kv_positions=enc_pos)
        xc = xc + ffn_apply(lp["ffn"], cfg.ffn, norm_apply(lp["norm3"], xc))
        return xc, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["decoder"])
    return norm_apply(params["dec_norm"], x)


def _layer_cross_kv(lp, cfg: EncDecConfig, enc_out):
    b, s, _ = enc_out.shape
    k = (enc_out @ lp["cross_attn"]["wk"]["w"]).reshape(
        b, s, cfg.attn.n_kv_heads, cfg.attn.d_head)
    v = (enc_out @ lp["cross_attn"]["wv"]["w"]).reshape(
        b, s, cfg.attn.n_kv_heads, cfg.attn.d_head)
    if cfg.attn.bias:
        k = k + lp["cross_attn"]["wk"]["b"].reshape(
            cfg.attn.n_kv_heads, cfg.attn.d_head)
        v = v + lp["cross_attn"]["wv"]["b"].reshape(
            cfg.attn.n_kv_heads, cfg.attn.d_head)
    return k, v


def _vocab_mask(cfg, dtype):
    if cfg.padded_vocab == cfg.vocab:
        return None
    return jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, NEG) \
        .astype(dtype)


def forward(params, cfg: EncDecConfig, batch, mesh=None):
    """batch: {frames (B,Se,D), tokens (B,St)} -> (logits (B,St,Vp), aux)."""
    enc_out = encode(params, cfg, batch["frames"])
    b, st = batch["tokens"].shape
    se = enc_out.shape[1]
    x = jnp.take(params["embed"]["embedding"], batch["tokens"], axis=0)
    x = x + params["dec_pos"][:st][None]
    positions = jnp.broadcast_to(jnp.arange(st, dtype=jnp.int32)[None], (b, st))
    enc_pos = jnp.arange(se, dtype=jnp.int32)
    x = _decoder_stack(params, cfg, x, positions, enc_out, enc_pos)
    logits = x @ params["embed"]["embedding"].T
    logits = constrain(logits, P(BATCH_AXES, None, "model"))
    return logits, jnp.zeros((), jnp.float32)


def loss_and_metrics(params, cfg: EncDecConfig, batch, mesh=None):
    logits, aux = forward(params, cfg, batch, mesh)
    lf = logits.astype(jnp.float32)
    vm = _vocab_mask(cfg, jnp.float32)
    if vm is not None:
        lf = lf + vm
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(
        lf, batch["labels"][..., None].astype(jnp.int32), axis=-1)[..., 0]
    loss = (lse - gold).mean()
    return loss, {"loss": loss, "aux_loss": aux}


def make_train_step(cfg: EncDecConfig, optimizer, lr_fn, *, num_micro: int = 1,
                    grad_clip: float = 1.0, mesh=None):
    from repro.optim import apply_updates, clip_by_global_norm

    def loss_fn(p, mb):
        return loss_and_metrics(p, cfg, mb, mesh)

    def train_step(params, opt_state, batch, step):
        lr = lr_fn(step)
        if num_micro == 1:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(num_micro, x.shape[0] // num_micro,
                                    *x.shape[1:]), batch)

            def micro(carry, m):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, m)
                return (jax.tree.map(lambda a, bb: a + bb.astype(jnp.float32),
                                     g_acc, g), l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / num_micro, gsum)
            loss = lsum / num_micro
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        upd, opt_state = optimizer.update(grads, opt_state, params, lr)
        params = apply_updates(params, upd)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return train_step


# --------------------------------------------------------------------- #
# serving                                                               #
# --------------------------------------------------------------------- #

def init_self_caches(cfg: EncDecConfig, batch: int, max_len: int):
    proto = attn_lib.init_kv_cache(cfg.attn, batch, max_len, cfg.dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_dec_layers,) + a.shape),
        proto)


def make_serve_step(cfg: EncDecConfig, mesh=None):
    """One decoder token against (self KV ring, static cross KV).

    caches = {"self": stacked ring caches, "cross_k"/"cross_v":
    (L,B,Se,hkv,dh), "enc_pos": (Se,)}."""

    def serve_step(params, caches, batch, cur_pos):
        b = batch["tokens"].shape[0]
        x = jnp.take(params["embed"]["embedding"], batch["tokens"], axis=0)
        x = x + params["dec_pos"][cur_pos][:, None]
        scale = cfg.attn.softmax_scale or cfg.attn.d_head ** -0.5

        def body(xc, xs):
            lp, cache, ck, cv = xs
            h = norm_apply(lp["norm1"], xc)
            mix, cache = attn_lib.decode_step(lp["self_attn"], cfg.attn, h,
                                              cache, cur_pos, window=None)
            xc = xc + mix
            # cross attention: 1 query token vs static encoder K/V
            h = norm_apply(lp["norm2"], xc)
            q, _, _ = attn_lib.qkv_project(lp["cross_attn"], cfg.attn, h)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q, ck,
                           preferred_element_type=jnp.float32) * scale
            w = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(xc.dtype), cv)
            xc = xc + attn_lib.out_project(lp["cross_attn"], cfg.attn, o)
            xc = xc + ffn_apply(lp["ffn"], cfg.ffn, norm_apply(lp["norm3"], xc))
            return xc, cache

        x, new_self = jax.lax.scan(
            body, x, (params["decoder"], caches["self"],
                      caches["cross_k"], caches["cross_v"]))
        x = norm_apply(params["dec_norm"], x)
        logits = x @ params["embed"]["embedding"].T
        vm = _vocab_mask(cfg, logits.dtype)
        if vm is not None:
            logits = logits + vm
        caches = dict(caches, self=new_self)
        return logits, caches

    return serve_step


def prepare_serve_caches(params, cfg: EncDecConfig, frames, max_len: int):
    """Encode + project cross K/V + empty self caches."""
    enc_out = encode(params, cfg, frames)
    ck, cv = cross_kv(params, cfg, enc_out)
    return {"self": init_self_caches(cfg, frames.shape[0], max_len),
            "cross_k": ck, "cross_v": cv}
