"""Unified decoder-only LM covering the assigned architecture families:

  dense GQA            — qwen3, h2o-danube (SWA), command-r (parallel block,
                         LayerNorm, tied+scaled logits), nemotron (relu²),
                         qwen2-vl backbone (M-RoPE, qkv-bias, embeds frontend)
  MoE                  — deepseek-moe (64e top-6 + shared, first-layer dense),
                         mixtral (8e top-2, SWA)
  SSM (attention-free) — mamba2 (SSD blocks, no FFN)
  hybrid               — hymba (parallel attn+SSM heads per layer, mixed
                         SWA/global pattern)

One config, one forward, one train/serve step.  Layers are grouped into
maximal runs with identical structure; each group is ONE ``lax.scan`` over
stacked parameters (constant-size HLO regardless of depth — what keeps 96-
layer dry-run compiles tractable) with per-layer scalars (SWA window) passed
as scanned operands, so heterogeneous window patterns don't break stacking.

Sharding: specs are declared at init (see nn/*.py) — FSDP over 'data',
TP/EP over 'model', batch over ('pod','data'), SP residual (S over 'model').
The forward only places *constraints* at group boundaries; GSPMD propagates
through layer internals.  All specs degrade gracefully off-mesh (CPU tests).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (ACT_RESIDUAL, BATCH_AXES, constrain,
                                        stack_spec)
from repro.nn import attention as attn_lib
from repro.nn import ffn as ffn_lib
from repro.nn import hybrid as hybrid_lib
from repro.nn import ssm as ssm_lib
from repro.nn.attention import AttnConfig
from repro.nn.common import embed_init, norm_apply, norm_init, dense_init
from repro.nn.ffn import FFNConfig, MoEConfig
from repro.nn.hybrid import HybridConfig
from repro.nn.ssm import SSMConfig

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"     # attn | ssm | hybrid
    ffn: str = "dense"      # dense | moe | none
    window: int = 0         # 0 = full attention; >0 = SWA window


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    vocab: int
    d_model: int
    layers: tuple                      # tuple[LayerSpec]
    attn: Optional[AttnConfig] = None
    ssm: Optional[SSMConfig] = None
    ffn: Optional[FFNConfig] = None    # dense FFN (per-layer width overrides
    dense_ffn0: Optional[FFNConfig] = None  # ffn for 'dense' layers in MoE archs
    moe: Optional[MoEConfig] = None
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    logit_scale: float = 1.0           # command-r multiplies logits
    parallel_block: bool = False       # command-r: x + attn(n(x)) + ffn(n(x))
    param_dtype: str = "bfloat16"
    remat: bool = True
    moe_impl: str = "dense"            # dense | shard_map  (EP all-to-all)
    frontend: str = "tokens"           # tokens | embeds (vlm/audio stub)
    vocab_pad_to: int = 128

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab + m - 1) // m) * m

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def groups(self):
        """Maximal runs of layers with identical (mixer, ffn) structure."""
        out = []
        i = 0
        while i < len(self.layers):
            j = i
            sig = (self.layers[i].mixer, self.layers[i].ffn)
            while (j + 1 < len(self.layers)
                   and (self.layers[j + 1].mixer, self.layers[j + 1].ffn) == sig):
                j += 1
            out.append((sig, self.layers[i:j + 1], i))
            i = j + 1
        return out

    def hybrid_cfg(self) -> HybridConfig:
        return HybridConfig(self.attn, self.ssm)

    def num_params(self) -> int:
        """Exact parameter count (from abstract shapes, no allocation)."""
        abs_p, _ = abstract_params(self)
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abs_p))

    def num_active_params(self) -> int:
        """Active-per-token params (MoE: top_k + shared experts only)."""
        total = self.num_params()
        if self.moe is None:
            return total
        n_moe_layers = sum(1 for l in self.layers if l.ffn == "moe")
        per_expert = 3 * self.d_model * self.moe.d_expert
        inactive = n_moe_layers * per_expert * (self.moe.num_experts
                                                - self.moe.top_k)
        return total - inactive


# --------------------------------------------------------------------- #
# init                                                                  #
# --------------------------------------------------------------------- #

def _init_layer(key, cfg: LMConfig, mixer: str, ffn_kind: str):
    km, kf, k_ = jax.random.split(key, 3)
    params, specs = {}, {}
    p, s = norm_init(cfg.d_model, cfg.dtype, cfg.norm)
    params["norm1"], specs["norm1"] = p, s
    if mixer == "attn":
        p, s = attn_lib.attn_init(km, cfg.attn, cfg.dtype)
    elif mixer == "ssm":
        p, s = ssm_lib.ssm_init(km, cfg.ssm, cfg.dtype)
    elif mixer == "hybrid":
        p, s = hybrid_lib.hybrid_init(km, cfg.hybrid_cfg(), cfg.dtype)
    else:
        raise ValueError(mixer)
    params["mixer"], specs["mixer"] = p, s
    if ffn_kind != "none":
        if not cfg.parallel_block:
            p, s = norm_init(cfg.d_model, cfg.dtype, cfg.norm)
            params["norm2"], specs["norm2"] = p, s
        if ffn_kind == "dense":
            fcfg = cfg.dense_ffn0 if (cfg.moe is not None
                                      and cfg.dense_ffn0 is not None) else cfg.ffn
            p, s = ffn_lib.ffn_init(kf, fcfg, cfg.dtype)
        elif ffn_kind == "moe":
            p, s = ffn_lib.moe_init(kf, cfg.moe, cfg.dtype)
        else:
            raise ValueError(ffn_kind)
        params["ffn"], specs["ffn"] = p, s
    return params, specs


def init_params(key, cfg: LMConfig):
    """Returns (params, specs).  Group layers are vmap-stacked on axis 0."""
    keys = jax.random.split(key, 3 + len(cfg.groups()))
    params, specs = {}, {}
    if cfg.frontend == "tokens" or cfg.tie_embeddings:
        p, s = embed_init(keys[0], cfg.padded_vocab, cfg.d_model, cfg.dtype)
        params["embed"], specs["embed"] = p, s
    for gi, ((mixer, ffn_kind), layer_specs, _) in enumerate(cfg.groups()):
        n = len(layer_specs)
        gkeys = jax.random.split(keys[3 + gi], n)
        gp, gs = jax.vmap(
            lambda k: _init_layer(k, cfg, mixer, ffn_kind)[0])(gkeys), None
        _, gs = _init_layer(keys[3 + gi], cfg, mixer, ffn_kind)
        params[f"g{gi}"] = gp
        specs[f"g{gi}"] = stack_spec(gs)
    p, s = norm_init(cfg.d_model, cfg.dtype, cfg.norm)
    params["final_norm"], specs["final_norm"] = p, s
    if not cfg.tie_embeddings:
        p, s = dense_init(keys[1], cfg.d_model, cfg.padded_vocab, cfg.dtype,
                          P("data", "model"))
        params["lm_head"], specs["lm_head"] = p, s
    return params, specs


def abstract_params(cfg: LMConfig):
    """(ShapeDtypeStruct tree, spec tree) with ZERO allocation — the spec
    tree (plain Python objects) is captured through a side-channel while
    eval_shape traces the param construction abstractly."""
    box = {}

    def build(key):
        p, s = init_params(key, cfg)
        box["specs"] = s
        return p

    abs_p = jax.eval_shape(build, jax.random.PRNGKey(0))
    return abs_p, box["specs"]


# --------------------------------------------------------------------- #
# forward                                                               #
# --------------------------------------------------------------------- #

def _vocab_mask(cfg: LMConfig, dtype):
    if cfg.padded_vocab == cfg.vocab:
        return None
    return jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, NEG) \
        .astype(dtype)


def _layer_apply(lp, cfg: LMConfig, mixer: str, ffn_kind: str, x, positions,
                 window, mesh):
    """One transformer block.  window: traced int32 (0 = full attention)."""
    h = norm_apply(lp["norm1"], x)
    if mixer == "attn":
        mix = attn_lib.attention(lp["mixer"], cfg.attn, h, positions,
                                 window=window)
    elif mixer == "ssm":
        mix = ssm_lib.ssm_apply(lp["mixer"], cfg.ssm, h)
    else:
        mix = hybrid_lib.hybrid_apply(lp["mixer"], cfg.hybrid_cfg(), h,
                                      positions, window=window)
    aux = jnp.zeros((), jnp.float32)
    if ffn_kind == "none":
        return x + mix, aux
    if cfg.parallel_block:
        f = h                              # command-r: shared input norm
    else:
        x = x + mix
        f = norm_apply(lp["norm2"], x)
    if ffn_kind == "dense":
        fcfg = cfg.dense_ffn0 if (cfg.moe is not None
                                  and cfg.dense_ffn0 is not None) else cfg.ffn
        y = ffn_lib.ffn_apply(lp["ffn"], fcfg, f)
    else:
        y, aux = _moe_dispatch(lp["ffn"], cfg, f, mesh)
    if cfg.parallel_block:
        return x + mix + y, aux
    return x + y, aux


def _moe_dispatch(pf, cfg: LMConfig, f, mesh):
    """Pick the MoE execution strategy: EP all-to-all (experts ≥ mesh axis),
    TP experts (experts < mesh axis), or the auto-shardable dense path."""
    if cfg.moe_impl == "shard_map" and mesh is not None:
        if cfg.moe.sharding == "tp":
            return ffn_lib.moe_apply_tp_shard_map(
                pf, cfg.moe, f, mesh, tp_axis="model", sp_axis=_dp_axes())
        return ffn_lib.moe_apply_shard_map(
            pf, cfg.moe, f, mesh, ep_axis="model", sp_axis=_dp_axes())
    return ffn_lib.moe_apply_dense(pf, cfg.moe, f)


def _dp_axes():
    from repro.distributed.sharding import mesh_axis_sizes
    sizes = mesh_axis_sizes()
    return tuple(a for a in BATCH_AXES if a in sizes) or ("data",)


def _group_scan(gp, cfg: LMConfig, mixer, ffn_kind, layer_specs, x, positions,
                mesh):
    windows = jnp.asarray([ls.window for ls in layer_specs], jnp.int32)

    def body(carry, xs):
        xc, aux = carry
        lp, win = xs
        xc = constrain(xc, ACT_RESIDUAL)
        xc, a = _layer_apply(lp, cfg, mixer, ffn_kind, xc, positions, win, mesh)
        return (xc, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (gp, windows))
    return x, aux


def _embed_in(params, cfg: LMConfig, batch):
    if cfg.frontend == "embeds":
        x = batch["embeds"].astype(cfg.dtype)
    else:
        tokens = batch["tokens"]
        x = jnp.take(params["embed"]["embedding"], tokens, axis=0)
    return x


def _positions_for(cfg: LMConfig, b, s):
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.attn is not None and cfg.attn.rope_kind == "mrope":
        return jnp.broadcast_to(pos[None], (3, b, s))   # text-equivalent stub
    return pos


def forward(params, cfg: LMConfig, batch, mesh=None):
    """batch: {tokens|embeds} -> (logits (B,S,Vp), aux_loss)."""
    x = _embed_in(params, cfg, batch)
    b, s = x.shape[:2]
    positions = _positions_for(cfg, b, s)
    x = constrain(x, ACT_RESIDUAL)
    aux = jnp.zeros((), jnp.float32)
    for gi, ((mixer, ffn_kind), layer_specs, _) in enumerate(cfg.groups()):
        x, a = _group_scan(params[f"g{gi}"], cfg, mixer, ffn_kind, layer_specs,
                           x, positions, mesh)
        aux = aux + a
    x = norm_apply(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["embedding"].T
    else:
        logits = x @ params["lm_head"]["w"]
    logits = logits * cfg.logit_scale
    logits = constrain(logits, P(BATCH_AXES, None, "model"))
    return logits, aux


def softmax_xent(logits, labels, cfg: LMConfig, z_loss: float = 1e-4):
    """Mean NLL over tokens; pad-vocab slots masked; z-loss regulariser."""
    lf = logits.astype(jnp.float32)
    vm = _vocab_mask(cfg, jnp.float32)
    if vm is not None:
        lf = lf + vm
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = lse - gold
    loss = nll.mean()
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse ** 2)
    return loss


def loss_and_metrics(params, cfg: LMConfig, batch, mesh=None):
    logits, aux = forward(params, cfg, batch, mesh)
    loss = softmax_xent(logits, batch["labels"], cfg)
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": jnp.asarray(batch["labels"].size, jnp.float32)}


# --------------------------------------------------------------------- #
# train step                                                            #
# --------------------------------------------------------------------- #

def make_train_step(cfg: LMConfig, optimizer, lr_fn, *, num_micro: int = 1,
                    grad_clip: float = 1.0, mesh=None, param_specs=None,
                    accum_dtype=jnp.float32):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    ``num_micro > 1`` runs gradient accumulation as a lax.scan over
    microbatches (f32 accumulator tree, params closure) — live activations
    scale with global_batch/num_micro, which is what lets 1M-token steps of
    a 340B model fit 16 GB chips.  ``param_specs`` pins per-micro grads and
    the accumulator to the parameter sharding so the data-axis reduction
    lowers as reduce-scatter instead of full-size all-reduce (§Perf
    hillclimb iteration 3)."""
    from repro.optim import apply_updates, clip_by_global_norm

    def loss_fn(p, mb):
        return loss_and_metrics(p, cfg, mb, mesh)

    def to_param_sharding(tree):
        if param_specs is None:
            return tree
        return jax.tree.map(
            lambda g, sp: constrain(g, sp), tree, param_specs,
            is_leaf=lambda x: not isinstance(x, dict))

    def train_step(params, opt_state, batch, step):
        lr = lr_fn(step)
        if num_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = to_param_sharding(
                jax.tree.map(lambda g: g.astype(jnp.float32), grads))
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(num_micro, x.shape[0] // num_micro,
                                    *x.shape[1:]), batch)
            mb = jax.tree.map(
                lambda x: constrain(x, P(None, BATCH_AXES)), mb)

            def micro(carry, m):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, m)
                g = to_param_sharding(
                    jax.tree.map(lambda x: x.astype(accum_dtype), g))
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                return (g_acc, l_acc + l), None

            # accum_dtype=bf16 halves the two live grad buffers (accumulator
            # + per-micro grads) — the 340B policy (§Perf iteration 5)
            zeros = to_param_sharding(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / num_micro, gsum)
            loss = lsum / num_micro
            metrics = {"loss": loss}
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        upd, opt_state = optimizer.update(grads, opt_state, params, lr)
        params = apply_updates(params, upd)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, out

    return train_step


# --------------------------------------------------------------------- #
# serving: cache init / prefill / decode                                #
# --------------------------------------------------------------------- #

def _layer_cache_proto(cfg: LMConfig, mixer: str, batch: int, max_len: int):
    if mixer == "attn":
        return attn_lib.init_kv_cache(cfg.attn, batch, max_len, cfg.dtype)
    if mixer == "ssm":
        return ssm_lib.init_ssm_cache(cfg.ssm, batch, cfg.dtype)
    return hybrid_lib.init_hybrid_cache(cfg.hybrid_cfg(), batch, max_len,
                                        cfg.dtype)


def init_caches(cfg: LMConfig, batch: int, max_len: int):
    """Per-group stacked decode caches (leading axis = layers in group).

    SWA layers allocate only ``window`` slots (ring buffer) — a group mixing
    window sizes allocates max(window, ...) per spec uniformity."""
    caches = {}
    for gi, ((mixer, _), layer_specs, _) in enumerate(cfg.groups()):
        n = len(layer_specs)
        wins = [ls.window for ls in layer_specs]
        if mixer in ("attn", "hybrid") and all(w > 0 for w in wins):
            eff_len = min(max_len, max(wins))
        else:
            eff_len = max_len
        proto = _layer_cache_proto(cfg, mixer, batch, eff_len)
        caches[f"g{gi}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), proto)
    return caches


def generic_cache_specs(abs_caches):
    """Spec tree for any cache pytree (lm groups or whisper self/cross):
    KV length / SSM heads shard over 'model', batch over ('pod','data')."""
    def leaf(path, a):
        names = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
        if "conv" in names:       # (L,B,K,convdim)
            return P(None, BATCH_AXES, None, "model")
        if "state" in names:      # (L,B,H,P,N)
            return P(None, BATCH_AXES, "model", None, None)
        if "pos" in names:        # (L,B,C)
            return P(None, BATCH_AXES, "model")
        # k/v/cross: (L,B,C,hkv,dh)
        return P(None, BATCH_AXES, "model", None, None)

    return jax.tree_util.tree_map_with_path(leaf, abs_caches)


def cache_specs(cfg: LMConfig, batch: int, max_len: int):
    abs_caches = jax.eval_shape(partial(init_caches, cfg, batch, max_len))
    return generic_cache_specs(abs_caches)


def make_serve_step(cfg: LMConfig, mesh=None):
    """One-token decode: (params, caches, batch{tokens|embeds}, cur_pos) ->
    (logits (B,1,Vp), new caches)."""

    def serve_step(params, caches, batch, cur_pos):
        caches = dict(caches)
        x = _embed_in(params, cfg, batch)          # (B,1,D)
        x = constrain(x, P(BATCH_AXES, None, None))
        for gi, ((mixer, ffn_kind), layer_specs, _) in enumerate(cfg.groups()):
            windows = jnp.asarray([ls.window for ls in layer_specs], jnp.int32)

            def body(xc_cache, xs, mixer=mixer, ffn_kind=ffn_kind):
                # caches ride in the CARRY and are updated in place with
                # dynamic-update-slice — XLA aliases the (donated) buffer, so
                # decode never holds a second copy of the multi-GB KV stack
                xc, gcaches = xc_cache
                lp, win, li = xs
                cache = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(
                        c, li, 0, keepdims=False), gcaches)
                h = norm_apply(lp["norm1"], xc)
                if mixer == "attn":
                    mix, cache = attn_lib.decode_step(
                        lp["mixer"], cfg.attn, h, cache, cur_pos, window=win)
                elif mixer == "ssm":
                    mix, cache = ssm_lib.ssm_decode_step(
                        lp["mixer"], cfg.ssm, h, cache)
                else:
                    mix, cache = hybrid_lib.hybrid_decode_step(
                        lp["mixer"], cfg.hybrid_cfg(), h, cache, cur_pos,
                        window=win)
                def write(gc):
                    return jax.tree.map(
                        lambda c, u: jax.lax.dynamic_update_index_in_dim(
                            c, u.astype(c.dtype), li, 0), gc, cache)

                if ffn_kind == "none":
                    return (xc + mix, write(gcaches)), None
                if cfg.parallel_block:
                    f = h
                else:
                    xc = xc + mix
                    f = norm_apply(lp["norm2"], xc)
                if ffn_kind == "dense":
                    fcfg = cfg.dense_ffn0 if (cfg.moe is not None and
                                              cfg.dense_ffn0 is not None) \
                        else cfg.ffn
                    y = ffn_lib.ffn_apply(lp["ffn"], fcfg, f)
                else:
                    y, _ = ffn_lib.moe_apply_dense(lp["ffn"], cfg.moe, f)
                out = xc + mix + y if cfg.parallel_block else xc + y
                return (out, write(gcaches)), None

            n_layers = len(layer_specs)
            (x, caches[f"g{gi}"]), _ = jax.lax.scan(
                body, (x, caches[f"g{gi}"]),
                (params[f"g{gi}"], windows,
                 jnp.arange(n_layers, dtype=jnp.int32)))
        x = norm_apply(params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["embedding"].T
        else:
            logits = x @ params["lm_head"]["w"]
        logits = logits * cfg.logit_scale
        vm = _vocab_mask(cfg, logits.dtype)
        if vm is not None:
            logits = logits + vm
        return logits, caches

    return serve_step


def prefill(params, cfg: LMConfig, batch, max_len: int, mesh=None):
    """Full-prompt forward that also builds decode caches.

    Returns (last-position logits (B,1,Vp), caches positioned after S)."""
    x = _embed_in(params, cfg, batch)
    b, s = x.shape[:2]
    positions = _positions_for(cfg, b, s)
    x = constrain(x, ACT_RESIDUAL)
    caches = init_caches(cfg, b, max_len)
    for gi, ((mixer, ffn_kind), layer_specs, _) in enumerate(cfg.groups()):
        windows = jnp.asarray([ls.window for ls in layer_specs], jnp.int32)
        clen = jax.tree.leaves(caches[f"g{gi}"])[0].shape[2] \
            if mixer != "ssm" else None

        def body(xc, xs, mixer=mixer, ffn_kind=ffn_kind, clen=clen):
            lp, win = xs
            h = norm_apply(lp["norm1"], xc)
            new_cache = None
            if mixer == "attn":
                mix, (k, v) = attn_lib.attention(
                    lp["mixer"], cfg.attn, h, positions, window=win,
                    return_kv=True)
                new_cache = _kv_to_ring(k, v, s, clen)
            elif mixer == "ssm":
                mix, new_cache = ssm_lib.ssm_apply(
                    lp["mixer"], cfg.ssm, h, return_cache=True)
            else:
                hc = cfg.hybrid_cfg()
                ya, (k, v) = attn_lib.attention(
                    lp["mixer"]["attn"], hc.attn, h, positions, window=win,
                    return_kv=True)
                ys, sc = ssm_lib.ssm_apply(lp["mixer"]["ssm"], hc.ssm, h,
                                           return_cache=True)
                beta = lp["mixer"]["beta"].astype(jnp.float32)
                mix = (beta[0] * hybrid_lib._headnorm(
                    lp["mixer"]["attn_out_norm"], ya).astype(jnp.float32)
                    + beta[1] * hybrid_lib._headnorm(
                        lp["mixer"]["ssm_out_norm"], ys).astype(jnp.float32)
                ).astype(xc.dtype)
                clen_a = clen
                new_cache = {"attn": _kv_to_ring(k, v, s, clen_a), "ssm": sc}
            if ffn_kind == "none":
                return xc + mix, new_cache
            if cfg.parallel_block:
                f = h
            else:
                xc = xc + mix
                f = norm_apply(lp["norm2"], xc)
            if ffn_kind == "dense":
                fcfg = cfg.dense_ffn0 if (cfg.moe is not None and
                                          cfg.dense_ffn0 is not None) \
                    else cfg.ffn
                y = ffn_lib.ffn_apply(lp["ffn"], fcfg, f)
            else:
                y, _ = _moe_dispatch(lp["ffn"], cfg, f, mesh)
            if cfg.parallel_block:
                return xc + mix + y, new_cache
            return xc + y, new_cache

        x, caches[f"g{gi}"] = jax.lax.scan(
            body, x, (params[f"g{gi}"], windows))
    x = norm_apply(params["final_norm"], x[:, -1:])
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["embedding"].T
    else:
        logits = x @ params["lm_head"]["w"]
    logits = logits * cfg.logit_scale
    vm = _vocab_mask(cfg, logits.dtype)
    if vm is not None:
        logits = logits + vm
    return logits, caches


def _kv_to_ring(k, v, s: int, clen: int):
    """Pack prefill (B,S,hkv,dh) k/v into the decode ring-buffer layout."""
    b = k.shape[0]
    take = min(s, clen)
    pos_tail = np.arange(s - take, s)
    slots = pos_tail % clen
    ck = jnp.zeros((b, clen) + k.shape[2:], k.dtype)
    cv = jnp.zeros((b, clen) + v.shape[2:], v.dtype)
    cpos = jnp.full((b, clen), -1, jnp.int32)
    ck = ck.at[:, slots].set(k[:, -take:])
    cv = cv.at[:, slots].set(v[:, -take:])
    cpos = cpos.at[:, slots].set(jnp.asarray(pos_tail, jnp.int32)[None])
    return {"k": ck, "v": cv, "pos": cpos}
