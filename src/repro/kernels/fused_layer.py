"""Fused population-layer kernel: block-diagonal GEMM + per-member bias +
per-segment activation in ONE Pallas pass (DESIGN.md §7).

The unfused ``bd_impl=pallas`` path runs every mid layer as three HBM round
trips — block-diag GEMM writes the pre-activations z, an XLA pass adds the
bias, seg_act reads z+b back and writes act(z+b)·mask — and the backward
mirrors them (seg_act_bwd materialises dz, then the transposed GEMM and dw
kernels read it).  Here the epilogue runs while the accumulator tile is
still in VMEM:

  forward   y  = act(z + b) · mask            (one kernel, z never in HBM)
            g' = act'(z + b) · mask           (the activation derivative,
                                               computed IN-REGISTER while z
                                               is live, emitted instead of z)
  backward  du = dy ⊙ g'  fused into ONE two-level-grid kernel — the
            transposed param step runs on the OUTER grid dimension, the
            batch tile on the INNER one, and each (step, tile) invocation
            forms du on the VPU right before both MXU contractions: the dx
            accumulation (per-batch-tile running sums in a (B, blk) f32
            scratch) and the dw parameter tile (accumulated across the
            inner batch tiles) — so neither z nor dz ever materialises in
            HBM in either direction, at ANY batch size, in a single launch.
            db = Σ_b dy·g' is one XLA fused reduce over arrays that exist
            anyway.

Grid/tile metadata is the ragged flattened step layout shared with
``kernels/block_diag.py`` (``BlockDiagLayout``); the per-step activation id
(the OUTPUT tile's segment activation) is scalar-prefetched and dispatched
through ``lax.switch`` over the ten paper activations, exactly like
kernels/seg_act.py — but only on the flush step of each output tile.

Mixed precision: operand tiles may be bf16 (``--compute-dtype bfloat16``);
the accumulator and the bias add are always f32 (``preferred_element_type``
+ f32 VMEM scratch), and outputs are cast back to the operand dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.activations import ACTIVATION_FNS
from repro.kernels.block_diag import tpu_compiler_params


def _deriv(fn):
    """Elementwise derivative of an activation, via vjp at ones — traced
    into the kernel body, so it runs on the VPU in the epilogue."""
    def d(x):
        return jax.vjp(fn, x)[1](jnp.ones_like(x))[0]
    return d


# (value, derivative) branch per activation — one lax.switch in the epilogue
_VAL_DERIV_BRANCHES = tuple(
    (lambda fn: (lambda x: (fn(x), _deriv(fn)(x))))(fn)
    for fn in ACTIVATION_FNS)
_VAL_BRANCHES = tuple(ACTIVATION_FNS)


# --------------------------------------------------------------------- #
# forward: GEMM + bias + activation epilogue                            #
# --------------------------------------------------------------------- #

def _make_fwd_kernel(with_deriv: bool):
    def kernel(ins_ref, w_ids, outs_ref, first_ref, last_ref, act_ref,
               x_ref, wb_ref, b_ref, m_ref, *out_and_scratch):
        if with_deriv:
            y_ref, g_ref, acc_ref = out_and_scratch
        else:
            y_ref, acc_ref = out_and_scratch
        s = pl.program_id(1)

        @pl.when(first_ref[s] == 1)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], wb_ref[...][0],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(last_ref[s] == 1)
        def _epilogue():
            u = acc_ref[...] + b_ref[...].astype(jnp.float32)
            m = m_ref[...].astype(jnp.float32)
            if with_deriv:
                y, g = jax.lax.switch(act_ref[s], _VAL_DERIV_BRANCHES, u)
                y_ref[...] = (y * m).astype(y_ref.dtype)
                g_ref[...] = (g * m).astype(g_ref.dtype)
            else:
                y = jax.lax.switch(act_ref[s], _VAL_BRANCHES, u)
                y_ref[...] = (y * m).astype(y_ref.dtype)
    return kernel


def fused_layer_fwd(x: jax.Array, wb: jax.Array, bias: jax.Array,
                    mask: jax.Array, s_in, s_w, s_out, s_first, s_last,
                    s_act, *, n_out_tiles: int, n_steps: int, block: int,
                    block_b: int, with_deriv: bool,
                    interpret: bool = False):
    """x (B, in_tiles·blk), wb (n_tiles, blk, blk), bias/mask (1, out·blk)
    → y (B, out_tiles·blk) [, g' (B, out_tiles·blk) when ``with_deriv``]."""
    b = x.shape[0]
    grid = (b // block_b, n_steps)
    h_out = n_out_tiles * block
    out_shape = [jax.ShapeDtypeStruct((b, h_out), x.dtype)]
    out_specs = [pl.BlockSpec(
        (block_b, block),
        lambda i, s, ins, w, outs, fr, la, act: (i, outs[s]))]
    if with_deriv:
        out_shape.append(jax.ShapeDtypeStruct((b, h_out), x.dtype))
        out_specs.append(pl.BlockSpec(
            (block_b, block),
            lambda i, s, ins, w, outs, fr, la, act: (i, outs[s])))
    y = pl.pallas_call(
        _make_fwd_kernel(with_deriv),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=6,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (block_b, block),
                    lambda i, s, ins, w, outs, fr, la, act: (i, ins[s])),
                pl.BlockSpec(
                    (1, block, block),
                    lambda i, s, ins, w, outs, fr, la, act: (w[s], 0, 0)),
                pl.BlockSpec(
                    (1, block),
                    lambda i, s, ins, w, outs, fr, la, act: (0, outs[s])),
                pl.BlockSpec(
                    (1, block),
                    lambda i, s, ins, w, outs, fr, la, act: (0, outs[s])),
            ],
            out_specs=out_specs if with_deriv else out_specs[0],
            scratch_shapes=[pltpu.VMEM((block_b, block), jnp.float32)],
        ),
        out_shape=out_shape if with_deriv else out_shape[0],
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary"),
            (block_b, block), (block, block), (1, block), (1, block),
            (block_b, block), (block_b, block), (block_b, block)),
        interpret=interpret,
    )(s_in, s_w, s_out, s_first, s_last, s_act, x, wb, bias, mask)
    return y


# --------------------------------------------------------------------- #
# forward, int8 weights: in-loop dequant + GEMM + bias + activation     #
# --------------------------------------------------------------------- #

def _int8_fwd_kernel(ins_ref, w_ids, outs_ref, first_ref, last_ref, act_ref,
                     sc_ref, x_ref, wb_ref, b_ref, m_ref, y_ref, acc_ref):
    """The serving twin of ``_make_fwd_kernel(False)`` for the int8 weight
    store (DESIGN.md §12): the step loads an int8 weight tile plus its f32
    per-member-per-tile scale (scalar-prefetched whole, indexed
    ``sc_ref[w_ids[s]]`` — no per-step blocked operand) and dequantizes ON
    THE VPU right before the MXU contraction — the f32 weight tile exists
    only in registers, never in HBM.  Same grid, same blocked-operand count
    as the f32 path, same epilogue: the launch count cannot differ from the
    f32/bf16 path."""
    s = pl.program_id(1)

    @pl.when(first_ref[s] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = (wb_ref[...][0].astype(jnp.float32) * sc_ref[w_ids[s]])
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(last_ref[s] == 1)
    def _epilogue():
        u = acc_ref[...] + b_ref[...].astype(jnp.float32)
        m = m_ref[...].astype(jnp.float32)
        y = jax.lax.switch(act_ref[s], _VAL_BRANCHES, u)
        y_ref[...] = (y * m).astype(y_ref.dtype)


def fused_layer_int8_fwd(x: jax.Array, wb_q: jax.Array, wb_scale: jax.Array,
                         bias: jax.Array, mask: jax.Array, s_in, s_w, s_out,
                         s_first, s_last, s_act, *, n_out_tiles: int,
                         n_steps: int, block: int, block_b: int,
                         interpret: bool = False):
    """x (B, in_tiles·blk), wb_q (n_tiles, blk, blk) int8, wb_scale
    (n_tiles,) f32 scalar-prefetch, bias/mask (1, out·blk) →
    y (B, out_tiles·blk).  Forward-only by construction — there is no
    ``with_deriv`` variant."""
    b = x.shape[0]
    grid = (b // block_b, n_steps)
    h_out = n_out_tiles * block
    return pl.pallas_call(
        _int8_fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=7,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (block_b, block),
                    lambda i, s, ins, w, outs, fr, la, act, sc: (i, ins[s])),
                pl.BlockSpec(
                    (1, block, block),
                    lambda i, s, ins, w, outs, fr, la, act, sc:
                        (w[s], 0, 0)),
                pl.BlockSpec(
                    (1, block),
                    lambda i, s, ins, w, outs, fr, la, act, sc:
                        (0, outs[s])),
                pl.BlockSpec(
                    (1, block),
                    lambda i, s, ins, w, outs, fr, la, act, sc:
                        (0, outs[s])),
            ],
            out_specs=pl.BlockSpec(
                (block_b, block),
                lambda i, s, ins, w, outs, fr, la, act, sc: (i, outs[s])),
            scratch_shapes=[pltpu.VMEM((block_b, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h_out), x.dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary"),
            (block_b, block), (block, block), (1, block), (1, block),
            (block_b, block), (block_b, block)),
        interpret=interpret,
    )(s_in, s_w, s_out, s_first, s_last, s_act, wb_scale, x, wb_q, bias,
      mask)


# --------------------------------------------------------------------- #
# backward: ONE two-level-grid pass — dx and dw, du = dy·g' in-register #
# --------------------------------------------------------------------- #

def _dx_dw_kernel(ins_ref, w_ids, outs_ref, first_ref, last_ref, q_ref,
                  dy_ref, g_ref, x_ref, wb_ref, dx_ref, dw_ref,
                  dx_acc_ref, dw_acc_ref):
    """ONE backward pass over a two-level grid (transposed param step s
    OUTER, batch tile i INNER): at step (s, i) the du tile (dy·g', out-tile
    space) and the x tile (= this step's dx output tile) are both live in
    VMEM, so the step emits its dw parameter tile (du^T·x, accumulated
    across the inner batch tiles in a (blk, blk) f32 scratch) alongside the
    dx accumulation — the dw sweep costs zero extra kernel launches and
    zero extra du reads at ANY batch size.

    dx state: each batch tile's running sum lives in its slice of a
    (B, blk) f32 scratch, zeroed at the first step of a reduction run; the
    running value is stored to the dx output block every step.  The block
    index (i, outs[s]) changes every step so each store is copied back to
    HBM, and since every output tile belongs to exactly ONE run per batch
    tile, the run's last (complete) store is sequentially the final writer
    of that block — partial sums written earlier are overwritten.
    Pass-through steps write the appended dummy dw slot (sliced off by the
    wrapper)."""
    s = pl.program_id(0)
    i = pl.program_id(1)
    nb = pl.num_programs(1)
    bb = dy_ref.shape[0]

    du = dy_ref[...] * g_ref[...]          # the VPU fusion: dz tile never
                                           # exists outside this register
    rows = pl.ds(i * bb, bb)
    prev = dx_acc_ref[rows, :]
    prev = jnp.where(first_ref[s] == 1, jnp.zeros_like(prev), prev)
    acc = prev + jax.lax.dot_general(
        du, wb_ref[...][0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    dx_acc_ref[rows, :] = acc
    dx_ref[...] = acc.astype(dx_ref.dtype)

    @pl.when(i == 0)
    def _init_dw():
        dw_acc_ref[...] = jnp.zeros_like(dw_acc_ref)

    dw_acc_ref[...] += jax.lax.dot_general(
        du, x_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == nb - 1)
    def _flush_dw():
        dw_ref[...] = dw_acc_ref[...].astype(dw_ref.dtype)[None]


def fused_layer_dx_dw(dy: jax.Array, gp: jax.Array, x: jax.Array,
                      wb_t: jax.Array, s_in_t, s_w_t, s_out_t, s_first_t,
                      s_last_t, s_q_t, *, n_in_tiles: int, n_steps_t: int,
                      n_param_blocks: int, block: int, block_b: int,
                      interpret: bool = False):
    """Single-pass backward at any batch size: → (dx, dWB) where dWB has
    the trailing dummy tile already sliced off.  Batch must be padded to a
    block_b multiple (the wrapper's ``_pad_axis`` guarantees it)."""
    b = dy.shape[0]
    if b % block_b:
        raise ValueError(
            f"fused one-pass backward needs batch padded to a block_b "
            f"multiple, got batch {b} with block_b {block_b}")
    grid = (n_steps_t, b // block_b)
    dx, dwb = pl.pallas_call(
        _dx_dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=6,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (block_b, block),
                    lambda s, i, ins, w, outs, fr, la, q: (i, ins[s])),
                pl.BlockSpec(
                    (block_b, block),
                    lambda s, i, ins, w, outs, fr, la, q: (i, ins[s])),
                pl.BlockSpec(
                    (block_b, block),
                    lambda s, i, ins, w, outs, fr, la, q: (i, outs[s])),
                pl.BlockSpec(
                    (1, block, block),
                    lambda s, i, ins, w, outs, fr, la, q: (w[s], 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec(
                    (block_b, block),
                    lambda s, i, ins, w, outs, fr, la, q: (i, outs[s])),
                pl.BlockSpec(
                    (1, block, block),
                    lambda s, i, ins, w, outs, fr, la, q: (q[s], 0, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((b, block), jnp.float32),
                            pltpu.VMEM((block, block), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, n_in_tiles * block), dy.dtype),
            jax.ShapeDtypeStruct((n_param_blocks + 1, block, block),
                                 dy.dtype),
        ],
        compiler_params=tpu_compiler_params(
            ("arbitrary", "arbitrary"),
            (block_b, block), (block_b, block), (block_b, block),
            (block, block), (block_b, block), (block, block),
            (b, block), (block, block)),
        interpret=interpret,
    )(s_in_t, s_w_t, s_out_t, s_first_t, s_last_t, s_q_t, dy, gp, x, wb_t)
    return dx, dwb[:n_param_blocks]
