"""Fused population-layer kernel: block-diagonal GEMM + per-member bias +
per-segment activation in ONE Pallas pass (DESIGN.md §7).

The unfused ``bd_impl=pallas`` path runs every mid layer as three HBM round
trips — block-diag GEMM writes the pre-activations z, an XLA pass adds the
bias, seg_act reads z+b back and writes act(z+b)·mask — and the backward
mirrors them (seg_act_bwd materialises dz, then the transposed GEMM and dw
kernels read it).  Here the epilogue runs while the accumulator tile is
still in VMEM:

  forward   y  = act(z + b) · mask            (one kernel, z never in HBM)
            g' = act'(z + b) · mask           (the activation derivative,
                                               computed IN-REGISTER while z
                                               is live, emitted instead of z)
  backward  du = dy ⊙ g'  fused INTO the transposed-GEMM (dx) and dw
            kernels — each reads the (dy, g') tile pair and forms du on the
            VPU right before the MXU contraction, so neither z nor dz ever
            materialises in HBM in either direction.  db = Σ_b dy·g' is one
            XLA fused reduce over arrays that exist anyway.

Grid/tile metadata is the ragged flattened step layout shared with
``kernels/block_diag.py`` (``BlockDiagLayout``); the per-step activation id
(the OUTPUT tile's segment activation) is scalar-prefetched and dispatched
through ``lax.switch`` over the ten paper activations, exactly like
kernels/seg_act.py — but only on the flush step of each output tile.

Mixed precision: operand tiles may be bf16 (``--compute-dtype bfloat16``);
the accumulator and the bias add are always f32 (``preferred_element_type``
+ f32 VMEM scratch), and outputs are cast back to the operand dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.activations import ACTIVATION_FNS
from repro.kernels.block_diag import tpu_compiler_params


def _deriv(fn):
    """Elementwise derivative of an activation, via vjp at ones — traced
    into the kernel body, so it runs on the VPU in the epilogue."""
    def d(x):
        return jax.vjp(fn, x)[1](jnp.ones_like(x))[0]
    return d


# (value, derivative) branch per activation — one lax.switch in the epilogue
_VAL_DERIV_BRANCHES = tuple(
    (lambda fn: (lambda x: (fn(x), _deriv(fn)(x))))(fn)
    for fn in ACTIVATION_FNS)
_VAL_BRANCHES = tuple(ACTIVATION_FNS)


# --------------------------------------------------------------------- #
# forward: GEMM + bias + activation epilogue                            #
# --------------------------------------------------------------------- #

def _make_fwd_kernel(with_deriv: bool):
    def kernel(ins_ref, w_ids, outs_ref, first_ref, last_ref, act_ref,
               x_ref, wb_ref, b_ref, m_ref, *out_and_scratch):
        if with_deriv:
            y_ref, g_ref, acc_ref = out_and_scratch
        else:
            y_ref, acc_ref = out_and_scratch
        s = pl.program_id(1)

        @pl.when(first_ref[s] == 1)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], wb_ref[...][0],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(last_ref[s] == 1)
        def _epilogue():
            u = acc_ref[...] + b_ref[...].astype(jnp.float32)
            m = m_ref[...].astype(jnp.float32)
            if with_deriv:
                y, g = jax.lax.switch(act_ref[s], _VAL_DERIV_BRANCHES, u)
                y_ref[...] = (y * m).astype(y_ref.dtype)
                g_ref[...] = (g * m).astype(g_ref.dtype)
            else:
                y = jax.lax.switch(act_ref[s], _VAL_BRANCHES, u)
                y_ref[...] = (y * m).astype(y_ref.dtype)
    return kernel


def fused_layer_fwd(x: jax.Array, wb: jax.Array, bias: jax.Array,
                    mask: jax.Array, s_in, s_w, s_out, s_first, s_last,
                    s_act, *, n_out_tiles: int, n_steps: int, block: int,
                    block_b: int, with_deriv: bool,
                    interpret: bool = False):
    """x (B, in_tiles·blk), wb (n_tiles, blk, blk), bias/mask (1, out·blk)
    → y (B, out_tiles·blk) [, g' (B, out_tiles·blk) when ``with_deriv``]."""
    b = x.shape[0]
    grid = (b // block_b, n_steps)
    h_out = n_out_tiles * block
    out_shape = [jax.ShapeDtypeStruct((b, h_out), x.dtype)]
    out_specs = [pl.BlockSpec(
        (block_b, block),
        lambda i, s, ins, w, outs, fr, la, act: (i, outs[s]))]
    if with_deriv:
        out_shape.append(jax.ShapeDtypeStruct((b, h_out), x.dtype))
        out_specs.append(pl.BlockSpec(
            (block_b, block),
            lambda i, s, ins, w, outs, fr, la, act: (i, outs[s])))
    y = pl.pallas_call(
        _make_fwd_kernel(with_deriv),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=6,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (block_b, block),
                    lambda i, s, ins, w, outs, fr, la, act: (i, ins[s])),
                pl.BlockSpec(
                    (1, block, block),
                    lambda i, s, ins, w, outs, fr, la, act: (w[s], 0, 0)),
                pl.BlockSpec(
                    (1, block),
                    lambda i, s, ins, w, outs, fr, la, act: (0, outs[s])),
                pl.BlockSpec(
                    (1, block),
                    lambda i, s, ins, w, outs, fr, la, act: (0, outs[s])),
            ],
            out_specs=out_specs if with_deriv else out_specs[0],
            scratch_shapes=[pltpu.VMEM((block_b, block), jnp.float32)],
        ),
        out_shape=out_shape if with_deriv else out_shape[0],
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary"),
            (block_b, block), (block, block), (1, block), (1, block),
            (block_b, block), (block_b, block), (block_b, block)),
        interpret=interpret,
    )(s_in, s_w, s_out, s_first, s_last, s_act, x, wb, bias, mask)
    return y


# --------------------------------------------------------------------- #
# backward: dx (transposed GEMM) and dw, with du = dy·g' in-register    #
# --------------------------------------------------------------------- #

def _dx_kernel(ins_ref, w_ids, outs_ref, first_ref, last_ref,
               dy_ref, g_ref, wb_ref, dx_ref, acc_ref):
    s = pl.program_id(1)

    @pl.when(first_ref[s] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    du = dy_ref[...] * g_ref[...]          # the VPU fusion: dz tile never
    acc_ref[...] += jax.lax.dot_general(   # exists outside this register
        du, wb_ref[...][0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(last_ref[s] == 1)
    def _flush():
        dx_ref[...] = acc_ref[...].astype(dx_ref.dtype)


def fused_layer_dx(dy: jax.Array, gp: jax.Array, wb_t: jax.Array,
                   s_in_t, s_w_t, s_out_t, s_first_t, s_last_t, *,
                   n_in_tiles: int, n_steps_t: int, block: int, block_b: int,
                   interpret: bool = False) -> jax.Array:
    """dy, g' (B, out_tiles·blk), wb_t transposed tiles → dx (B, in·blk)."""
    b = dy.shape[0]
    grid = (b // block_b, n_steps_t)
    return pl.pallas_call(
        _dx_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block),
                             lambda i, s, ins, w, outs, fr, la: (i, ins[s])),
                pl.BlockSpec((block_b, block),
                             lambda i, s, ins, w, outs, fr, la: (i, ins[s])),
                pl.BlockSpec((1, block, block),
                             lambda i, s, ins, w, outs, fr, la: (w[s], 0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (block_b, block),
                lambda i, s, ins, w, outs, fr, la: (i, outs[s])),
            scratch_shapes=[pltpu.VMEM((block_b, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_in_tiles * block), dy.dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary"),
            (block_b, block), (block_b, block), (block, block),
            (block_b, block), (block_b, block)),
        interpret=interpret,
    )(s_in_t, s_w_t, s_out_t, s_first_t, s_last_t, dy, gp, wb_t)


def _dx_dw_kernel(ins_ref, w_ids, outs_ref, first_ref, last_ref, q_ref,
                  dy_ref, g_ref, x_ref, wb_ref, dx_ref, dw_ref, acc_ref):
    """ONE backward pass (single-batch-tile case): at transposed step s the
    du tile (dy·g', out-tile space) and the x tile (= this step's dx output
    tile) are both live in VMEM, so the step emits its dw parameter tile
    (du^T·x) alongside the dx accumulation — the dw sweep costs zero extra
    grid steps and zero extra du reads.  Pass-through steps write the
    appended dummy dw slot (sliced off by the wrapper)."""
    s = pl.program_id(1)

    @pl.when(first_ref[s] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    du = dy_ref[...] * g_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        du, wb_ref[...][0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    dw_ref[...] = jax.lax.dot_general(
        du, x_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(dw_ref.dtype)[None]

    @pl.when(last_ref[s] == 1)
    def _flush():
        dx_ref[...] = acc_ref[...].astype(dx_ref.dtype)


def fused_layer_dx_dw(dy: jax.Array, gp: jax.Array, x: jax.Array,
                      wb_t: jax.Array, s_in_t, s_w_t, s_out_t, s_first_t,
                      s_last_t, s_q_t, *, n_in_tiles: int, n_steps_t: int,
                      n_param_blocks: int, block: int, block_b: int,
                      interpret: bool = False):
    """Single-pass backward for B ≤ block_b: → (dx, dWB) where dWB has the
    trailing dummy tile already sliced off."""
    b = dy.shape[0]
    if b != block_b:
        raise ValueError(
            f"fused one-pass backward needs exactly one batch tile, got "
            f"batch {b} with block_b {block_b}")
    grid = (1, n_steps_t)
    dx, dwb = pl.pallas_call(
        _dx_dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=6,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (block_b, block),
                    lambda i, s, ins, w, outs, fr, la, q: (i, ins[s])),
                pl.BlockSpec(
                    (block_b, block),
                    lambda i, s, ins, w, outs, fr, la, q: (i, ins[s])),
                pl.BlockSpec(
                    (block_b, block),
                    lambda i, s, ins, w, outs, fr, la, q: (i, outs[s])),
                pl.BlockSpec(
                    (1, block, block),
                    lambda i, s, ins, w, outs, fr, la, q: (w[s], 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec(
                    (block_b, block),
                    lambda i, s, ins, w, outs, fr, la, q: (i, outs[s])),
                pl.BlockSpec(
                    (1, block, block),
                    lambda i, s, ins, w, outs, fr, la, q: (q[s], 0, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((block_b, block), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, n_in_tiles * block), dy.dtype),
            jax.ShapeDtypeStruct((n_param_blocks + 1, block, block),
                                 dy.dtype),
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary"),
            (block_b, block), (block_b, block), (block_b, block),
            (block, block), (block_b, block), (block, block),
            (block_b, block)),
        interpret=interpret,
    )(s_in_t, s_w_t, s_out_t, s_first_t, s_last_t, s_q_t, dy, gp, x, wb_t)
    return dx, dwb[:n_param_blocks]


def _dw_kernel(ot_ref, it_ref, dy_ref, g_ref, x_ref, dw_ref, acc_ref):
    i = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    du = dy_ref[...] * g_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        du, x_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == nb - 1)
    def _flush():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)[None]


def fused_layer_dw(dy: jax.Array, gp: jax.Array, x: jax.Array,
                   wb_out_tile, wb_in_tile, *, n_param_blocks: int,
                   block: int, block_b: int,
                   interpret: bool = False) -> jax.Array:
    """(dy·g')^T · x per parameter tile → dWB (n_param, blk, blk)."""
    b = x.shape[0]
    grid = (n_param_blocks, b // block_b)
    return pl.pallas_call(
        _dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block),
                             lambda q, i, ot, it: (i, ot[q])),
                pl.BlockSpec((block_b, block),
                             lambda q, i, ot, it: (i, ot[q])),
                pl.BlockSpec((block_b, block),
                             lambda q, i, ot, it: (i, it[q])),
            ],
            out_specs=pl.BlockSpec((1, block, block),
                                   lambda q, i, ot, it: (q, 0, 0)),
            scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_param_blocks, block, block),
                                       dy.dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary"),
            (block_b, block), (block_b, block), (block_b, block),
            (block, block), (block, block)),
        interpret=interpret,
    )(wb_out_tile, wb_in_tile, dy, gp, x)
