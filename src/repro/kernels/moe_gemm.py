"""Grouped GEMM — the M3 segment trick applied along the *row* axis.

MoE expert computation: tokens sorted by expert id form contiguous row
segments; each segment multiplies its own expert weight.  Identical structure
to m3_matmul with the roles of rows/columns swapped: the scalar-prefetched
per-tile expert id selects the *weight* block instead of the output block.

    y[t] = x[t] @ w[expert(t)]        x (T, D), w (E, D, F) -> y (T, F)

Grid (t_tiles, f_tiles, d_tiles); accumulation over d in f32 VMEM scratch.
The wrapper (ops.moe_gemm) requires every expert's token run padded to a
multiple of ``block_t`` — the MoE layer guarantees this by capacity padding,
exactly how the population layout guarantees 128-aligned member slices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(eid_ref, x_ref, w_ref, y_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (block_t, block_d) @ (block_d, block_f)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...][0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def moe_gemm(x: jax.Array, w: jax.Array, block_expert_ids: jax.Array, *,
             block_t: int, block_d: int, block_f: int,
             interpret: bool = False) -> jax.Array:
    t, d = x.shape
    e, _, f = w.shape
    grid = (t // block_t, f // block_f, d // block_d)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_t, block_d), lambda i, j, k, eid: (i, k)),
                pl.BlockSpec((1, block_d, block_f),
                             lambda i, j, k, eid: (eid[i], k, j)),
            ],
            out_specs=pl.BlockSpec((block_t, block_f),
                                   lambda i, j, k, eid: (i, j)),
            scratch_shapes=[pltpu.VMEM((block_t, block_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        interpret=interpret,
    )(block_expert_ids, x, w)
