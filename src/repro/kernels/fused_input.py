"""Fused input-layer kernel: dense input GEMM + per-member bias +
per-segment activation in ONE Pallas pass (DESIGN.md §9).

The mid layers got their §7 epilogue in kernels/fused_layer.py, but the
INPUT projection — the one dense (non-block-diagonal) GEMM of the stack,
shared x (B, F) against the stacked first-layer weight (H, F) — still ran
as an XLA dot followed by a standalone seg_act pass: z0 round-trips
through HBM twice.  This kernel folds the same epilogue into the input
GEMM:

  forward   y  = act(x·W_in^T + b_in) · mask   (one kernel, z0 never in HBM)
            g' = act'(x·W_in^T + b_in) · mask  (emitted instead of z0 when a
                                               VJP will consume it)
  backward  du = dy ⊙ g' formed in-register in ONE kernel that emits both
            dx (du·W_in, accumulated across hidden tiles in an f32 scratch)
            and dW_in (du^T·x, accumulated across batch tiles in an f32
            scratch holding every hidden tile's slice).  db = Σ_b dy·g' is
            one XLA fused reduce over arrays that exist anyway.

Grid layout: the hidden axis is tiled at the population block size (the
per-block activation id is scalar-prefetched, dispatched via lax.switch on
the flush step, exactly like the mid layers); the feature axis F is tiled
at ``block_f`` (the whole padded F when F ≤ 128, else 128 lanes) as the
reduction dimension.

Mixed precision: operand tiles may be bf16; accumulators and the bias add
are always f32, outputs are cast back to the operand dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.block_diag import tpu_compiler_params
from repro.kernels.fused_layer import _VAL_BRANCHES, _VAL_DERIV_BRANCHES


def pick_block_f(f_pad: int) -> int:
    """Feature-axis tile: whole (padded) F when it fits a lane register,
    else 128-lane tiles."""
    return f_pad if f_pad <= 128 else 128


# --------------------------------------------------------------------- #
# forward: dense GEMM + bias + activation epilogue                      #
# --------------------------------------------------------------------- #

def _make_fwd_kernel(with_deriv: bool):
    def kernel(act_ref, x_ref, w_ref, b_ref, m_ref, *out_and_scratch):
        if with_deriv:
            y_ref, g_ref, acc_ref = out_and_scratch
        else:
            y_ref, acc_ref = out_and_scratch
        t = pl.program_id(1)
        kf = pl.program_id(2)
        nf = pl.num_programs(2)

        @pl.when(kf == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(kf == nf - 1)
        def _epilogue():
            u = acc_ref[...] + b_ref[...].astype(jnp.float32)
            m = m_ref[...].astype(jnp.float32)
            if with_deriv:
                y, g = jax.lax.switch(act_ref[t], _VAL_DERIV_BRANCHES, u)
                y_ref[...] = (y * m).astype(y_ref.dtype)
                g_ref[...] = (g * m).astype(g_ref.dtype)
            else:
                y = jax.lax.switch(act_ref[t], _VAL_BRANCHES, u)
                y_ref[...] = (y * m).astype(y_ref.dtype)
    return kernel


def fused_input_fwd(x: jax.Array, w: jax.Array, bias: jax.Array,
                    mask: jax.Array, act_ids: jax.Array, *, block: int,
                    block_b: int, with_deriv: bool,
                    interpret: bool = False):
    """x (B, F_pad), w (H, F_pad), bias/mask (1, H), per-block act ids
    (H/block,) → y (B, H) [, g' (B, H) when ``with_deriv``]."""
    b, f_pad = x.shape
    h = w.shape[0]
    block_f = pick_block_f(f_pad)
    grid = (b // block_b, h // block, f_pad // block_f)
    out_shape = [jax.ShapeDtypeStruct((b, h), x.dtype)]
    out_specs = [pl.BlockSpec((block_b, block),
                              lambda i, t, kf, act: (i, t))]
    if with_deriv:
        out_shape.append(jax.ShapeDtypeStruct((b, h), x.dtype))
        out_specs.append(pl.BlockSpec((block_b, block),
                                      lambda i, t, kf, act: (i, t)))
    y = pl.pallas_call(
        _make_fwd_kernel(with_deriv),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block_f),
                             lambda i, t, kf, act: (i, kf)),
                pl.BlockSpec((block, block_f),
                             lambda i, t, kf, act: (t, kf)),
                pl.BlockSpec((1, block), lambda i, t, kf, act: (0, t)),
                pl.BlockSpec((1, block), lambda i, t, kf, act: (0, t)),
            ],
            out_specs=out_specs if with_deriv else out_specs[0],
            scratch_shapes=[pltpu.VMEM((block_b, block), jnp.float32)],
        ),
        out_shape=out_shape if with_deriv else out_shape[0],
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary", "arbitrary"),
            (block_b, block_f), (block, block_f), (1, block), (1, block),
            (block_b, block), (block_b, block), (block_b, block)),
        interpret=interpret,
    )(act_ids, x, w, bias, mask)
    return y


# --------------------------------------------------------------------- #
# forward, int8 weights: in-loop dequant + dense GEMM + epilogue        #
# --------------------------------------------------------------------- #

def _int8_fwd_kernel(act_ref, sc_ref, x_ref, w_ref, b_ref, m_ref, y_ref,
                     acc_ref):
    """Int8-weight twin of ``_make_fwd_kernel(False)`` (DESIGN.md §12):
    one f32 scale per hidden row block (each owned by one member), shared
    across the feature reduction tiles — the scales ride the scalar
    prefetch stream (indexed ``sc_ref[t]``, no per-step blocked operand),
    and the int8 weight tile is dequantized on the VPU right before the
    contraction.  Same grid, same epilogue, forward-only by
    construction."""
    t = pl.program_id(1)
    kf = pl.program_id(2)
    nf = pl.num_programs(2)

    @pl.when(kf == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.float32) * sc_ref[t]
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kf == nf - 1)
    def _epilogue():
        u = acc_ref[...] + b_ref[...].astype(jnp.float32)
        m = m_ref[...].astype(jnp.float32)
        y = jax.lax.switch(act_ref[t], _VAL_BRANCHES, u)
        y_ref[...] = (y * m).astype(y_ref.dtype)


def fused_input_int8_fwd(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                         bias: jax.Array, mask: jax.Array,
                         act_ids: jax.Array, *, block: int, block_b: int,
                         interpret: bool = False):
    """x (B, F_pad), w_q (H, F_pad) int8, w_scale (H/block,) f32
    scalar-prefetch, bias/mask (1, H), per-block act ids (H/block,) →
    y (B, H)."""
    b, f_pad = x.shape
    h = w_q.shape[0]
    block_f = pick_block_f(f_pad)
    grid = (b // block_b, h // block, f_pad // block_f)
    return pl.pallas_call(
        _int8_fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block_f),
                             lambda i, t, kf, act, sc: (i, kf)),
                pl.BlockSpec((block, block_f),
                             lambda i, t, kf, act, sc: (t, kf)),
                pl.BlockSpec((1, block), lambda i, t, kf, act, sc: (0, t)),
                pl.BlockSpec((1, block), lambda i, t, kf, act, sc: (0, t)),
            ],
            out_specs=pl.BlockSpec((block_b, block),
                                   lambda i, t, kf, act, sc: (i, t)),
            scratch_shapes=[pltpu.VMEM((block_b, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h), x.dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary", "arbitrary"),
            (block_b, block_f), (block, block_f), (1, block),
            (1, block), (block_b, block), (block_b, block)),
        interpret=interpret,
    )(act_ids, w_scale, x, w_q, bias, mask)


# --------------------------------------------------------------------- #
# backward: dx and dw in one pass, du = dy·g' in-register               #
# --------------------------------------------------------------------- #

def _bwd_kernel(dy_ref, g_ref, x_ref, w_ref, dx_ref, dw_ref,
                dx_acc_ref, dw_acc_ref):
    """Grid (kf, i, t): feature tile OUTER (each emits an independent dx /
    dw column stripe), batch tile middle, hidden tile INNER.  dx
    accumulates over the inner hidden tiles; dw accumulates over the
    middle batch tiles in a per-hidden-tile slice of a (H, block_f)
    scratch — the dw output block (t, kf) is revisited across i, and the
    final (complete) store at i = nb−1 is sequentially the last writer."""
    i = pl.program_id(1)
    nb = pl.num_programs(1)
    t = pl.program_id(2)
    nt = pl.num_programs(2)
    blk = dy_ref.shape[1]

    du = dy_ref[...] * g_ref[...]          # dz0 never exists outside
                                           # this register
    @pl.when(t == 0)
    def _init_dx():
        dx_acc_ref[...] = jnp.zeros_like(dx_acc_ref)

    dx_acc_ref[...] += jax.lax.dot_general(
        du, w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(t == nt - 1)
    def _flush_dx():
        dx_ref[...] = dx_acc_ref[...].astype(dx_ref.dtype)

    rows = pl.ds(t * blk, blk)
    prev = dw_acc_ref[rows, :]
    prev = jnp.where(i == 0, jnp.zeros_like(prev), prev)
    acc = prev + jax.lax.dot_general(
        du, x_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dw_acc_ref[rows, :] = acc
    dw_ref[...] = acc.astype(dw_ref.dtype)


def fused_input_bwd(dy: jax.Array, gp: jax.Array, x: jax.Array,
                    w: jax.Array, *, block: int, block_b: int,
                    interpret: bool = False):
    """dy, g' (B, H), x (B, F_pad), w (H, F_pad) → (dx (B, F_pad),
    dW (H, F_pad)) in ONE launch."""
    b, h = dy.shape
    f_pad = x.shape[1]
    block_f = pick_block_f(f_pad)
    grid = (f_pad // block_f, b // block_b, h // block)
    dx, dw = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block), lambda kf, i, t: (i, t)),
            pl.BlockSpec((block_b, block), lambda kf, i, t: (i, t)),
            pl.BlockSpec((block_b, block_f), lambda kf, i, t: (i, kf)),
            pl.BlockSpec((block, block_f), lambda kf, i, t: (t, kf)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_f), lambda kf, i, t: (i, kf)),
            pl.BlockSpec((block, block_f), lambda kf, i, t: (t, kf)),
        ],
        scratch_shapes=[pltpu.VMEM((block_b, block_f), jnp.float32),
                        pltpu.VMEM((h, block_f), jnp.float32)],
        out_shape=[
            jax.ShapeDtypeStruct((b, f_pad), dy.dtype),
            jax.ShapeDtypeStruct((h, f_pad), dy.dtype),
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary", "arbitrary"),
            (block_b, block), (block_b, block), (block_b, block_f),
            (block, block_f), (block_b, block_f), (block, block_f),
            (block_b, block_f), (h, block_f)),
        interpret=interpret,
    )(dy, gp, x, w)
    return dx, dw
