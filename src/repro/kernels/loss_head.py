"""Fused loss-head kernel: output projection (M3) + softmax cross-entropy
+ dlogits in ONE Pallas pass (DESIGN.md §9).

The pre-§9 loss head ran the M3 segment-blocked matmul, materialised the
(B, P, O) logits in HBM, and let XLA run log_softmax + NLL over them — and
the backward re-materialised dlogits before the M3 transposed kernels.
Here the softmax cross-entropy runs in the epilogue of the projection
while each member's logits tile is still in VMEM:

  forward   per[m] = mean_b( lse(z_m) − z_m[target] )   accumulated in a
            (1, P) f32 scratch across the grid, ONE launch for projection
            AND loss.  The backward's seed, dlogits_base =
            (softmax(z) − onehot(target)) / B, is emitted in the same
            epilogue (instead of the logits) — the only (B, P, O) array
            that ever touches HBM, and the logits never do.
  backward  ONE kernel reads dlogits_base, scales by the incoming
            per-member cotangent d_per[m] (a (1, P) block, scalar per
            member tile), and emits both dh (dl·W_out, direct per-tile
            writes) and dW_out (dl^T·h, accumulated across batch tiles).
            db_out = d_per ⊙ Σ_b dlogits_base is one XLA fused reduce over
            the array that exists anyway.

Grid/tile metadata is the per-block member id (``block_segment_ids``)
scalar-prefetched exactly like kernels/m3_matmul.py: member boundaries
(first/last) are derived from neighbouring ids, so ragged member widths
need no extra metadata.  Padded batch rows carry target −1 and contribute
zero loss and zero dlogits; the output-class axis is padded via −1e30 bias
columns, so softmax assigns them zero mass and their dW rows vanish.

Mixed precision: h/W_out tiles may be bf16; the logits accumulator, the
softmax/lse math, per-member losses, and dlogits_base are always f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.block_diag import tpu_compiler_params


# --------------------------------------------------------------------- #
# forward: projection + softmax-XE epilogue                             #
# --------------------------------------------------------------------- #

def _make_fwd_kernel(inv_b: float, with_dl: bool):
    def kernel(seg_ref, h_ref, w_ref, b_ref, t_ref, *out_and_scratch):
        if with_dl:
            per_ref, dl_ref, acc_ref, per_acc = out_and_scratch
        else:
            per_ref, acc_ref, per_acc = out_and_scratch
        i = pl.program_id(0)
        ni = pl.num_programs(0)
        t = pl.program_id(1)
        nt = pl.num_programs(1)
        seg_t = seg_ref[t]
        first = jnp.logical_or(t == 0, seg_ref[jnp.maximum(t - 1, 0)] != seg_t)
        last = jnp.logical_or(t == nt - 1,
                              seg_ref[jnp.minimum(t + 1, nt - 1)] != seg_t)

        @pl.when(jnp.logical_and(i == 0, t == 0))
        def _zero_per():
            per_acc[...] = jnp.zeros_like(per_acc)

        @pl.when(first)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jax.lax.dot_general(
            h_ref[...], w_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(last)
        def _epilogue():
            logits = acc_ref[...] + b_ref[...].astype(jnp.float32)
            mx = jnp.max(logits, axis=1, keepdims=True)
            ex = jnp.exp(logits - mx)
            den = jnp.sum(ex, axis=1, keepdims=True)
            lse = jnp.log(den) + mx                    # (bb, 1)
            tgt = t_ref[...]                           # (bb, 1) int32
            valid = (tgt >= 0).astype(jnp.float32)     # −1 marks batch pad
            cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
            onehot = (cols == tgt).astype(jnp.float32)
            nll = (lse[:, 0] - jnp.sum(logits * onehot, axis=1)) * valid[:, 0]
            p_ = per_acc.shape[1]
            mrow = (jax.lax.broadcasted_iota(jnp.int32, (1, p_), 1)
                    == seg_t).astype(jnp.float32)
            per_acc[...] += mrow * (jnp.sum(nll) * inv_b)
            if with_dl:
                dl_ref[...] = ((ex / den - onehot)
                               * (valid * inv_b))[:, None, :]

        @pl.when(jnp.logical_and(i == ni - 1, t == nt - 1))
        def _flush_per():
            per_ref[...] = per_acc[...]
    return kernel


def loss_head_fwd(h: jax.Array, w2: jax.Array, b2: jax.Array,
                  targets: jax.Array, seg: jax.Array, num_members: int, *,
                  b_real: int, block_h: int, block_b: int, with_dl: bool,
                  interpret: bool = False):
    """h (B, H), w2 (O, H), b2 (P, O), targets (B, 1) int32 (−1 = pad row)
    → per-member mean NLL (1, P) f32 [, dlogits_base (B, P, O) f32]."""
    b, hh = h.shape
    o = w2.shape[0]
    p = num_members
    grid = (b // block_b, hh // block_h)
    out_shape = [jax.ShapeDtypeStruct((1, p), jnp.float32)]
    out_specs = [pl.BlockSpec((1, p), lambda i, t, seg_r: (0, 0))]
    if with_dl:
        out_shape.append(jax.ShapeDtypeStruct((b, p, o), jnp.float32))
        out_specs.append(pl.BlockSpec((block_b, 1, o),
                                      lambda i, t, seg_r: (i, seg_r[t], 0)))
    res = pl.pallas_call(
        _make_fwd_kernel(1.0 / b_real, with_dl),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block_h),
                             lambda i, t, seg_r: (i, t)),
                pl.BlockSpec((o, block_h), lambda i, t, seg_r: (0, t)),
                pl.BlockSpec((1, o), lambda i, t, seg_r: (seg_r[t], 0)),
                pl.BlockSpec((block_b, 1), lambda i, t, seg_r: (i, 0)),
            ],
            out_specs=out_specs if with_dl else out_specs[0],
            scratch_shapes=[pltpu.VMEM((block_b, o), jnp.float32),
                            pltpu.VMEM((1, p), jnp.float32)],
        ),
        out_shape=out_shape if with_dl else out_shape[0],
        compiler_params=tpu_compiler_params(
            ("arbitrary", "arbitrary"),
            (block_b, block_h), (o, block_h), (1, o), (block_b, 1),
            (1, p), (block_b, o), (block_b, o), (1, p)),
        interpret=interpret,
    )(seg, h, w2, b2, targets)
    return res


# --------------------------------------------------------------------- #
# backward: dh and dW_out in one pass from dlogits_base                 #
# --------------------------------------------------------------------- #

def _bwd_kernel(seg_ref, dper_ref, dl_ref, h_ref, w_ref, dh_ref, dw_ref,
                acc_ref):
    """Grid (t, i): hidden tile OUTER, batch tile INNER.  dh is a direct
    per-(i, t) write; dW_out accumulates over the inner batch tiles in an
    (O, block_h) f32 scratch and flushes on the last one."""
    i = pl.program_id(1)
    nb = pl.num_programs(1)

    dl = dl_ref[...][:, 0, :] * dper_ref[0, 0]     # (bb, O) · d_per[member]
    dh_ref[...] = jax.lax.dot_general(
        dl.astype(w_ref.dtype), w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(dh_ref.dtype)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        dl.astype(h_ref.dtype), h_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == nb - 1)
    def _flush():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


def loss_head_bwd(dper: jax.Array, dl: jax.Array, h: jax.Array,
                  w2: jax.Array, seg: jax.Array, *, block_h: int,
                  block_b: int, interpret: bool = False):
    """dper (1, P) f32, dl (B, P, O) f32 → (dh (B, H), dW_out (O, H)) in
    ONE launch."""
    b, hh = h.shape
    o = w2.shape[0]
    grid = (hh // block_h, b // block_b)
    dh, dw = pl.pallas_call(
        _bwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1), lambda t, i, seg_r: (0, seg_r[t])),
                pl.BlockSpec((block_b, 1, o),
                             lambda t, i, seg_r: (i, seg_r[t], 0)),
                pl.BlockSpec((block_b, block_h),
                             lambda t, i, seg_r: (i, t)),
                pl.BlockSpec((o, block_h), lambda t, i, seg_r: (0, t)),
            ],
            out_specs=[
                pl.BlockSpec((block_b, block_h),
                             lambda t, i, seg_r: (i, t)),
                pl.BlockSpec((o, block_h), lambda t, i, seg_r: (0, t)),
            ],
            scratch_shapes=[pltpu.VMEM((o, block_h), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, hh), h.dtype),
            jax.ShapeDtypeStruct((o, hh), w2.dtype),
        ],
        compiler_params=tpu_compiler_params(
            ("arbitrary", "arbitrary"),
            (1, 1), (block_b, o), (block_b, block_h), (o, block_h),
            (block_b, block_h), (o, block_h), (o, block_h)),
        interpret=interpret,
    )(seg, dper, dl, h, w2)
    return dh, dw
