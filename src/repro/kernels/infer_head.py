"""Forward-only inference head: output projection (M3) + per-member bias
(+ optional log-softmax) in ONE Pallas pass (DESIGN.md §10).

Derived from the loss-head kernel (kernels/loss_head.py) by keeping its
projection loop and REPLACING the epilogue: no targets, no NLL, no
dlogits_base — the epilogue just adds the member bias to the still-in-VMEM
f32 accumulator and stores the finished (block_b, O) logits tile straight
into its member's slot of the (B, P, O) output.  With ``log_probs=True``
the same stable logsumexp the loss head runs produces normalised
log-probabilities instead — serving's soft-vote ensembles consume
``exp(log_probs)`` without any extra XLA softmax pass over the (B, P, O)
tensor.

What the epilogue DROPS vs training (and why the batch tile can grow):
the loss head keeps a second (block_b, O) array live for dlogits_base and
the per-member (1, P) loss scratch; the mid/input training kernels keep a
whole (block_b, H_out) g' residual block.  Here the only live buffers are
the h/w tiles and ONE f32 accumulator, so ``block_b`` defaults to 2× the
training tile (kernels/ops.py routes 256 vs 128) and the grid has half
the batch rows.

Grid/tile metadata is the per-block member id (``block_segment_ids``)
scalar-prefetched exactly like the loss head: member boundaries
(first/last) come from neighbouring ids, so ragged member widths need no
extra metadata.  O pads via −1e30 bias columns (zero softmax mass under
``log_probs``; the caller slices them off regardless).

Mixed precision: h/w tiles may be bf16; the accumulator and the emitted
logits / log-probs are always f32.

There is NO backward: this kernel exists so that no VJP (and no residual)
can even trace into a serving program — training paths keep using the
loss head / m3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.block_diag import tpu_compiler_params


def _make_kernel(log_probs: bool):
    def kernel(seg_ref, h_ref, w_ref, b_ref, y_ref, acc_ref):
        t = pl.program_id(1)
        nt = pl.num_programs(1)
        seg_t = seg_ref[t]
        first = jnp.logical_or(t == 0, seg_ref[jnp.maximum(t - 1, 0)] != seg_t)
        last = jnp.logical_or(t == nt - 1,
                              seg_ref[jnp.minimum(t + 1, nt - 1)] != seg_t)

        @pl.when(first)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jax.lax.dot_general(
            h_ref[...], w_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(last)
        def _epilogue():
            logits = acc_ref[...] + b_ref[...].astype(jnp.float32)
            if log_probs:
                mx = jnp.max(logits, axis=1, keepdims=True)
                lse = jnp.log(jnp.sum(jnp.exp(logits - mx), axis=1,
                                      keepdims=True)) + mx
                logits = logits - lse
            y_ref[...] = logits[:, None, :]
    return kernel


def infer_head_fwd(h: jax.Array, w2: jax.Array, b2: jax.Array,
                   seg: jax.Array, num_members: int, *, block_h: int,
                   block_b: int, log_probs: bool,
                   interpret: bool = False) -> jax.Array:
    """h (B, H), w2 (O, H), b2 (P, O) → logits (or log-probs) (B, P, O) f32.
    Forward-only: one launch, no residual outputs."""
    b, hh = h.shape
    o = w2.shape[0]
    p = num_members
    grid = (b // block_b, hh // block_h)
    return pl.pallas_call(
        _make_kernel(log_probs),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block_h),
                             lambda i, t, seg_r: (i, t)),
                pl.BlockSpec((o, block_h), lambda i, t, seg_r: (0, t)),
                pl.BlockSpec((1, o), lambda i, t, seg_r: (seg_r[t], 0)),
            ],
            out_specs=pl.BlockSpec((block_b, 1, o),
                                   lambda i, t, seg_r: (i, seg_r[t], 0)),
            scratch_shapes=[pltpu.VMEM((block_b, o), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, p, o), jnp.float32),
        compiler_params=tpu_compiler_params(
            ("arbitrary", "arbitrary"),
            (block_b, block_h), (o, block_h), (1, o),
            (block_b, o), (block_b, o)),
        interpret=interpret,
    )(seg, h, w2, b2)


# --------------------------------------------------------------------- #
# int8 weights: in-loop dequant + projection + bias (+ log-softmax)     #
# --------------------------------------------------------------------- #

def _make_int8_kernel(log_probs: bool):
    """Int8-weight twin of ``_make_kernel`` (DESIGN.md §12): the hidden
    tile's f32 scale (one per hidden tile — each owned by exactly one
    member's output rows) rides the scalar-prefetch stream next to ``seg``
    (indexed ``sc_ref[t]``, no per-step blocked operand); the int8 weight
    stripe is dequantized on the VPU before the MXU contraction.  Same
    grid, same member-boundary epilogue."""
    def kernel(seg_ref, sc_ref, h_ref, w_ref, b_ref, y_ref, acc_ref):
        t = pl.program_id(1)
        nt = pl.num_programs(1)
        seg_t = seg_ref[t]
        first = jnp.logical_or(t == 0, seg_ref[jnp.maximum(t - 1, 0)] != seg_t)
        last = jnp.logical_or(t == nt - 1,
                              seg_ref[jnp.minimum(t + 1, nt - 1)] != seg_t)

        @pl.when(first)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        w = w_ref[...].astype(jnp.float32) * sc_ref[t]
        acc_ref[...] += jax.lax.dot_general(
            h_ref[...].astype(jnp.float32), w,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(last)
        def _epilogue():
            logits = acc_ref[...] + b_ref[...].astype(jnp.float32)
            if log_probs:
                mx = jnp.max(logits, axis=1, keepdims=True)
                lse = jnp.log(jnp.sum(jnp.exp(logits - mx), axis=1,
                                      keepdims=True)) + mx
                logits = logits - lse
            y_ref[...] = logits[:, None, :]
    return kernel


def infer_head_int8_fwd(h: jax.Array, w2_q: jax.Array, w2_scale: jax.Array,
                        b2: jax.Array, seg: jax.Array, num_members: int, *,
                        block_h: int, block_b: int, log_probs: bool,
                        interpret: bool = False) -> jax.Array:
    """h (B, H), w2_q (O, H) int8, w2_scale (H/block_h,) f32
    scalar-prefetch, b2 (P, O) → logits (or log-probs) (B, P, O) f32.
    Forward-only, one launch."""
    b, hh = h.shape
    o = w2_q.shape[0]
    p = num_members
    grid = (b // block_b, hh // block_h)
    return pl.pallas_call(
        _make_int8_kernel(log_probs),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block_h),
                             lambda i, t, seg_r, sc: (i, t)),
                pl.BlockSpec((o, block_h), lambda i, t, seg_r, sc: (0, t)),
                pl.BlockSpec((1, o), lambda i, t, seg_r, sc: (seg_r[t], 0)),
            ],
            out_specs=pl.BlockSpec((block_b, 1, o),
                                   lambda i, t, seg_r, sc: (i, seg_r[t], 0)),
            scratch_shapes=[pltpu.VMEM((block_b, o), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, p, o), jnp.float32),
        compiler_params=tpu_compiler_params(
            ("arbitrary", "arbitrary"),
            (block_b, block_h), (o, block_h), (1, o),
            (block_b, o), (block_b, o)),
        interpret=interpret,
    )(seg, w2_scale, h, w2_q, b2)
