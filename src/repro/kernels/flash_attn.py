"""Flash attention (forward) — fused online-softmax attention in VMEM.

WHY (EXPERIMENTS §Perf Cell B diagnosis): the memory term of every
attention-bearing train/prefill cell is dominated by (Sq × block_k) score
tensors round-tripping HBM — XLA materialises each chunk's dot.  This
kernel keeps the whole (scores → mask → online softmax → weighted V)
pipeline in VMEM: HBM sees only Q, K, V once and O once — arithmetic
intensity rises from ~1 to ~d_head FLOP/byte.

TPU mapping:
  grid = (batch·heads, Sq/block_q, Sk/block_k), k-blocks innermost; the
  running (m, l, acc) state lives in VMEM scratch across the k-dimension
  of the grid (the standard Pallas reduction idiom — same trick as the M3
  kernel's output-block accumulation, which is why it lives in this repo).
  GQA without materialised KV repeat: the K/V BlockSpec index_map divides
  the head index by the group size — each q-head group reads its kv head
  straight from HBM.
  Causality + sliding windows are position arithmetic on block offsets;
  scratch rows are (block_q, 128) lane-replicated (TPU VMEM layout).

Backward falls back to the exact chunked-scan XLA path via custom_vjp
(recompute-from-inputs) — flash-bwd is follow-up work; the forward alone
covers serving/prefill and the recompute half of remat'd training.
Validated against kernels/ref.flash_attn_ref + nn/attention.attend_dense in
interpret mode (tests/test_flash_attn.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, seq_k: int):
    i = pl.program_id(1)                  # q block
    j = pl.program_id(2)                  # k block
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                          # (block_q, dh)
    k = k_ref[0]                          # (block_k, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    ok = k_pos < seq_k                    # kv padding
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[:, :1]                                    # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)                          # (bq, 1)
    p = jnp.exp(s - m_new)                                   # (bq, bk)
    l_new = l_ref[:, :1] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, scale: float, causal: bool,
                        window: int, block_q: int = 512, block_k: int = 512,
                        interpret: bool = False):
    """q (B,H,Sq,dh), k/v (B,Hkv,Sk,dh) → o (B,H,Sq,dh).

    H must be a multiple of Hkv (GQA groups map through the index_map —
    KV is never repeated in memory)."""
    b, h, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else v
    grid = (b * h, (sq + pad_q) // block_q, (sk + pad_k) // block_k)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal,
        window=window if window else 0,
        block_q=block_q, block_k=block_k, seq_k=sk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh),
                         lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, i, j, g=g, h=h: (
                             (bh % h) // g + (bh // h) * (h // g), j, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, i, j, g=g, h=h: (
                             (bh % h) // g + (bh // h) * (h // g), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pad_q, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running denom
            pltpu.VMEM((block_q, dh), jnp.float32),      # output acc
        ],
        interpret=interpret,
    )(qp.reshape(b * h, sq + pad_q, dh),
      kp.reshape(b * hkv, sk + pad_k, dh),
      vp.reshape(b * hkv, sk + pad_k, dh))
    return out.reshape(b, h, sq + pad_q, dh)[:, :, :sq]
