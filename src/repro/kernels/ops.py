"""Jit'd public wrappers around the Pallas kernels.

Handle: batch/feature padding to block multiples, dtype policy, the
custom_vjp that routes the M3 backward through the transposed kernels, and
the ``interpret`` switch (True = run the kernel body in Python on CPU; the
container has no TPU — interpret mode is how correctness is validated here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attn as _flashk
from repro.kernels import m3_matmul as _m3k
from repro.kernels import moe_gemm as _moek
from repro.kernels import seg_act as _segk


def _pad_axis(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


# --------------------------------------------------------------------- #
# m3_matmul with custom_vjp                                             #
# --------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _m3_core(h, w2, block_seg_ids_t, num_members, block_h, block_b, interpret):
    seg = jnp.asarray(np.asarray(block_seg_ids_t, np.int32))
    return _m3k.m3_matmul_fwd(h, w2, seg, num_members,
                              block_h=block_h, block_b=block_b,
                              interpret=interpret)


def _m3_fwd(h, w2, block_seg_ids_t, num_members, block_h, block_b, interpret):
    y = _m3_core(h, w2, block_seg_ids_t, num_members, block_h, block_b, interpret)
    return y, (h, w2)


def _m3_bwd(block_seg_ids_t, num_members, block_h, block_b, interpret, res, dy):
    h, w2 = res
    seg = jnp.asarray(np.asarray(block_seg_ids_t, np.int32))
    dh = _m3k.m3_matmul_dh(dy, w2, seg, block_h=block_h, block_b=block_b,
                           interpret=interpret)
    dw = _m3k.m3_matmul_dw(dy, h, seg, block_h=block_h, block_b=block_b,
                           interpret=interpret)
    return dh, dw


_m3_core.defvjp(_m3_fwd, _m3_bwd)


def m3_matmul(h: jax.Array, w2: jax.Array, block_seg_ids: np.ndarray,
              num_members: int, *, block_h: int, block_b: int = 128,
              interpret: bool = True) -> jax.Array:
    """Segment-blocked matmul; differentiable; pads B and O to block multiples.

    h (B, H), w2 (O, H), per-block member ids (H/block_h,) -> (B, M, O).
    H must already be block_h-aligned (Population guarantees this).
    """
    if h.shape[1] % block_h:
        raise ValueError(f"hidden axis {h.shape[1]} not {block_h}-aligned")
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    # O padding: kernels keep full O in-block; pad to 128 lanes for TPU layout
    w2p, o0 = _pad_axis(w2, 0, 128 if not interpret else 1)
    seg_t = tuple(int(s) for s in np.asarray(block_seg_ids, np.int32))
    y = _m3_core(hp, w2p, seg_t, num_members, block_h, block_b, interpret)
    return y[:b0, :, :o0]


# --------------------------------------------------------------------- #
# segmented activation                                                  #
# --------------------------------------------------------------------- #

def seg_act(h: jax.Array, block_act_ids: np.ndarray, mask: np.ndarray, *,
            block_h: int, block_b: int = 256, interpret: bool = True) -> jax.Array:
    """One-pass per-block activation + padding mask. h (B, H) -> (B, H)."""
    if h.shape[1] % block_h:
        raise ValueError(f"hidden axis {h.shape[1]} not {block_h}-aligned")
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    ids = jnp.asarray(np.asarray(block_act_ids, np.int32))
    m2 = jnp.asarray(np.asarray(mask, np.float32)).reshape(1, -1)
    y = _segk.seg_act(hp, ids, m2, block_h=block_h, block_b=block_b,
                      interpret=interpret)
    return y[:b0]


# --------------------------------------------------------------------- #
# flash attention                                                        #
# --------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, scale, causal=True, window=0,
                    block_q=512, block_k=512, interpret=True):
    """Fused flash attention forward. q (B,H,Sq,dh), k/v (B,Hkv,Sk,dh).

    Backward recomputes through the exact dense/chunked XLA path
    (flash-bwd kernel is follow-up work — the forward covers serving,
    prefill, and the recompute half of remat'd training)."""
    return _flashk.flash_attention_fwd(
        q, k, v, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)


def _flash_fwd(q, k, v, scale, causal, window, block_q, block_k, interpret):
    y = flash_attention(q, k, v, scale, causal, window, block_q, block_k,
                        interpret)
    return y, (q, k, v)


def _flash_bwd(scale, causal, window, block_q, block_k, interpret, res, dy):
    from repro.kernels.ref import flash_attn_ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda qq, kk, vv: flash_attn_ref(qq, kk, vv, scale=scale,
                                          causal=causal, window=window),
        q, k, v)
    return vjp(dy)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# --------------------------------------------------------------------- #
# grouped GEMM                                                          #
# --------------------------------------------------------------------- #

def moe_gemm(x: jax.Array, w: jax.Array, block_expert_ids: np.ndarray, *,
             block_t: int = 128, block_d: int = 512, block_f: int = 512,
             interpret: bool = True) -> jax.Array:
    """Tokens-sorted-by-expert grouped GEMM. x (T, D), w (E, D, F) -> (T, F).

    T must be block_t-aligned per expert run (capacity padding upstream).
    D and F are padded here if needed.
    """
    t, d = x.shape
    e, dw, f = w.shape
    if t % block_t:
        raise ValueError(f"token axis {t} not {block_t}-aligned")
    block_d = min(block_d, d)
    block_f = min(block_f, f)
    xp, _ = _pad_axis(x, 1, block_d)
    wp, _ = _pad_axis(w, 1, block_d)
    wp, f0 = _pad_axis(wp, 2, block_f)
    ids = jnp.asarray(np.asarray(block_expert_ids, np.int32))
    y = _moek.moe_gemm(xp, wp, ids, block_t=block_t, block_d=block_d,
                       block_f=block_f, interpret=interpret)
    return y[:, :f0]
