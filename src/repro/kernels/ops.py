"""Jit'd public wrappers around the Pallas kernels.

Handle: batch/feature padding to block multiples, dtype policy, the
custom_vjp that routes the M3 backward through the transposed kernels, and
the ``interpret`` switch (True = run the kernel body in Python on CPU; the
container has no TPU — interpret mode is how correctness is validated here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import block_diag as _bdk
from repro.kernels import flash_attn as _flashk
from repro.kernels import fused_input as _fik
from repro.kernels import fused_layer as _flk
from repro.kernels import infer_head as _ihk
from repro.kernels import loss_head as _lhk
from repro.kernels import m3_matmul as _m3k
from repro.kernels import moe_gemm as _moek
from repro.kernels import seg_act as _segk


def _resolve_interpret(interpret) -> bool:
    """None → auto: compile on TPU, interpret elsewhere (CPU containers run
    the kernel body in Python for correctness validation)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_axis(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


# --------------------------------------------------------------------- #
# m3_matmul with custom_vjp                                             #
# --------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _m3_core(h, w2, block_seg_ids_t, num_members, block_h, block_b, interpret):
    seg = jnp.asarray(np.asarray(block_seg_ids_t, np.int32))
    return _m3k.m3_matmul_fwd(h, w2, seg, num_members,
                              block_h=block_h, block_b=block_b,
                              interpret=interpret)


def _m3_fwd(h, w2, block_seg_ids_t, num_members, block_h, block_b, interpret):
    y = _m3_core(h, w2, block_seg_ids_t, num_members, block_h, block_b, interpret)
    return y, (h, w2)


def _m3_bwd(block_seg_ids_t, num_members, block_h, block_b, interpret, res, dy):
    h, w2 = res
    seg = jnp.asarray(np.asarray(block_seg_ids_t, np.int32))
    dh = _m3k.m3_matmul_dh(dy, w2, seg, block_h=block_h, block_b=block_b,
                           interpret=interpret)
    dw = _m3k.m3_matmul_dw(dy, h, seg, block_h=block_h, block_b=block_b,
                           interpret=interpret)
    return dh, dw


_m3_core.defvjp(_m3_fwd, _m3_bwd)


def m3_matmul(h: jax.Array, w2: jax.Array, block_seg_ids: np.ndarray,
              num_members: int, *, block_h: int, block_b: int = 128,
              interpret: bool | None = None) -> jax.Array:
    """Segment-blocked matmul; differentiable; pads B and O to block multiples.

    h (B, H), w2 (O, H), per-block member ids (H/block_h,) -> (B, M, O).
    H must already be block_h-aligned (Population guarantees this).
    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.
    """
    interpret = _resolve_interpret(interpret)
    if h.shape[1] % block_h:
        raise ValueError(f"hidden axis {h.shape[1]} not {block_h}-aligned")
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    # O padding: kernels keep full O in-block; pad to 128 lanes for TPU layout
    w2p, o0 = _pad_axis(w2, 0, 128 if not interpret else 1)
    seg_t = tuple(int(s) for s in np.asarray(block_seg_ids, np.int32))
    y = _m3_core(hp, w2p, seg_t, num_members, block_h, block_b, interpret)
    return y[:b0, :, :o0]


# --------------------------------------------------------------------- #
# block-diagonal GEMM with custom_vjp (layered-population mid layers)   #
# --------------------------------------------------------------------- #

def _bd_ids(layout, transposed: bool):
    import numpy as _np
    if transposed:
        fields = (layout.s_in_t, layout.s_w_t, layout.s_out_t,
                  layout.s_first_t, layout.s_last_t)
    else:
        fields = (layout.s_in, layout.s_w, layout.s_out,
                  layout.s_first, layout.s_last)
    return tuple(jnp.asarray(_np.asarray(f, _np.int32)) for f in fields)


def _bd_augment(wb: jax.Array, layout) -> jax.Array:
    """Append the shared identity tile used by pass-through members (not a
    parameter — its cotangent is discarded by the VJP)."""
    eye = jnp.eye(layout.block, dtype=wb.dtype)[None]
    return jnp.concatenate([wb, eye], axis=0)


def _bd_transposed_tiles(wb, layout):
    """Per-member-transposed augmented tile array (static permutation +
    per-tile transpose) — the dh weight of both custom VJPs."""
    import numpy as _np
    return jnp.transpose(
        _bd_augment(wb, layout)[_np.asarray(layout.perm_t, _np.int32)],
        (0, 2, 1))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _bd_core(h, wb, layout, block_b, interpret):
    ids = _bd_ids(layout, transposed=False)
    return _bdk.block_diag_fwd(
        h, _bd_augment(wb, layout), *ids,
        n_out_tiles=layout.n_out_tiles, n_steps=layout.n_steps,
        block=layout.block, block_b=block_b, interpret=interpret)


def _bd_fwd(h, wb, layout, block_b, interpret):
    return _bd_core(h, wb, layout, block_b, interpret), (h, wb)


def _bd_bwd(layout, block_b, interpret, res, dy):
    import numpy as _np
    h, wb = res
    # dh: the transposed block-diagonal — same kernel, transposed tiles and
    # swapped (ragged-step) metadata.
    ids_t = _bd_ids(layout, transposed=True)
    dh = _bdk.block_diag_fwd(
        dy, _bd_transposed_tiles(wb, layout), *ids_t,
        n_out_tiles=layout.n_in_tiles, n_steps=layout.n_steps_t,
        block=layout.block, block_b=block_b, interpret=interpret)
    dwb = _bdk.block_diag_dw(
        dy, h,
        jnp.asarray(_np.asarray(layout.wb_out_tile, _np.int32)),
        jnp.asarray(_np.asarray(layout.wb_in_tile, _np.int32)),
        n_param_blocks=layout.n_param_blocks, block=layout.block,
        block_b=block_b, interpret=interpret)
    return dh, dwb


_bd_core.defvjp(_bd_fwd, _bd_bwd)


def block_diag_gemm(h: jax.Array, wb: jax.Array, layout, *,
                    block_b: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """Block-diagonal member projection; differentiable; pads B.

    h (B, n_in_tiles·blk), wb (n_param_blocks, blk, blk) tile array,
    ``layout`` a static ``repro.core.population.BlockDiagLayout`` →
    (B, n_out_tiles·blk).  Pass-through members are identity-copied via the
    shared appended identity tile and contribute no weight gradient.
    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.
    """
    interpret = _resolve_interpret(interpret)
    if h.shape[1] != layout.n_in_tiles * layout.block:
        raise ValueError(f"input axis {h.shape[1]} != "
                         f"{layout.n_in_tiles}×{layout.block}")
    if wb.shape != (layout.n_param_blocks, layout.block, layout.block):
        raise ValueError(f"weight tiles {wb.shape} != "
                         f"({layout.n_param_blocks}, {layout.block}, {layout.block})")
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    y = _bd_core(hp, wb, layout, block_b, interpret)
    return y[:b0]


# --------------------------------------------------------------------- #
# fused layer: block-diag GEMM + bias + activation epilogue             #
# --------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _fused_core(h, wb, b_eff, layout, acts_s, mask_s, block_b, interpret):
    """Primal (no-grad contexts, e.g. eval): single-output kernel — the
    activation derivative is only computed when a VJP will consume it."""
    ids = _bd_ids(layout, transposed=False)
    return _flk.fused_layer_fwd(
        h, _bd_augment(wb, layout), jnp.reshape(b_eff, (1, -1)),
        jnp.asarray(mask_s.arr).reshape(1, -1), *ids,
        jnp.asarray(acts_s.arr),
        n_out_tiles=layout.n_out_tiles, n_steps=layout.n_steps,
        block=layout.block, block_b=block_b, with_deriv=False,
        interpret=interpret)


def _fused_fwd(h, wb, b_eff, layout, acts_s, mask_s, block_b, interpret):
    ids = _bd_ids(layout, transposed=False)
    y, gp = _flk.fused_layer_fwd(
        h, _bd_augment(wb, layout), jnp.reshape(b_eff, (1, -1)),
        jnp.asarray(mask_s.arr).reshape(1, -1), *ids,
        jnp.asarray(acts_s.arr),
        n_out_tiles=layout.n_out_tiles, n_steps=layout.n_steps,
        block=layout.block, block_b=block_b, with_deriv=True,
        interpret=interpret)
    return y, (h, wb, gp)


def _fused_bwd(layout, acts_s, mask_s, block_b, interpret, res, dy):
    import numpy as _np
    h, wb, gp = res
    ids_t = _bd_ids(layout, transposed=True)
    # ONE backward pass at any batch size (two-level grid: transposed param
    # step outer, batch tile inner) — dw tiles are emitted at the dx steps
    # where their (du, x) pair is already in VMEM
    dh, dwb = _flk.fused_layer_dx_dw(
        dy, gp, h, _bd_transposed_tiles(wb, layout), *ids_t,
        jnp.asarray(_np.asarray(layout.s_q_t, _np.int32)),
        n_in_tiles=layout.n_in_tiles, n_steps_t=layout.n_steps_t,
        n_param_blocks=layout.n_param_blocks, block=layout.block,
        block_b=block_b, interpret=interpret)
    # bias cotangent: one fused XLA reduce over tiles that exist anyway
    db = (dy.astype(jnp.float32) * gp.astype(jnp.float32)).sum(axis=0)
    return dh, dwb, db.astype(jnp.float32)


_fused_core.defvjp(_fused_fwd, _fused_bwd)


def fused_layer(h: jax.Array, wb: jax.Array, b_eff: jax.Array, layout,
                block_act_ids: np.ndarray, mask: np.ndarray, *,
                block_b: int = 128,
                interpret: bool | None = None) -> jax.Array:
    """Block-diagonal projection + bias + per-segment activation + padding
    mask in one Pallas pass (kernels/fused_layer.py; DESIGN.md §7);
    differentiable (fused custom VJP — ``dy·act'(z)`` forms in-register
    inside the transposed-GEMM and dw kernels); pads B.

    h (B, n_in_tiles·blk), wb (n_param_blocks, blk, blk) tile array,
    ``b_eff`` (n_out_tiles·blk,) the pass-through-gated bias, ``layout`` a
    static ``BlockDiagLayout``, ``block_act_ids`` the OUTPUT layer's
    per-block activation ids, ``mask`` its hidden mask →
    (B, n_out_tiles·blk) of ``act(h·W + b)·mask``.
    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.
    """
    interpret = _resolve_interpret(interpret)
    if h.shape[1] != layout.n_in_tiles * layout.block:
        raise ValueError(f"input axis {h.shape[1]} != "
                         f"{layout.n_in_tiles}×{layout.block}")
    if wb.shape != (layout.n_param_blocks, layout.block, layout.block):
        raise ValueError(f"weight tiles {wb.shape} != "
                         f"({layout.n_param_blocks}, {layout.block}, "
                         f"{layout.block})")
    h_out = layout.n_out_tiles * layout.block
    if b_eff.shape != (h_out,):
        raise ValueError(f"bias shape {b_eff.shape} != ({h_out},)")
    import numpy as _np
    s_act = _np.asarray(block_act_ids, _np.int32)[
        _np.asarray(layout.s_out, _np.int32)]
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    y = _fused_core(hp, wb, b_eff, layout, _StaticArray(s_act, np.int32),
                    _StaticArray(mask, np.float32), block_b, interpret)
    return y[:b0]


# Inference batch tile: forward-only launches keep no g' residual block in
# VMEM (the dominant extra buffer of the training kernels), so the batch
# tile defaults to 2× the training tile — half the grid rows per launch.
INFER_BLOCK_B = 256


def fused_layer_infer(h: jax.Array, wb: jax.Array, b_eff: jax.Array, layout,
                      block_act_ids: np.ndarray, mask: np.ndarray, *,
                      block_b: int = INFER_BLOCK_B,
                      interpret: bool | None = None) -> jax.Array:
    """Forward-only ``fused_layer``: same one-pass GEMM + bias + activation,
    but no custom_vjp is attached and the kernel runs ``with_deriv=False``
    unconditionally — a VJP traced through a serving program cannot emit a
    residual here, it fails loudly instead (DESIGN.md §10).  The freed VMEM
    pays for the bigger default batch tile."""
    interpret = _resolve_interpret(interpret)
    if h.shape[1] != layout.n_in_tiles * layout.block:
        raise ValueError(f"input axis {h.shape[1]} != "
                         f"{layout.n_in_tiles}×{layout.block}")
    if wb.shape != (layout.n_param_blocks, layout.block, layout.block):
        raise ValueError(f"weight tiles {wb.shape} != "
                         f"({layout.n_param_blocks}, {layout.block}, "
                         f"{layout.block})")
    h_out = layout.n_out_tiles * layout.block
    if b_eff.shape != (h_out,):
        raise ValueError(f"bias shape {b_eff.shape} != ({h_out},)")
    import numpy as _np
    s_act = _np.asarray(block_act_ids, _np.int32)[
        _np.asarray(layout.s_out, _np.int32)]
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    ids = _bd_ids(layout, transposed=False)
    y = _flk.fused_layer_fwd(
        hp, _bd_augment(wb, layout), jnp.reshape(b_eff, (1, -1)),
        jnp.asarray(_np.asarray(mask, _np.float32)).reshape(1, -1), *ids,
        jnp.asarray(s_act),
        n_out_tiles=layout.n_out_tiles, n_steps=layout.n_steps,
        block=layout.block, block_b=block_b, with_deriv=False,
        interpret=interpret)
    return y[:b0]


def fused_layer_infer_int8(h: jax.Array, wb_q: jax.Array,
                           wb_scale: jax.Array, b_eff: jax.Array, layout,
                           block_act_ids: np.ndarray, mask: np.ndarray, *,
                           block_b: int = INFER_BLOCK_B,
                           interpret: bool | None = None) -> jax.Array:
    """``fused_layer_infer`` over the int8 serve copy (DESIGN.md §12):
    consumes the packer's PRE-PACKED, identity-augmented tile array plus
    per-member-per-tile f32 scales — no per-call pack/augment of weight
    bytes, and the dequant runs inside the kernel's tile loop, so an f32
    weight array never exists in this program."""
    interpret = _resolve_interpret(interpret)
    blk = layout.block
    if h.shape[1] != layout.n_in_tiles * blk:
        raise ValueError(f"input axis {h.shape[1]} != "
                         f"{layout.n_in_tiles}×{blk}")
    if wb_q.dtype != jnp.int8:
        raise ValueError(f"int8 serve path got {wb_q.dtype} weight tiles")
    if wb_q.shape != (layout.n_param_blocks + 1, blk, blk):
        raise ValueError(
            f"weight tiles {wb_q.shape} != ({layout.n_param_blocks + 1}, "
            f"{blk}, {blk}) — the int8 store is pre-augmented (identity "
            "tile appended by quantize_population)")
    if wb_scale.shape != (layout.n_param_blocks + 1,):
        raise ValueError(f"scales {wb_scale.shape} != "
                         f"({layout.n_param_blocks + 1},)")
    h_out = layout.n_out_tiles * blk
    if b_eff.shape != (h_out,):
        raise ValueError(f"bias shape {b_eff.shape} != ({h_out},)")
    import numpy as _np
    s_act = _np.asarray(block_act_ids, _np.int32)[
        _np.asarray(layout.s_out, _np.int32)]
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    ids = _bd_ids(layout, transposed=False)
    y = _flk.fused_layer_int8_fwd(
        hp, wb_q, wb_scale.astype(jnp.float32).reshape(-1),
        jnp.reshape(b_eff, (1, -1)),
        jnp.asarray(_np.asarray(mask, _np.float32)).reshape(1, -1), *ids,
        jnp.asarray(s_act),
        n_out_tiles=layout.n_out_tiles, n_steps=layout.n_steps,
        block=blk, block_b=block_b, interpret=interpret)
    return y[:b0]


# --------------------------------------------------------------------- #
# fused input layer: dense GEMM + bias + activation epilogue            #
# --------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _fin_core(x, w, b, acts_s, mask_s, block, block_b, interpret):
    """Primal (no-grad contexts, e.g. eval): single-output kernel."""
    return _fik.fused_input_fwd(
        x, w, jnp.reshape(b, (1, -1)).astype(jnp.float32),
        jnp.asarray(mask_s.arr).reshape(1, -1), jnp.asarray(acts_s.arr),
        block=block, block_b=block_b, with_deriv=False, interpret=interpret)


def _fin_fwd(x, w, b, acts_s, mask_s, block, block_b, interpret):
    y, gp = _fik.fused_input_fwd(
        x, w, jnp.reshape(b, (1, -1)).astype(jnp.float32),
        jnp.asarray(mask_s.arr).reshape(1, -1), jnp.asarray(acts_s.arr),
        block=block, block_b=block_b, with_deriv=True, interpret=interpret)
    return y, (x, w, gp)


def _fin_bwd(acts_s, mask_s, block, block_b, interpret, res, dy):
    x, w, gp = res
    dx, dw = _fik.fused_input_bwd(dy, gp, x, w, block=block,
                                  block_b=block_b, interpret=interpret)
    # bias cotangent: one fused XLA reduce over tiles that exist anyway
    db = (dy.astype(jnp.float32) * gp.astype(jnp.float32)).sum(axis=0)
    return dx, dw, db.astype(jnp.float32)


_fin_core.defvjp(_fin_fwd, _fin_bwd)


def fused_input(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
                block_act_ids: np.ndarray, mask: np.ndarray, *,
                block: int, block_b: int = 128,
                interpret: bool | None = None) -> jax.Array:
    """Dense input projection + bias + per-segment activation + padding
    mask in one Pallas pass (kernels/fused_input.py; DESIGN.md §9);
    differentiable (fused one-pass custom VJP); pads B and F.

    x (B, F), w_in (H, F) the stacked first-layer weight, ``b_in`` (H,),
    ``block_act_ids`` the first hidden layer's per-block activation ids,
    ``mask`` its hidden mask → (B, H) of ``act(x·W_in^T + b_in)·mask``.
    H must already be block-aligned (Population guarantees this).
    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.
    """
    interpret = _resolve_interpret(interpret)
    h = w_in.shape[0]
    if h % block:
        raise ValueError(f"hidden axis {h} not {block}-aligned")
    if x.shape[1] != w_in.shape[1]:
        raise ValueError(f"feature axis {x.shape[1]} != {w_in.shape[1]}")
    if b_in.shape != (h,):
        raise ValueError(f"bias shape {b_in.shape} != ({h},)")
    block_b = min(block_b, max(8, 1 << (x.shape[0] - 1).bit_length()))
    xp, b0 = _pad_axis(x, 0, block_b)
    # feature padding: whole-F lane register when small, 128-lane reduction
    # tiles when large (pick_block_f)
    fmult = 8 if x.shape[1] <= 128 else 128
    xp, _ = _pad_axis(xp, 1, fmult)
    wp, _ = _pad_axis(w_in, 1, fmult)
    y = _fin_core(xp, wp, b_in, _StaticArray(block_act_ids, np.int32),
                  _StaticArray(mask, np.float32), block, block_b, interpret)
    return y[:b0]


def fused_input_infer(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
                      block_act_ids: np.ndarray, mask: np.ndarray, *,
                      block: int, block_b: int = INFER_BLOCK_B,
                      interpret: bool | None = None) -> jax.Array:
    """Forward-only ``fused_input``: no custom_vjp, ``with_deriv=False``
    unconditionally — no g' residual can be emitted, and the freed VMEM
    pays for the bigger default batch tile (DESIGN.md §10)."""
    interpret = _resolve_interpret(interpret)
    h = w_in.shape[0]
    if h % block:
        raise ValueError(f"hidden axis {h} not {block}-aligned")
    if x.shape[1] != w_in.shape[1]:
        raise ValueError(f"feature axis {x.shape[1]} != {w_in.shape[1]}")
    if b_in.shape != (h,):
        raise ValueError(f"bias shape {b_in.shape} != ({h},)")
    block_b = min(block_b, max(8, 1 << (x.shape[0] - 1).bit_length()))
    xp, b0 = _pad_axis(x, 0, block_b)
    fmult = 8 if x.shape[1] <= 128 else 128
    xp, _ = _pad_axis(xp, 1, fmult)
    wp, _ = _pad_axis(w_in, 1, fmult)
    y = _fik.fused_input_fwd(
        xp, wp, jnp.reshape(b_in, (1, -1)).astype(jnp.float32),
        jnp.asarray(np.asarray(mask, np.float32)).reshape(1, -1),
        jnp.asarray(np.asarray(block_act_ids, np.int32)),
        block=block, block_b=block_b, with_deriv=False, interpret=interpret)
    return y[:b0]


def fused_input_infer_int8(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                           b_in: jax.Array, block_act_ids: np.ndarray,
                           mask: np.ndarray, *, block: int,
                           block_b: int = INFER_BLOCK_B,
                           interpret: bool | None = None) -> jax.Array:
    """``fused_input_infer`` over the int8 serve copy: ``w_q`` is stored
    PRE-PADDED to the kernel's feature tile (quantize_population), with one
    f32 scale per hidden row block dequantized inside the tile loop —
    weight bytes are never padded or upcast per call."""
    interpret = _resolve_interpret(interpret)
    h = w_q.shape[0]
    if h % block:
        raise ValueError(f"hidden axis {h} not {block}-aligned")
    if w_q.dtype != jnp.int8:
        raise ValueError(f"int8 serve path got {w_q.dtype} input weight")
    fmult = 8 if x.shape[1] <= 128 else 128
    f_pad = x.shape[1] + ((-x.shape[1]) % fmult)
    if w_q.shape[1] != f_pad:
        raise ValueError(
            f"int8 input weight has F={w_q.shape[1]}, expected the "
            f"pre-padded {f_pad} (quantize_population stores it padded)")
    if w_scale.shape != (h // block,):
        raise ValueError(f"scales {w_scale.shape} != ({h // block},)")
    if b_in.shape != (h,):
        raise ValueError(f"bias shape {b_in.shape} != ({h},)")
    block_b = min(block_b, max(8, 1 << (x.shape[0] - 1).bit_length()))
    xp, b0 = _pad_axis(x, 0, block_b)
    xp, _ = _pad_axis(xp, 1, fmult)
    y = _fik.fused_input_int8_fwd(
        xp, w_q, w_scale.astype(jnp.float32).reshape(-1),
        jnp.reshape(b_in, (1, -1)).astype(jnp.float32),
        jnp.asarray(np.asarray(mask, np.float32)).reshape(1, -1),
        jnp.asarray(np.asarray(block_act_ids, np.int32)),
        block=block, block_b=block_b, interpret=interpret)
    return y[:b0]


# --------------------------------------------------------------------- #
# segmented activation                                                  #
# --------------------------------------------------------------------- #

class _StaticArray:
    """Hashable wrapper making a numpy constant usable as a jit /
    custom_vjp STATIC argument without materialising a per-element Python
    tuple (the fused hidden mask is 10^5-10^6 floats at paper scale —
    hashing the raw bytes once beats building and caching a tuple)."""
    __slots__ = ("arr", "_hash")

    def __init__(self, arr, dtype):
        self.arr = np.ascontiguousarray(np.asarray(arr, dtype))
        self.arr.setflags(write=False)
        self._hash = hash((self.arr.shape, self.arr.dtype.str,
                           self.arr.tobytes()))

    def __hash__(self):
        return self._hash

    def __eq__(self, other):
        return (isinstance(other, _StaticArray)
                and self.arr.dtype == other.arr.dtype
                and self.arr.shape == other.arr.shape
                and np.array_equal(self.arr, other.arr))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def _seg_core(h, act_ids_s, mask_s, block_h, block_b, interpret):
    ids = jnp.asarray(act_ids_s.arr)
    m2 = jnp.asarray(mask_s.arr).reshape(1, -1)
    return _segk.seg_act(h, ids, m2, block_h=block_h, block_b=block_b,
                         interpret=interpret)


def _seg_fwd(h, act_ids_s, mask_s, block_h, block_b, interpret):
    return _seg_core(h, act_ids_s, mask_s, block_h, block_b, interpret), h


def _seg_bwd(act_ids_s, mask_s, block_h, block_b, interpret, h, dy):
    ids = jnp.asarray(act_ids_s.arr)
    m2 = jnp.asarray(mask_s.arr).reshape(1, -1)
    return (_segk.seg_act_bwd(h, dy, ids, m2, block_h=block_h,
                              block_b=block_b, interpret=interpret),)


_seg_core.defvjp(_seg_fwd, _seg_bwd)


def seg_act(h: jax.Array, block_act_ids: np.ndarray, mask: np.ndarray, *,
            block_h: int, block_b: int = 256,
            interpret: bool | None = None) -> jax.Array:
    """One-pass per-block activation + padding mask. h (B, H) -> (B, H).

    Differentiable (custom VJP through the seg_act_bwd kernel).
    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.
    """
    interpret = _resolve_interpret(interpret)
    if h.shape[1] % block_h:
        raise ValueError(f"hidden axis {h.shape[1]} not {block_h}-aligned")
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    y = _seg_core(hp, _StaticArray(block_act_ids, np.int32),
                  _StaticArray(mask, np.float32), block_h, block_b,
                  interpret)
    return y[:b0]


# --------------------------------------------------------------------- #
# fused loss head: M3 projection + softmax-XE + dlogits                 #
# --------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _lh_core(h, w2, b2, tgt, seg_s, b_real, block_h, block_b, interpret):
    """Primal (no-grad contexts): per-member losses only, dlogits_base is
    only emitted when a VJP will consume it."""
    per = _lhk.loss_head_fwd(
        h, w2, b2, tgt, jnp.asarray(seg_s.arr), b2.shape[0],
        b_real=b_real, block_h=block_h, block_b=block_b, with_dl=False,
        interpret=interpret)
    return per[0]


def _lh_fwd(h, w2, b2, tgt, seg_s, b_real, block_h, block_b, interpret):
    per, dl = _lhk.loss_head_fwd(
        h, w2, b2, tgt, jnp.asarray(seg_s.arr), b2.shape[0],
        b_real=b_real, block_h=block_h, block_b=block_b, with_dl=True,
        interpret=interpret)
    return per[0], (h, w2, dl)


def _lh_bwd(seg_s, b_real, block_h, block_b, interpret, res, dper):
    h, w2, dl = res
    dper = dper.astype(jnp.float32)
    dh, dw = _lhk.loss_head_bwd(
        dper.reshape(1, -1), dl, h, w2, jnp.asarray(seg_s.arr),
        block_h=block_h, block_b=block_b, interpret=interpret)
    # bias cotangent: one fused XLA reduce over the array that exists anyway
    db = dper[:, None] * dl.sum(axis=0)
    # integer targets carry a float0 cotangent
    dt = np.zeros((h.shape[0], 1), jax.dtypes.float0)
    return dh, dw, db, dt


_lh_core.defvjp(_lh_fwd, _lh_bwd)


def loss_head(h: jax.Array, w_out: jax.Array, b_out: jax.Array,
              targets: jax.Array, block_seg_ids: np.ndarray, *,
              block_h: int, block_b: int = 128,
              interpret: bool | None = None) -> jax.Array:
    """Output projection + per-member softmax cross-entropy in one Pallas
    pass (kernels/loss_head.py; DESIGN.md §9); differentiable (fused
    one-pass custom VJP emitting dh and dW_out together); pads B and O.

    h (B, H), w_out (O, H), b_out (P, O), integer targets (B,) →
    per-member mean NLL (P,) f32 — ``per.sum()`` is the scalar training
    loss and matches the XLA log_softmax reference to f32 tolerance.
    H must already be block_h-aligned (Population guarantees this).
    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.
    """
    interpret = _resolve_interpret(interpret)
    if h.shape[1] % block_h:
        raise ValueError(f"hidden axis {h.shape[1]} not {block_h}-aligned")
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    # pad rows carry target −1 → zero loss weight, zero dlogits
    tp = jnp.pad(targets.astype(jnp.int32).reshape(-1, 1),
                 ((0, hp.shape[0] - b0), (0, 0)), constant_values=-1)
    # O padding: −1e30 bias columns get zero softmax mass (and zero dW rows)
    w2p, o0 = _pad_axis(w_out, 0, 128 if not interpret else 1)
    pad_o = w2p.shape[0] - o0
    b2p = b_out.astype(jnp.float32)
    if pad_o:
        b2p = jnp.pad(b2p, ((0, 0), (0, pad_o)), constant_values=-1e30)
    return _lh_core(hp, w2p, b2p, tp,
                    _StaticArray(block_seg_ids, np.int32), b0, block_h,
                    block_b, interpret)


def infer_head(h: jax.Array, w_out: jax.Array, b_out: jax.Array,
               block_seg_ids: np.ndarray, *, block_h: int,
               block_b: int = INFER_BLOCK_B, log_probs: bool = False,
               interpret: bool | None = None) -> jax.Array:
    """Forward-only output head: M3 projection + per-member bias (+ optional
    stable log-softmax) in one Pallas pass (kernels/infer_head.py;
    DESIGN.md §10).  NOT differentiable by design — serving programs must
    not be able to trace a residual-emitting VJP through the head.

    h (B, H), w_out (O, H), b_out (P, O) → per-member logits — or, with
    ``log_probs=True``, log-probabilities — (B, P, O) f32; pads B and O.
    H must already be block_h-aligned (Population guarantees this).
    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.
    """
    interpret = _resolve_interpret(interpret)
    if h.shape[1] % block_h:
        raise ValueError(f"hidden axis {h.shape[1]} not {block_h}-aligned")
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    # O padding: −1e30 bias columns get zero softmax mass under log_probs
    # (and are sliced off regardless)
    w2p, o0 = _pad_axis(w_out, 0, 128 if not interpret else 1)
    pad_o = w2p.shape[0] - o0
    b2p = b_out.astype(jnp.float32)
    if pad_o:
        b2p = jnp.pad(b2p, ((0, 0), (0, pad_o)), constant_values=-1e30)
    seg = jnp.asarray(np.asarray(block_seg_ids, np.int32))
    y = _ihk.infer_head_fwd(hp, w2p, b2p, seg, b2p.shape[0],
                            block_h=block_h, block_b=block_b,
                            log_probs=log_probs, interpret=interpret)
    return y[:b0, :, :o0]


def infer_head_int8(h: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                    b_out: jax.Array, block_seg_ids: np.ndarray, *,
                    block_h: int, block_b: int = INFER_BLOCK_B,
                    log_probs: bool = False,
                    interpret: bool | None = None) -> jax.Array:
    """``infer_head`` over the int8 serve copy: one f32 scale per hidden
    tile dequantized in the projection loop.  O pads with int8 zero rows
    (exact under any scale) and −1e30 bias columns, exactly like the f32
    head."""
    interpret = _resolve_interpret(interpret)
    if h.shape[1] % block_h:
        raise ValueError(f"hidden axis {h.shape[1]} not {block_h}-aligned")
    if w_q.dtype != jnp.int8:
        raise ValueError(f"int8 serve path got {w_q.dtype} head weight")
    if w_scale.shape != (h.shape[1] // block_h,):
        raise ValueError(f"scales {w_scale.shape} != "
                         f"({h.shape[1] // block_h},)")
    block_b = min(block_b, max(8, 1 << (h.shape[0] - 1).bit_length()))
    hp, b0 = _pad_axis(h, 0, block_b)
    w2p, o0 = _pad_axis(w_q, 0, 128 if not interpret else 1)
    pad_o = w2p.shape[0] - o0
    b2p = b_out.astype(jnp.float32)
    if pad_o:
        b2p = jnp.pad(b2p, ((0, 0), (0, pad_o)), constant_values=-1e30)
    seg = jnp.asarray(np.asarray(block_seg_ids, np.int32))
    y = _ihk.infer_head_int8_fwd(
        hp, w2p, w_scale.astype(jnp.float32).reshape(-1), b2p, seg,
        b2p.shape[0], block_h=block_h, block_b=block_b,
        log_probs=log_probs, interpret=interpret)
    return y[:b0, :, :o0]


# --------------------------------------------------------------------- #
# flash attention                                                        #
# --------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, scale, causal=True, window=0,
                    block_q=512, block_k=512, interpret=True):
    """Fused flash attention forward. q (B,H,Sq,dh), k/v (B,Hkv,Sk,dh).

    Backward recomputes through the exact dense/chunked XLA path
    (flash-bwd kernel is follow-up work — the forward covers serving,
    prefill, and the recompute half of remat'd training)."""
    return _flashk.flash_attention_fwd(
        q, k, v, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)


def _flash_fwd(q, k, v, scale, causal, window, block_q, block_k, interpret):
    y = flash_attention(q, k, v, scale, causal, window, block_q, block_k,
                        interpret)
    return y, (q, k, v)


def _flash_bwd(scale, causal, window, block_q, block_k, interpret, res, dy):
    from repro.kernels.ref import flash_attn_ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda qq, kk, vv: flash_attn_ref(qq, kk, vv, scale=scale,
                                          causal=causal, window=window),
        q, k, v)
    return vjp(dy)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# --------------------------------------------------------------------- #
# grouped GEMM                                                          #
# --------------------------------------------------------------------- #

def moe_gemm(x: jax.Array, w: jax.Array, block_expert_ids: np.ndarray, *,
             block_t: int = 128, block_d: int = 512, block_f: int = 512,
             interpret: bool = True) -> jax.Array:
    """Tokens-sorted-by-expert grouped GEMM. x (T, D), w (E, D, F) -> (T, F).

    T must be block_t-aligned per expert run (capacity padding upstream).
    D and F are padded here if needed.
    """
    t, d = x.shape
    e, dw, f = w.shape
    if t % block_t:
        raise ValueError(f"token axis {t} not {block_t}-aligned")
    block_d = min(block_d, d)
    block_f = min(block_f, f)
    xp, _ = _pad_axis(x, 1, block_d)
    wp, _ = _pad_axis(w, 1, block_d)
    wp, f0 = _pad_axis(wp, 2, block_f)
    ids = jnp.asarray(np.asarray(block_expert_ids, np.int32))
    y = _moek.moe_gemm(xp, wp, ids, block_t=block_t, block_d=block_d,
                       block_f=block_f, interpret=interpret)
    return y[:, :f0]
