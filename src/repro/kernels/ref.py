"""Pure-jnp oracles for every Pallas kernel in this package.

These define the *semantics*; the kernels must match them (asserted across a
shape/dtype sweep in tests/test_kernels.py).  They are also the lowering used
on backends without Pallas TPU support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.activations import ACTIVATION_FNS


def expand_block_ids(block_ids: np.ndarray, block: int) -> np.ndarray:
    """Per-block id array -> per-unit id array."""
    return np.repeat(np.asarray(block_ids), block)


def m3_matmul_ref(h: jax.Array, w2: jax.Array, block_seg_ids: np.ndarray,
                  num_members: int, block_h: int) -> jax.Array:
    """y[b,m,o] = sum_{j: seg(j)==m} h[b,j] * w2[o,j]   (f32 accumulation).

    h (B, H), w2 (O, H) -> (B, M, O)."""
    seg = jnp.asarray(expand_block_ids(block_seg_ids, block_h))
    s = h.astype(jnp.float32)[:, None, :] * w2.astype(jnp.float32)[None, :, :]
    y = jax.ops.segment_sum(jnp.moveaxis(s, -1, 0), seg,
                            num_segments=num_members, indices_are_sorted=True)
    return jnp.moveaxis(y, 0, 1).astype(h.dtype)


def m3_matmul_ref_f32out(h, w2, block_seg_ids, num_members, block_h):
    seg = jnp.asarray(expand_block_ids(block_seg_ids, block_h))
    s = h.astype(jnp.float32)[:, None, :] * w2.astype(jnp.float32)[None, :, :]
    y = jax.ops.segment_sum(jnp.moveaxis(s, -1, 0), seg,
                            num_segments=num_members, indices_are_sorted=True)
    return jnp.moveaxis(y, 0, 1)


def seg_act_ref(h: jax.Array, block_act_ids: np.ndarray, block_h: int,
                mask: np.ndarray | None = None) -> jax.Array:
    """Per-block activation id applied column-wise, then optional unit mask."""
    ids = jnp.asarray(expand_block_ids(block_act_ids, block_h))
    out = jnp.zeros_like(h)
    for i, fn in enumerate(ACTIVATION_FNS):
        out = jnp.where(ids == i, fn(h), out)
    if mask is not None:
        out = out * jnp.asarray(mask, h.dtype)
    return out


def flash_attn_ref(q, k, v, *, scale: float, causal: bool, window: int):
    """Dense masked softmax attention. q (B,H,Sq,dh), k/v (B,Hkv,Sk,dh)."""
    b, h, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= qp >= kp
    if window and window > 0:
        ok &= (qp - kp) < window
    s = jnp.where(ok[None, None], s, -1e30)
    w_ = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w_,
                      vr.astype(jnp.float32)).astype(q.dtype)


def moe_gemm_ref(x: jax.Array, w: jax.Array, block_expert_ids: np.ndarray,
                 block_t: int) -> jax.Array:
    """Grouped GEMM: y[t] = x[t] @ w[e(t)].

    x (T, D) tokens sorted by expert (padded so each expert's run is a
    multiple of block_t); w (E, D, F) -> y (T, F)."""
    eid = jnp.asarray(expand_block_ids(block_expert_ids, block_t))
    wt = w[eid]                                   # (T, D, F) gather — oracle only
    return jnp.einsum("td,tdf->tf", x.astype(jnp.float32),
                      wt.astype(jnp.float32)).astype(x.dtype)
