"""Segment-blocked matmul — the TPU-native M3 (DESIGN.md §2).

Forward:   y[b, m, o] = sum_{j in segment m} h[b, j] * w2[o, j]
with every member's hidden slice padded to a multiple of ``block_h`` so each
hidden tile belongs to exactly one member.  The paper's scatter-add becomes
*output-block selection*: grid step (i, t) computes a dense
(block_b × block_h)·(block_h × O) MXU matmul and accumulates it (f32 VMEM
scratch) into output block (i, seg[t]); ``seg`` arrives via scalar prefetch
so the index map is known before the tile is fetched.  Because members are
contiguous, revisits of an output block are consecutive grid steps — the
standard Pallas reduction pattern (no atomics, no (B,O,H) intermediate).

Backward (two more kernels, same trick transposed):
    dh[b, j] = dot(dy[b, seg(j), :], w2[:, j])        — gather-matmul per tile
    dw2[o, j] = sum_b h[b, j] * dy[b, seg(j), o]      — accumulate over b tiles
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------- #
# forward                                                               #
# --------------------------------------------------------------------- #

def _fwd_kernel(seg_ref, h_ref, w_ref, y_ref, acc_ref):
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    seg_t = seg_ref[t]
    first = jnp.logical_or(t == 0, seg_ref[jnp.maximum(t - 1, 0)] != seg_t)
    last = jnp.logical_or(t == nt - 1, seg_ref[jnp.minimum(t + 1, nt - 1)] != seg_t)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (block_b, block_h) @ (block_h, O) on the MXU, f32 accumulate
    acc_ref[...] += jax.lax.dot_general(
        h_ref[...], w_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(last)
    def _flush():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)[:, None, :]


def m3_matmul_fwd(h: jax.Array, w2: jax.Array, block_seg_ids: jax.Array,
                  num_members: int, *, block_h: int, block_b: int,
                  interpret: bool = False) -> jax.Array:
    b, hh = h.shape
    o = w2.shape[0]
    nt = hh // block_h
    nb = b // block_b
    grid = (nb, nt)
    return pl.pallas_call(
        _fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block_h), lambda i, t, seg: (i, t)),
                pl.BlockSpec((o, block_h), lambda i, t, seg: (0, t)),
            ],
            out_specs=pl.BlockSpec((block_b, 1, o),
                                   lambda i, t, seg: (i, seg[t], 0)),
            scratch_shapes=[pltpu.VMEM((block_b, o), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, num_members, o), h.dtype),
        interpret=interpret,
    )(block_seg_ids, h, w2)


# --------------------------------------------------------------------- #
# backward: dh                                                          #
# --------------------------------------------------------------------- #

def _dh_kernel(seg_ref, dy_ref, w_ref, dh_ref):
    # dy block (block_b, 1, O) is the member's output grad; one shot per tile.
    dy = dy_ref[...][:, 0, :]                       # (block_b, O)
    dh_ref[...] = jax.lax.dot_general(
        dy, w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(dh_ref.dtype)


def m3_matmul_dh(dy: jax.Array, w2: jax.Array, block_seg_ids: jax.Array,
                 *, block_h: int, block_b: int,
                 interpret: bool = False) -> jax.Array:
    b, _, o = dy.shape
    hh = w2.shape[1]
    grid = (b // block_b, hh // block_h)
    return pl.pallas_call(
        _dh_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, 1, o), lambda i, t, seg: (i, seg[t], 0)),
                pl.BlockSpec((o, block_h), lambda i, t, seg: (0, t)),
            ],
            out_specs=pl.BlockSpec((block_b, block_h), lambda i, t, seg: (i, t)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, hh), dy.dtype),
        interpret=interpret,
    )(block_seg_ids, dy, w2)


# --------------------------------------------------------------------- #
# backward: dw2                                                         #
# --------------------------------------------------------------------- #

def _dw_kernel(seg_ref, dy_ref, h_ref, dw_ref, acc_ref):
    i = pl.program_id(1)                            # batch tile (inner dim)
    nb = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dy = dy_ref[...][:, 0, :]                       # (block_b, O)
    # (O, block_b) @ (block_b, block_h) -> (O, block_h)
    acc_ref[...] += jax.lax.dot_general(
        dy, h_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == nb - 1)
    def _flush():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


def m3_matmul_dw(dy: jax.Array, h: jax.Array, block_seg_ids: jax.Array,
                 *, block_h: int, block_b: int,
                 interpret: bool = False) -> jax.Array:
    b, _, o = dy.shape
    hh = h.shape[1]
    grid = (hh // block_h, b // block_b)            # batch is the reduction dim
    return pl.pallas_call(
        _dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, 1, o), lambda t, i, seg: (i, seg[t], 0)),
                pl.BlockSpec((block_b, block_h), lambda t, i, seg: (i, t)),
            ],
            out_specs=pl.BlockSpec((o, block_h), lambda t, i, seg: (0, t)),
            scratch_shapes=[pltpu.VMEM((o, block_h), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((o, hh), h.dtype),
        interpret=interpret,
    )(block_seg_ids, dy, h)
