"""Segmented activation kernel: apply a *different* activation function per
hidden block in a single pass over the tensor.

The paper applies per-member activations by split→activate→concat (or by
masking, which reads the tensor 10×).  TPU-native version: the per-block
activation id is scalar-prefetched; each tile is read once from VMEM and
dispatched through ``lax.switch`` over the ten paper activations; the
padding mask is fused into the same pass (zero HBM overhead).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.activations import ACTIVATION_FNS


def _kernel(act_ref, h_ref, mask_ref, out_ref):
    t = pl.program_id(1)
    x = h_ref[...]
    y = jax.lax.switch(act_ref[t], ACTIVATION_FNS, x)
    out_ref[...] = y * mask_ref[...].astype(y.dtype)


def seg_act(h: jax.Array, block_act_ids: jax.Array, mask: jax.Array, *,
            block_h: int, block_b: int, interpret: bool = False) -> jax.Array:
    """h (B, H), block_act_ids (H//block_h,), mask (1, H) -> (B, H)."""
    b, hh = h.shape
    grid = (b // block_b, hh // block_h)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block_h), lambda i, t, act: (i, t)),
                pl.BlockSpec((1, block_h), lambda i, t, act: (0, t)),
            ],
            out_specs=pl.BlockSpec((block_b, block_h), lambda i, t, act: (i, t)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, hh), h.dtype),
        interpret=interpret,
    )(block_act_ids, h, mask)


def _vjp_branch(fn):
    def branch(operands):
        x, g = operands
        return jax.vjp(fn, x)[1](g)[0]
    return branch


_VJP_BRANCHES = tuple(_vjp_branch(fn) for fn in ACTIVATION_FNS)


def _bwd_kernel(act_ref, h_ref, dy_ref, mask_ref, out_ref):
    t = pl.program_id(1)
    x = h_ref[...]
    g = dy_ref[...] * mask_ref[...].astype(dy_ref.dtype)
    out_ref[...] = jax.lax.switch(act_ref[t], _VJP_BRANCHES, (x, g))


def seg_act_bwd(h: jax.Array, dy: jax.Array, block_act_ids: jax.Array,
                mask: jax.Array, *, block_h: int, block_b: int,
                interpret: bool = False) -> jax.Array:
    """dL/dh of ``seg_act``: dy·mask routed through each block's activation
    VJP in the same one-pass tile-wise ``lax.switch`` dispatch as the
    forward (the cotangent of the fused mask-multiply is just another
    elementwise factor, so it fuses into the same tile read)."""
    b, hh = h.shape
    grid = (b // block_b, hh // block_h)
    return pl.pallas_call(
        _bwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block_h), lambda i, t, act: (i, t)),
                pl.BlockSpec((block_b, block_h), lambda i, t, act: (i, t)),
                pl.BlockSpec((1, block_h), lambda i, t, act: (0, t)),
            ],
            out_specs=pl.BlockSpec((block_b, block_h), lambda i, t, act: (i, t)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, hh), h.dtype),
        interpret=interpret,
    )(block_act_ids, h, dy, mask)
