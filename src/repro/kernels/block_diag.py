"""Block-diagonal GEMM — the mid-layer projection of layered populations
(DESIGN.md §3).

Member m's units in layer l+1 contract ONLY member m's units in layer l, so
the fused l→l+1 weight is block-diagonal with one (O_m × I_m) block per
member.  Instead of a Python loop of per-bucket einsums this runs as ONE
dense segment-blocked matmul: the weight is stored as a flat array of
(block × block) tiles (member-major, row-major over each member's tile grid,
plus one shared identity tile for pass-through members).

Members have DIFFERENT fan-ins, so the reduction is RAGGED.  The grid is
therefore flattened to one step per REAL (output tile, reduction k) pair —
``BlockDiagLayout.s_in/s_w/s_out`` select, for grid step s,

    input tile   s_in[s]
    weight tile  s_w[s]       (the moe_gemm weight-block-selection trick)
    output tile  s_out[s]     (revisits are consecutive grid steps)

with ``s_first/s_last`` flagging the accumulator init/flush edges.  This
replaces the earlier dense (out_tiles × k_max) grid whose clamped re-reads
burned a dead step for every tile below the maximum fan-in — the
BENCH_deep hbm_gap regression.  f32 VMEM accumulation, no scatter.

The backward pass reuses the SAME forward kernel for dh (block-diagonal with
each member block transposed — a static tile permutation + per-tile
transpose, metadata ``s_*_t``), and ``block_diag_dw`` accumulates each
parameter tile's dy^T·x over batch tiles (grid (param_tiles, b_tiles)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(dimension_semantics, *block_shapes, dtype_bytes=4):
    """Mosaic compiler params: dimension semantics (reduction dims are
    'arbitrary', independent dims 'parallel') and a VMEM budget derived from
    the kernel's live blocks (double-buffered pipeline + accumulator slack),
    floored so tiny-tile populations don't over-constrain the compiler.
    Returns None when this jax build lacks the params class (the interpret
    path ignores compiler params anyway)."""
    cls = (getattr(pltpu, "CompilerParams", None)
           or getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        return None
    import math
    need = sum(math.prod(s) * dtype_bytes for s in block_shapes)
    budget = max(4 * need, 2 * 1024 * 1024)
    try:
        return cls(dimension_semantics=tuple(dimension_semantics),
                   vmem_limit_bytes=int(budget))
    except TypeError:          # older signature without one of the fields
        return cls(dimension_semantics=tuple(dimension_semantics))


# --------------------------------------------------------------------- #
# forward (also computes dh when fed transposed metadata)               #
# --------------------------------------------------------------------- #

def _fwd_kernel(ins_ref, w_ref_ids, outs_ref, first_ref, last_ref,
                x_ref, wb_ref, y_ref, acc_ref):
    s = pl.program_id(1)

    @pl.when(first_ref[s] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (block_b, blk) @ (blk, blk)^T on the MXU, f32 accumulate; weight
    # tiles are (out_rows, in_cols) so the contraction is over dim 1/1.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], wb_ref[...][0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(last_ref[s] == 1)
    def _flush():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def block_diag_fwd(x: jax.Array, wb: jax.Array, s_in: jax.Array,
                   s_w: jax.Array, s_out: jax.Array, s_first: jax.Array,
                   s_last: jax.Array, *, n_out_tiles: int, n_steps: int,
                   block: int, block_b: int,
                   interpret: bool = False) -> jax.Array:
    """x (B, in_tiles·blk), wb (n_tiles, blk, blk) → y (B, out_tiles·blk)."""
    b = x.shape[0]
    grid = (b // block_b, n_steps)
    return pl.pallas_call(
        _fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block),
                             lambda i, s, ins, w, outs, fr, la: (i, ins[s])),
                pl.BlockSpec((1, block, block),
                             lambda i, s, ins, w, outs, fr, la: (w[s], 0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (block_b, block),
                lambda i, s, ins, w, outs, fr, la: (i, outs[s])),
            scratch_shapes=[pltpu.VMEM((block_b, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_out_tiles * block), x.dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary"),
            (block_b, block), (block, block), (block_b, block),
            (block_b, block)),
        interpret=interpret,
    )(s_in, s_w, s_out, s_first, s_last, x, wb)


# --------------------------------------------------------------------- #
# backward: dW tiles                                                    #
# --------------------------------------------------------------------- #

def _dw_kernel(ot_ref, it_ref, dy_ref, x_ref, dw_ref, acc_ref):
    i = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dW[o, i] = sum_b dy[b, o] · x[b, i]  — contract the batch tile
    acc_ref[...] += jax.lax.dot_general(
        dy_ref[...], x_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == nb - 1)
    def _flush():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)[None]


def block_diag_dw(dy: jax.Array, x: jax.Array, wb_out_tile: jax.Array,
                  wb_in_tile: jax.Array, *, n_param_blocks: int, block: int,
                  block_b: int, interpret: bool = False) -> jax.Array:
    """dy (B, out_tiles·blk), x (B, in_tiles·blk) → dWB (n_param, blk, blk).

    Parameter tile q reads dy tile wb_out_tile[q] against x tile
    wb_in_tile[q]; batch is the (inner) reduction grid dimension."""
    b = x.shape[0]
    grid = (n_param_blocks, b // block_b)
    return pl.pallas_call(
        _dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block),
                             lambda q, i, ot, it: (i, ot[q])),
                pl.BlockSpec((block_b, block),
                             lambda q, i, ot, it: (i, it[q])),
            ],
            out_specs=pl.BlockSpec((1, block, block),
                                   lambda q, i, ot, it: (q, 0, 0)),
            scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_param_blocks, block, block),
                                       dy.dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "arbitrary"),
            (block_b, block), (block_b, block), (block, block),
            (block, block)),
        interpret=interpret,
    )(wb_out_tile, wb_in_tile, dy, x)
