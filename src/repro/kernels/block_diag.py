"""Block-diagonal GEMM — the mid-layer projection of layered populations
(DESIGN.md §3).

Member m's units in layer l+1 contract ONLY member m's units in layer l, so
the fused l→l+1 weight is block-diagonal with one (O_m × I_m) block per
member.  Instead of a Python loop of per-bucket einsums this runs as ONE
dense segment-blocked matmul: the weight is stored as a flat array of
(block × block) tiles (member-major, row-major over each member's tile grid,
plus one shared identity tile for pass-through members), and three
scalar-prefetched arrays select, for output tile t at reduction step k,

    input tile   in_start[t] + k
    weight tile  w_row[t] + k          (the moe_gemm weight-block-selection
                                        trick, per *column* segment)
    steps        k < n_k[t]            (members have different fan-ins, so
                                        the reduction is masked per tile)

Grid (b_tiles, out_tiles, k_max); revisits of an output tile are consecutive
grid steps (k innermost) — the standard Pallas reduction pattern, f32 VMEM
accumulation, no scatter.  Tiles past a member's fan-in are clamped to its
last valid tile by the index map and masked out of the accumulation.

The backward pass reuses the SAME forward kernel for dh (block-diagonal with
each member block transposed — a static tile permutation + per-tile
transpose), and ``block_diag_dw`` accumulates each parameter tile's
dy^T·x over batch tiles (grid (param_tiles, b_tiles)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------- #
# forward (also computes dh when fed transposed metadata)               #
# --------------------------------------------------------------------- #

def _fwd_kernel(ins_ref, row_ref, nk_ref, x_ref, w_ref, y_ref, acc_ref):
    t = pl.program_id(1)
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < nk_ref[t])
    def _accum():
        # (block_b, blk) @ (blk, blk)^T on the MXU, f32 accumulate; weight
        # tiles are (out_rows, in_cols) so the contraction is over dim 1/1.
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...][0],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def block_diag_fwd(x: jax.Array, wb: jax.Array, in_start: jax.Array,
                   w_row: jax.Array, n_k: jax.Array, *,
                   n_out_tiles: int, k_max: int, block: int, block_b: int,
                   interpret: bool = False) -> jax.Array:
    """x (B, in_tiles·blk), wb (n_tiles, blk, blk) → y (B, out_tiles·blk)."""
    b = x.shape[0]
    grid = (b // block_b, n_out_tiles, k_max)
    return pl.pallas_call(
        _fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block),
                             lambda i, t, k, ins, row, nk: (i, ins[t] + jnp.minimum(k, nk[t] - 1))),
                pl.BlockSpec((1, block, block),
                             lambda i, t, k, ins, row, nk: (row[t] + jnp.minimum(k, nk[t] - 1), 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_b, block),
                                   lambda i, t, k, ins, row, nk: (i, t)),
            scratch_shapes=[pltpu.VMEM((block_b, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_out_tiles * block), x.dtype),
        interpret=interpret,
    )(in_start, w_row, n_k, x, wb)


# --------------------------------------------------------------------- #
# backward: dW tiles                                                    #
# --------------------------------------------------------------------- #

def _dw_kernel(ot_ref, it_ref, dy_ref, x_ref, dw_ref, acc_ref):
    i = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dW[o, i] = sum_b dy[b, o] · x[b, i]  — contract the batch tile
    acc_ref[...] += jax.lax.dot_general(
        dy_ref[...], x_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == nb - 1)
    def _flush():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)[None]


def block_diag_dw(dy: jax.Array, x: jax.Array, wb_out_tile: jax.Array,
                  wb_in_tile: jax.Array, *, n_param_blocks: int, block: int,
                  block_b: int, interpret: bool = False) -> jax.Array:
    """dy (B, out_tiles·blk), x (B, in_tiles·blk) → dWB (n_param, blk, blk).

    Parameter tile q reads dy tile wb_out_tile[q] against x tile
    wb_in_tile[q]; batch is the (inner) reduction grid dimension."""
    b = x.shape[0]
    grid = (n_param_blocks, b // block_b)
    return pl.pallas_call(
        _dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, block),
                             lambda q, i, ot, it: (i, ot[q])),
                pl.BlockSpec((block_b, block),
                             lambda q, i, ot, it: (i, it[q])),
            ],
            out_specs=pl.BlockSpec((1, block, block),
                                   lambda q, i, ot, it: (q, 0, 0)),
            scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_param_blocks, block, block),
                                       dy.dtype),
        interpret=interpret,
    )(wb_out_tile, wb_in_tile, dy, x)
