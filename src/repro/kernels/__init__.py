"""Pallas TPU kernels for the framework's hot spots (validated in interpret
mode on CPU):

  m3_matmul       — segment-blocked matmul (the TPU-native M3), fwd + custom bwd
  block_diag_gemm — block-diagonal member projection (layered-population mid
                    layers), fwd + custom bwd via the same kernel transposed
  seg_act         — one-pass per-block activation dispatch + padding mask
  moe_gemm        — grouped GEMM (M3's row-segment dual; MoE expert compute)
  flash_attention — fused online-softmax attention (causal/SWA/GQA), the
                    §Perf-identified lever for memory-bound attention cells
"""
from repro.kernels.ops import (block_diag_gemm, flash_attention, m3_matmul,
                               moe_gemm, seg_act)

__all__ = ["block_diag_gemm", "flash_attention", "m3_matmul", "moe_gemm",
           "seg_act"]
