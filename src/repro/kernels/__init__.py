"""Pallas TPU kernels for the framework's hot spots (validated in interpret
mode on CPU):

  m3_matmul       — segment-blocked matmul (the TPU-native M3), fwd + custom bwd
  block_diag_gemm — block-diagonal member projection (layered-population mid
                    layers), fwd + custom bwd via the same kernel transposed
  fused_layer     — block-diag projection + bias + per-segment activation in
                    ONE pass (act'(z) emitted in-register for the fused
                    backward; pre-activations never reach HBM — DESIGN.md §7)
  seg_act         — one-pass per-block activation dispatch + padding mask
  moe_gemm        — grouped GEMM (M3's row-segment dual; MoE expert compute)
  flash_attention — fused online-softmax attention (causal/SWA/GQA), the
                    §Perf-identified lever for memory-bound attention cells
"""
from repro.kernels.ops import (block_diag_gemm, flash_attention, fused_layer,
                               m3_matmul, moe_gemm, seg_act)

__all__ = ["block_diag_gemm", "flash_attention", "fused_layer", "m3_matmul",
           "moe_gemm", "seg_act"]
