"""Pallas TPU kernels for the framework's hot spots (validated in interpret
mode on CPU):

  m3_matmul       — segment-blocked matmul (the TPU-native M3), fwd + custom bwd
  seg_act         — one-pass per-block activation dispatch + padding mask
  moe_gemm        — grouped GEMM (M3's row-segment dual; MoE expert compute)
  flash_attention — fused online-softmax attention (causal/SWA/GQA), the
                    §Perf-identified lever for memory-bound attention cells
"""
from repro.kernels.ops import flash_attention, m3_matmul, moe_gemm, seg_act

__all__ = ["flash_attention", "m3_matmul", "moe_gemm", "seg_act"]
