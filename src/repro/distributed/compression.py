"""Gradient compression for the cross-pod (DCI) all-reduce.

The multi-pod mesh reduces gradients over the 'pod' axis across data-center
interconnect — an order of magnitude less bandwidth than in-pod ICI.  This
module implements int8-quantised all-reduce with ERROR FEEDBACK (residual
carried into the next step), the standard trick that keeps convergence
while cutting DCI bytes 4× vs f32 (2× vs bf16):

    q      = round(clip(g + err, ±s·127) / s)        s = max|g+err| / 127
    g_hat  = psum(q) · s_avg                          (int8 on the wire)
    err'   = (g + err) - q·s                          (local residual)

Usage is explicitly opt-in (--compress-grads): the train driver wraps its
gradient tree with :func:`compressed_psum_tree` inside a shard_map that is
manual ONLY over 'pod' (everything else stays GSPMD-auto)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.quant import dequantize, quantize, symmetric_scale


def quantize_int8(g: jax.Array, err: jax.Array):
    """Returns (q int8, scale f32, new_err).

    Composes the shared symmetric-scale helpers (``repro.quant``) that the
    serve-copy packer also uses — the op sequence is bit-identical to the
    original inline formula (regression-tested in
    tests/test_quantized_serve.py)."""
    gf = g.astype(jnp.float32) + err
    scale = symmetric_scale(gf)
    q = quantize(gf, scale)
    new_err = gf - dequantize(q, scale)
    return q, scale, new_err


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str):
    """int8 all-reduce over ``axis_name`` with error feedback.

    Inside shard_map.  The int32 widen is local; only int8 + one f32 scalar
    cross the wire per leaf."""
    q, scale, new_err = quantize_int8(g, err)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # each member contributed q_i·s_i ≈ g_i ; reconstruct the mean with the
    # mean scale (unbiased when scales are similar; error feedback absorbs
    # the rest)
    g_hat = qsum.astype(jnp.float32) * (ssum / n) / n
    return g_hat.astype(g.dtype), new_err


def compressed_psum_tree(grads, err_tree, axis_name: str):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_tree)
    out = [compressed_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
