"""Sharding vocabulary + helpers.

Axis roles (DESIGN.md §5):
  pod    — outermost data parallelism across pods (crosses DCI)
  data   — in-pod data parallelism; params/optimizer FSDP-sharded over it
  model  — tensor/expert/sequence-parallel axis (TP/EP/SP); also the
           population axis for ParallelMLP training (zero-collective)

Specs are written against the FULL axis set; :func:`constrain` and
:func:`filter_spec` drop axes that the ambient mesh doesn't have, so the
same model code runs on (data, model), (pod, data, model) and single-device
CPU without edits.  Axes whose dim size doesn't divide are also dropped
(GSPMD requires even sharding for explicit constraints; uneven cases —
batch=1 long_500k decode — degrade to replication, which is correct, just
not distributed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh, set_mesh

# canonical spec fragments
BATCH_AXES = ("pod", "data")        # batch dim shards over both DP axes
FSDP_AXIS = "data"                  # parameter sharding (ZeRO-3 style)
TP_AXIS = "model"                   # tensor/expert/sequence parallel
POP_AXIS = "model"                  # population members (paper's axis)

# Megatron-style inner-dim TP is applied only to projections at least this
# wide: for big layers it shrinks weight-grad buffers/all-reduces by the TP
# degree (nemotron: 3× on the collective term), but for small layers the
# AG/RS transitions cost more than the dW savings (qwen3 regressed 28% when
# constrained unconditionally — §Perf hillclimb, refuted-then-refined).
TP_INNER_MIN_COLS = 8192


def mesh_axis_sizes() -> dict:
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return {}
    return dict(mesh.shape)


def filter_spec(spec: P, dims=None) -> P:
    """Drop mesh axes that don't exist; optionally check divisibility against
    ``dims`` (the tensor shape) and drop non-dividing axes.

    On a multi-pod mesh, a bare 'data' entry expands to ('pod','data') —
    hybrid FSDP: parameter/gradient/optimizer shards span pods (ZeRO across
    DCI), halving per-chip state on the 2-pod mesh (§Perf iteration 4).
    Specs that already mention 'pod' (batch dims) are left as written."""
    sizes = mesh_axis_sizes()
    if "pod" in sizes and not _mentions_pod(spec):
        spec = P(*(_expand_data(e) for e in spec))

    def ax_size(e):
        if isinstance(e, (tuple, list)):
            out = 1
            for a in e:
                out *= sizes.get(a, 1)
            return out
        return sizes.get(e, 1)

    def filt(i, e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in sizes)
        else:
            kept = (e,) if e in sizes else ()
        if not kept:
            return None
        if dims is not None:
            total = 1
            for a in kept:
                total *= sizes[a]
            if dims[i] % total != 0:
                return None
        return kept if len(kept) > 1 else kept[0]

    return P(*(filt(i, e) for i, e in enumerate(spec)))


def _mentions_pod(spec: P) -> bool:
    for e in spec:
        if e == "pod" or (isinstance(e, (tuple, list)) and "pod" in e):
            return True
    return False


def _expand_data(e):
    if e == "data":
        return ("pod", "data")
    if isinstance(e, (tuple, list)) and "data" in e and "pod" not in e:
        return tuple(a for a in e) + ("pod",)
    return e


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that degrades gracefully: no mesh → no-op;
    missing/non-dividing axes → dropped."""
    sizes = mesh_axis_sizes()
    if not sizes:
        return x
    return jax.lax.with_sharding_constraint(x, filter_spec(spec, x.shape))


def logical_to_sharding(spec_tree, mesh: Mesh, shape_tree):
    """Spec tree + mesh + abstract shapes -> NamedSharding tree (axes
    filtered per-leaf for existence and divisibility)."""
    def leaf(spec, shp):
        with set_mesh(mesh):
            f = filter_spec(spec, shp.shape)
        return NamedSharding(mesh, f)
    return jax.tree.map(leaf, spec_tree, shape_tree,
                        is_leaf=lambda s: isinstance(s, P))


def stack_spec(spec_tree):
    """Prepend a replicated leading (layer) axis to every leaf spec — the
    spec-side mirror of vmapping an init over a stacked layer group."""
    return jax.tree.map(lambda s: P(None, *s),
                        spec_tree, is_leaf=lambda s: isinstance(s, P))


# canonical activation specs
ACT_RESIDUAL = P(BATCH_AXES, TP_AXIS, None)   # (B, S/model, D): SP residual
ACT_FULL_SEQ = P(BATCH_AXES, None, None)      # (B, S, D) gathered
ACT_HEADS = P(BATCH_AXES, None, TP_AXIS, None)          # (B, S, H/model, dh)
ACT_DECODE = P(BATCH_AXES, None, None)        # (B, 1, D)

# ------------------------------------------------------------------ #
# population specs (the paper's member axis; DESIGN.md §5)           #
# ------------------------------------------------------------------ #
# Fused population tensors are member-major: the fused hidden axis, the
# per-bucket member axis, and the (P, O) output-bias member axis all shard
# over POP_AXIS with ZERO cross-member collectives (members are
# independent by construction).  Logits carry the member axis at dim 1.
POP_HIDDEN = P(POP_AXIS)                      # (H_tot,) fused hidden
POP_BUCKET = P(POP_AXIS, None, None)          # (n, h_out, h_in) bucket stack
POP_LOGITS = P(BATCH_AXES, POP_AXIS, None)    # (B, P, O) per-member logits
POP_MEMBER = P(POP_AXIS)                      # (P,) per-member reductions
# Population train batches are (scan, B, ...): the scan axis stays on every
# device (each inner step consumes one slice), the BATCH axis shards over
# the data axes — population runs stop replicating their batches to the
# whole mesh.  GSPMD inserts the per-member loss-mean psum over 'data'.
POP_BATCH_X = P(None, BATCH_AXES, None)       # (scan, B, F) features
POP_BATCH_Y = P(None, BATCH_AXES)             # (scan, B) targets


def pop_axis_size(mesh=None) -> int:
    """Size of the population ('model') axis — of ``mesh`` if given, else of
    the ambient mesh; 1 when unmeshed.  The member-count/hidden-axis
    divisor that ``LayeredPopulation.shard_pad`` must satisfy."""
    if mesh is not None:
        return int(dict(mesh.shape).get(POP_AXIS, 1))
    return int(mesh_axis_sizes().get(POP_AXIS, 1))


def population_batch_shardings(mesh, batch_size: int):
    """NamedShardings for a population train chunk's ``(xs, ys)`` inputs
    (leading scan axis, then batch): the batch axis shards over the mesh's
    data axes, FALLING BACK to replication when ``batch_size`` doesn't
    divide them (``filter_spec`` drops the non-dividing axes, the
    documented degradation).  The specs are shape-agnostic in the leading
    scan axis, so one sharding pair serves full and tail chunks."""
    with set_mesh(mesh):
        fx = filter_spec(POP_BATCH_X, (1, batch_size, 1))
        fy = filter_spec(POP_BATCH_Y, (1, batch_size))
    return NamedSharding(mesh, fx), NamedSharding(mesh, fy)


def population_shardings(layout, mesh, dtype=None):
    """``layout.param_specs()`` + mesh → NamedSharding tree for the layout's
    parameter tree (per-leaf axis filtering handles buckets whose member
    run doesn't divide the axis — those replicate)."""
    import jax.numpy as jnp

    from repro.core.deep import abstract_params
    abs_p = abstract_params(layout, dtype or jnp.float32)
    return logical_to_sharding(layout.param_specs(), mesh, abs_p)


def population_opt_shardings(layout, opt, mesh, dtype=None):
    """``layout.opt_specs(opt)`` + mesh → NamedSharding tree for the
    optimizer STATE of training this layout with ``opt`` (a
    ``repro.optim.Optimizer``).  Every state leaf inherits the sharding of
    the parameter it tracks, so this is what born-sharded ``opt.init``
    out_shardings, rung-boundary ``device_put``s of compacted moments, and
    sharded opt-state restores all run through."""
    import jax
    import jax.numpy as jnp

    from repro.core.deep import abstract_params
    abs_st = jax.eval_shape(opt.init,
                            abstract_params(layout, dtype or jnp.float32))
    return logical_to_sharding(layout.opt_specs(opt, dtype), mesh, abs_st)


def population_state_shardings(layout, opt, mesh, dtype=None):
    """``(params, opt_state)`` NamedSharding pair for one layout — the
    rung-boundary bundle: every layout change (compact → re-pad, grow
    splice, constant-size refill) device_puts or out_shardings BOTH trees
    against the same mesh, so the driver fetches them together instead of
    re-deriving each side separately (and possibly against different
    meshes)."""
    return (population_shardings(layout, mesh, dtype),
            population_opt_shardings(layout, opt, mesh, dtype))
