"""Fault tolerance: checkpoint/restart loop, straggler watchdog, elastic
re-mesh.

At 1000+ nodes the mean time between chip/host failures drops below job
length; the framework therefore treats the train loop as a RESUMABLE pure
function of (checkpoint, step, data(step)):

  * ``TrainRunner`` — drives steps, checkpoints asynchronously every K
    steps, and on ANY exception restores the last committed checkpoint and
    replays (data is step-indexed → bitwise-identical replay).  Failure
    injection hooks make this testable on one host
    (tests/test_fault_tolerance.py).
  * ``StragglerPolicy`` — wall-clock per-step watchdog.  On a real pod the
    reaction is implemented by the control plane (preempt + re-slice); in
    this single-process framework the policy records the event, optionally
    triggers an elastic re-mesh, and raises after ``max_strikes``
    consecutive slow steps so the runner's restart path takes over.
  * ``elastic_remesh`` — rebuild a mesh from the CURRENTLY live device set
    (after losing a pod or scaling in new ones) and re-shard a state tree
    onto it.  Works because checkpoints store full host arrays and the
    spec trees are mesh-shape-agnostic (sharding.filter_spec).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_steps, restore
from repro.distributed.sharding import logical_to_sharding


@dataclasses.dataclass
class StragglerPolicy:
    timeout_s: float = 60.0
    max_strikes: int = 3
    on_straggler: Optional[Callable[[int, float], None]] = None
    strikes: int = 0
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float):
        if dt <= self.timeout_s:
            self.strikes = 0
            return
        self.strikes += 1
        self.events.append((step, dt))
        if self.on_straggler:
            self.on_straggler(step, dt)
        if self.strikes >= self.max_strikes:
            raise TimeoutError(
                f"step {step}: {self.strikes} consecutive steps over "
                f"{self.timeout_s}s — requesting restart/re-slice")


def elastic_remesh(state_tree, spec_tree, axis_order=("data", "model"),
                   devices=None):
    """Rebuild the largest (data × model) mesh from live devices and
    re-shard ``state_tree`` onto it.  model dim is kept if possible,
    data absorbs the remainder (data parallelism degrades gracefully)."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    model = 1
    for cand in (16, 8, 4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    from repro.compat import make_mesh
    mesh = make_mesh((n // model, model), axis_order,
                     devices=np.asarray(devices))
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state_tree)
    sh = logical_to_sharding(spec_tree, mesh, abstract)
    resharded = jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        state_tree, sh)
    return mesh, resharded


class TrainRunner:
    """Checkpoint/restart training driver.

    step_fn(state, step) -> (state, metrics)  must be pure & replayable.
    ``failure_hook(step)`` (tests) may raise to simulate chip loss."""

    def __init__(self, step_fn, state, *, ckpt_dir: str,
                 ckpt_every: int = 50, keep_last: int = 3,
                 straggler: StragglerPolicy | None = None,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 max_restarts: int = 3, ckpt_meta: dict | None = None,
                 ckpt_step_map: Optional[Callable[[int], int]] = None,
                 ckpt_step_unmap: Optional[Callable[[int], int]] = None,
                 ckpt_save_pred: Optional[Callable[[int], bool]] = None,
                 on_restore: Optional[Callable[[int], None]] = None,
                 restore_shardings=None, mesh=None, state_specs=None):
        """``ckpt_meta``/``ckpt_step_map``: forwarded to the checkpointer
        (population runs attach the fused layout and record GLOBAL step
        numbers while the runner counts scan chunks); ``ckpt_step_unmap``
        is the inverse of ``ckpt_step_map`` — the crash-restore path maps a
        restored checkpoint's recorded step back into the runner's step
        domain.  ``restore_shardings``: optional sharding tree matching
        ``state`` — crash restores device_put straight back onto the mesh
        instead of replicating.  ``mesh`` + ``state_specs`` (a
        PartitionSpec tree matching ``state``, e.g. ``{"params":
        layout.param_specs()}``) derive ``restore_shardings`` here, so
        callers wire their LOGICAL specs through and mid-run replay stays
        sharded without hand-building NamedSharding trees.

        ``on_restore(step)`` fires after every crash restore with the step
        the replay will re-enter at — the hook for re-synchronising
        step-indexed side state the replay would otherwise desynchronise
        (the streaming data plane drops queued slabs / unresolved deferred
        metrics for the abandoned trajectory, DESIGN.md §11)."""
        self.step_fn = step_fn
        self.state = state
        if restore_shardings is None and mesh is not None \
                and state_specs is not None:
            abstract = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            restore_shardings = logical_to_sharding(state_specs, mesh,
                                                    abstract)
        self.ckpt = AsyncCheckpointer(ckpt_dir, every=ckpt_every,
                                      keep_last=keep_last, meta=ckpt_meta,
                                      step_map=ckpt_step_map,
                                      save_pred=ckpt_save_pred)
        self.ckpt_step_unmap = ckpt_step_unmap or (lambda s: s)
        self.on_restore = on_restore
        self.restore_shardings = restore_shardings
        self.straggler = straggler or StragglerPolicy(timeout_s=1e9)
        self.failure_hook = failure_hook
        self.max_restarts = max_restarts
        self.restarts = 0
        self.metrics_log = []
        # host snapshot of the INITIAL state: a failure before the first
        # committed checkpoint replays from step 0 (data is step-indexed, so
        # replay is exact) — required because the current live state may
        # have been mutated by completed steps or DELETED by an
        # argument-donating step that failed mid-chunk.  Skipped when the
        # directory already holds a committed checkpoint (resume: _restore
        # reads disk instead) and freed as soon as one commits.
        self._init_state_host = None if latest_steps(ckpt_dir) else \
            jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

    def _put(self, host_tree):
        if self.restore_shardings is not None:
            return jax.tree.map(jax.device_put, host_tree,
                                self.restore_shardings)
        return jax.tree.map(jax.device_put, host_tree)

    def _restore(self):
        self.ckpt.wait()
        steps = latest_steps(self.ckpt.directory)
        if not steps:
            if self._init_state_host is None:
                # can only happen if the checkpoint dir vanished after a
                # commit freed the snapshot — nothing left to replay from
                raise RuntimeError(
                    f"no committed checkpoint under {self.ckpt.directory} "
                    "and the initial-state snapshot was already released")
            self.state = self._put(self._init_state_host)
            if self.on_restore:
                self.on_restore(0)
            return 0
        self.state, step = restore(self.ckpt.directory, self.state,
                                   shardings=self.restore_shardings)
        step = self.ckpt_step_unmap(step) + 1
        if self.on_restore:
            self.on_restore(step)
        return step

    def run(self, num_steps: int, start_step: int = 0) -> int:
        step = start_step
        while step < num_steps:
            try:
                t0 = time.time()
                if self.failure_hook:
                    self.failure_hook(step)
                self.state, metrics = self.step_fn(self.state, step)
                self.straggler.observe(step, time.time() - t0)
                self.metrics_log.append((step, metrics))
                self.ckpt.maybe_save(step, self.state)
                if self._init_state_host is not None and self.ckpt.saved:
                    self._init_state_host = None  # a checkpoint committed
                step += 1
            except (KeyboardInterrupt,):
                raise
            except Exception as e:   # noqa: BLE001 — restart on ANY failure
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                step = self._restore()
        self.ckpt.wait()
        return step
