"""Distribution layer: sharding vocabulary, gradient compression,
fault tolerance / elastic re-mesh."""
from repro.distributed.compression import (compressed_psum,
                                           compressed_psum_tree,
                                           init_error_feedback,
                                           quantize_int8)
from repro.distributed.fault_tolerance import (StragglerPolicy, TrainRunner,
                                               elastic_remesh)
from repro.distributed.sharding import (ACT_RESIDUAL, BATCH_AXES, POP_AXIS,
                                        POP_BUCKET, POP_HIDDEN, POP_LOGITS,
                                        POP_MEMBER, constrain, filter_spec,
                                        logical_to_sharding, mesh_axis_sizes,
                                        pop_axis_size,
                                        population_batch_shardings,
                                        population_shardings, stack_spec)

__all__ = [
    "compressed_psum", "compressed_psum_tree", "init_error_feedback",
    "quantize_int8", "StragglerPolicy", "TrainRunner", "elastic_remesh",
    "ACT_RESIDUAL", "BATCH_AXES", "POP_AXIS", "POP_BUCKET", "POP_HIDDEN",
    "POP_LOGITS", "POP_MEMBER", "constrain", "filter_spec",
    "logical_to_sharding", "mesh_axis_sizes", "pop_axis_size",
    "population_batch_shardings", "population_shardings", "stack_spec",
]
