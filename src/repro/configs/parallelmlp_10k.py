"""parallelmlp-10k [population] — the paper's OWN experiment as an arch.

10,000 independent MLPs fused into one network (§4.2 of the paper):
hidden sizes 1..100 × 10 activation functions × 10 repeats, 100 input
features, 2 classes.  block=128 aligns every member's hidden slice to the
TPU lane width so M3 lowers to the segment-blocked matmul kernel; block=1
(reduced/CPU) reproduces the paper's exact layout.

Distribution: members shard over the 'model' axis — ZERO cross-member
collectives (the paper's "embarrassingly parallel" becomes literal mesh
locality); batch shards over ('pod','data') with per-member gradient
all-reduce."""
from repro.configs.base import ArchSpec
from repro.core.activations import PAPER_TEN
from repro.core.population import Population

IN_FEATURES = 100
OUT_CLASSES = 2


def config() -> ArchSpec:
    # §Perf iterations (paper cell) — tight packing REFUTED twice:
    #   block 128→8 (130 buckets)            → mem term 7.6→297 ms
    #   block=8 + size-major order (13)      → mem term 7.6→64.5 ms
    # Diagnosis: bucket slice boundaries don't align with the 16-way shard
    # grid of the fused hidden axis, so every slice triggers SPMD
    # rematerialisation.  The paper's ONE-fused-op layout (uniform 128 pad,
    # single bucket einsum) beats tight packing at scale; its 2.5× padding
    # waste lands on the idle compute term.  Kept at 128.
    pop = Population.grid(IN_FEATURES, OUT_CLASSES,
                          hidden_range=range(1, 101),
                          activations=PAPER_TEN,
                          repeats=10, block=128)
    return ArchSpec(
        arch_id="parallelmlp-10k", kind="population", model=pop,
        optimizer="sgd", lr=1e-2,
        skip_shapes=("prefill_32k", "decode_32k", "long_500k"),
        skip_reason="tabular MLP population: LM shapes are not defined; "
                    "the paper's own shape grid lives in "
                    "benchmarks/bench_paper_tables.py",
        source="[the reproduced paper, §4.2]",
        notes="10,000 members, total fused hidden = 1,280,000 (128-aligned); "
              "population axis = 'model'.")


def reduced() -> ArchSpec:
    pop = Population.grid(10, 3, hidden_range=range(1, 9),
                          activations=("relu", "tanh"), repeats=2, block=8)
    return ArchSpec(arch_id="parallelmlp-10k", kind="population", model=pop,
                    optimizer="sgd", lr=1e-2)
