"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144.

qk-norm (per-head RMS on q and k), tied embeddings, vocab 151936,
rope_theta 1e6.  [hf:Qwen/Qwen3-8B family].  Also the demo arch for the
paper's population-axis training (examples/train_lm.py --population)."""
from repro.configs.base import ArchSpec
from repro.models.lm import LayerSpec, LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.ffn import FFNConfig


def config() -> ArchSpec:
    model = LMConfig(
        name="qwen3-1.7b", vocab=151_936, d_model=2048,
        layers=tuple(LayerSpec("attn", "dense", 0) for _ in range(28)),
        attn=AttnConfig(d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
                        qk_norm=True, rope_theta=1e6),
        ffn=FFNConfig(2048, 6144, act="silu", gated=True),
        # §Perf note: remat=False was tried (saves the 2·N·D recompute) and
        # REFUTED — 1M-token steps push saved activations to 100 GiB/chip;
        # full remat + the width-gated TP policy is the measured optimum
        norm="rmsnorm", tie_embeddings=True)
    return ArchSpec(
        arch_id="qwen3-1.7b", kind="lm", model=model,
        optimizer="adamw", lr=3e-4,
        skip_shapes=("long_500k",),
        skip_reason="full attention: 512k dense KV cache has no "
                    "sub-quadratic lowering (DESIGN.md §shape-skips)",
        source="[hf:Qwen/Qwen3-8B; hf]",
        notes="152k vocab dominates the 1.7B param count; logits are the "
              "compute hot-spot at train_4k.")


def reduced() -> ArchSpec:
    model = LMConfig(
        name="qwen3-reduced", vocab=293, d_model=64,
        layers=tuple(LayerSpec("attn", "dense", 0) for _ in range(3)),
        attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                        qk_norm=True),
        ffn=FFNConfig(64, 128, act="silu", gated=True),
        norm="rmsnorm", tie_embeddings=True, param_dtype="float32",
        remat=False)
    return ArchSpec(arch_id="qwen3-1.7b", kind="lm", model=model,
                    optimizer="adamw", lr=1e-3)
