"""mamba2-780m [ssm] — 48L d_model=1536, attention-free, ssm_state=128.

SSD (state-space duality), arXiv:2405.21060.  No FFN (pure Mamba2 stack,
d_ff=0 per assignment); tied embeddings, RMSNorm, vocab 50280 (GPT-NeoX).
Runs ALL four shapes including long_500k (O(1) recurrent state)."""
from repro.configs.base import ArchSpec
from repro.models.lm import LayerSpec, LMConfig
from repro.nn.ssm import SSMConfig


def config() -> ArchSpec:
    model = LMConfig(
        name="mamba2-780m", vocab=50_280, d_model=1536,
        layers=tuple(LayerSpec("ssm", "none", 0) for _ in range(48)),
        ssm=SSMConfig(d_model=1536, d_state=128, d_conv=4, expand=2,
                      head_dim=64, n_groups=1, chunk=256),
        norm="rmsnorm", tie_embeddings=True)
    return ArchSpec(
        arch_id="mamba2-780m", kind="lm", model=model,
        optimizer="adamw", lr=6e-4,
        num_micro=(("train_4k", 2),),
        source="[arXiv:2405.21060; unverified]",
        notes="SSD chunked scan; heads (48) shard over 'model'; long_500k "
              "runs on the O(1) SSM state.")


def reduced() -> ArchSpec:
    model = LMConfig(
        name="mamba2-reduced", vocab=257, d_model=64,
        layers=tuple(LayerSpec("ssm", "none", 0) for _ in range(3)),
        ssm=SSMConfig(d_model=64, d_state=16, d_conv=4, expand=2,
                      head_dim=16, n_groups=1, chunk=16),
        norm="rmsnorm", tie_embeddings=True, param_dtype="float32",
        remat=False)
    return ArchSpec(arch_id="mamba2-780m", kind="lm", model=model,
                    optimizer="adamw", lr=1e-3)
