"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728.

arXiv:2402.16819: squared-ReLU non-gated FFN, no biases, untied embeddings,
vocab 256000, LayerNorm.  340B params → adafactor (factored v, bf16 m):
param+opt state = 340B×(2+2) + factored stats ≈ 1.4 TB → 5.6 GB/chip at 256
chips; activations held down by 16-way microbatching + SP residual + remat."""
from repro.configs.base import ArchSpec
from repro.models.lm import LayerSpec, LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.ffn import FFNConfig


def config() -> ArchSpec:
    model = LMConfig(
        name="nemotron-4-340b", vocab=256_000, d_model=18_432,
        layers=tuple(LayerSpec("attn", "dense", 0) for _ in range(96)),
        attn=AttnConfig(d_model=18_432, n_heads=96, n_kv_heads=8, d_head=192,
                        rope_theta=1e4),
        ffn=FFNConfig(18_432, 73_728, act="relu2", gated=False),
        norm="layernorm")
    return ArchSpec(
        arch_id="nemotron-4-340b", kind="lm", model=model,
        optimizer="adafactor", lr=1.2e-4,
        grad_accum_dtype="bfloat16",   # §Perf iter 5: halve grad buffers
        # 8 microbatches: 32 seqs each — divisible by BOTH dp widths
        # (16 single-pod, 32 multi-pod); 16 would leave multi-pod batches
        # unshardable (replicated activations blew past HBM)
        num_micro=(("train_4k", 8),),
        skip_shapes=("long_500k",),
        skip_reason="full attention: 512k dense KV cache has no "
                    "sub-quadratic lowering (DESIGN.md §shape-skips)",
        source="[arXiv:2402.16819; unverified]",
        notes="the memory-pressure stress arch: FSDP('data') × TP('model') "
              "2D param sharding, adafactor, 16 microbatches.")


def reduced() -> ArchSpec:
    model = LMConfig(
        name="nemotron-reduced", vocab=283, d_model=64,
        layers=tuple(LayerSpec("attn", "dense", 0) for _ in range(3)),
        attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16),
        ffn=FFNConfig(64, 128, act="relu2", gated=False),
        norm="layernorm", param_dtype="float32", remat=False)
    return ArchSpec(arch_id="nemotron-4-340b", kind="lm", model=model,
                    optimizer="adafactor", lr=1e-3)
