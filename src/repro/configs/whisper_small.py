"""whisper-small [audio] — 12L enc + 12L dec, d_model=768 12H d_ff=3072.

arXiv:2212.04356.  Encoder-decoder; the conv frontend is a STUB per the
assignment (input_specs provides precomputed frame embeddings).  MHA
(kv=12), LayerNorm, biases, GELU FFN, vocab 51865, tied decoder readout.

Shape notes (DESIGN.md §shape-skips): decode_32k runs with a 32k learned
position table + 32k self-KV — beyond whisper's natural 448 targets, dry-run
only.  long_500k is skipped (dense cross+self attention, no sub-quadratic
path; 512k decoder positions are architecturally meaningless here)."""
from repro.configs.base import ArchSpec
from repro.models.encdec import EncDecConfig
from repro.nn.attention import AttnConfig
from repro.nn.ffn import FFNConfig


def config() -> ArchSpec:
    model = EncDecConfig(
        name="whisper-small", vocab=51_865, d_model=768,
        n_enc_layers=12, n_dec_layers=12,
        attn=AttnConfig(d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
                        bias=True, rope_kind="none"),
        ffn=FFNConfig(768, 3072, act="gelu", gated=False, bias=True),
        max_target=32_768)
    return ArchSpec(
        arch_id="whisper-small", kind="encdec", model=model,
        optimizer="adamw", lr=1e-3,
        skip_shapes=("long_500k",),
        skip_reason="enc-dec with dense self+cross attention; 512k decoder "
                    "positions have no sub-quadratic lowering and exceed the "
                    "architecture's design range (natural max 448)",
        source="[arXiv:2212.04356; unverified]",
        notes="frame-embedding frontend stub; train/prefill seq_len applies "
              "to encoder frames AND decoder tokens.")


def reduced() -> ArchSpec:
    model = EncDecConfig(
        name="whisper-reduced", vocab=311, d_model=64,
        n_enc_layers=2, n_dec_layers=2,
        attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
                        bias=True, rope_kind="none"),
        ffn=FFNConfig(64, 128, act="gelu", gated=False, bias=True),
        max_target=64, param_dtype="float32")
    return ArchSpec(arch_id="whisper-small", kind="encdec", model=model,
                    optimizer="adamw", lr=1e-3)
