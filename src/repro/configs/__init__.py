"""Architecture registry: ``--arch <id>`` resolution.

10 assigned architectures + the paper's own population experiment."""
from __future__ import annotations

import importlib

from repro.configs.base import ALL_SHAPES, SHAPE_GRID, ArchSpec, ShapeSpec, shape

_MODULES = {
    "mamba2-780m": "mamba2_780m",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x22b": "mixtral_8x22b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "command-r-35b": "command_r_35b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen3-1.7b": "qwen3_1_7b",
    "whisper-small": "whisper_small",
    "hymba-1.5b": "hymba_1_5b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "parallelmlp-10k": "parallelmlp_10k",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "parallelmlp-10k")
ALL_ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_arch(arch_id: str, reduced: bool = False) -> ArchSpec:
    mod = _module(arch_id)
    return mod.reduced() if reduced else mod.config()


__all__ = ["ALL_SHAPES", "SHAPE_GRID", "ArchSpec", "ShapeSpec", "shape",
           "ARCH_IDS", "ALL_ARCH_IDS", "get_arch"]
