"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240.

llama+mistral mix with SWA (window 4096 per assignment), vocab 32000.
arXiv:2401.16818.  d_head = 120 (3840/32)."""
from repro.configs.base import ArchSpec
from repro.models.lm import LayerSpec, LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.ffn import FFNConfig

SWA = 4096


def config() -> ArchSpec:
    model = LMConfig(
        name="h2o-danube-3-4b", vocab=32_000, d_model=3840,
        layers=tuple(LayerSpec("attn", "dense", SWA) for _ in range(24)),
        attn=AttnConfig(d_model=3840, n_heads=32, n_kv_heads=8, d_head=120,
                        sliding_window=SWA, rope_theta=5e5),
        ffn=FFNConfig(3840, 10_240, act="silu", gated=True),
        norm="rmsnorm")
    return ArchSpec(
        arch_id="h2o-danube-3-4b", kind="lm", model=model,
        optimizer="adamw", lr=3e-4,
        num_micro=(("train_4k", 2),),
        source="[arXiv:2401.16818; unverified]",
        notes="SWA ring KV bounds the cache → long_500k legal.")


def reduced() -> ArchSpec:
    model = LMConfig(
        name="danube-reduced", vocab=271, d_model=64,
        layers=tuple(LayerSpec("attn", "dense", 16) for _ in range(3)),
        attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                        sliding_window=16),
        ffn=FFNConfig(64, 128, act="silu", gated=True),
        norm="rmsnorm", param_dtype="float32", remat=False)
    return ArchSpec(arch_id="h2o-danube-3-4b", kind="lm", model=model,
                    optimizer="adamw", lr=1e-3)
