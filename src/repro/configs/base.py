"""Config system: shape grid, ArchSpec contract, and the registry helpers.

Every assigned architecture ships as ``src/repro/configs/<id>.py`` exposing

    def config()  -> ArchSpec   # the EXACT assigned configuration
    def reduced() -> ArchSpec   # same family, laptop-scale (smoke tests)

Full configs are only ever touched through ``abstract_params`` +
``jax.eval_shape`` (the dry-run path); only reduced configs allocate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

SHAPE_GRID = {
    # name: (kind, seq_len, global_batch)
    "train_4k":    ("train",   4_096,   256),
    "prefill_32k": ("prefill", 32_768,  32),
    "decode_32k":  ("decode",  32_768,  128),
    "long_500k":   ("decode",  524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


def shape(name: str) -> ShapeSpec:
    kind, s, b = SHAPE_GRID[name]
    return ShapeSpec(name, kind, s, b)


ALL_SHAPES = tuple(shape(n) for n in SHAPE_GRID)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """One selectable ``--arch``: model config + training/serving policy."""
    arch_id: str
    kind: str                      # lm | encdec | population
    model: object                  # LMConfig | EncDecConfig | Population
    optimizer: str = "adamw"
    optimizer_kw: tuple = ()       # (key, value) pairs (hashability)
    lr: float = 3e-4
    grad_accum_dtype: str = "float32"   # 'bfloat16' halves accumulators
    # per-shape gradient-accumulation counts (activation-memory policy)
    num_micro: tuple = ()          # ((shape_name, n), ...)
    skip_shapes: tuple = ()        # assigned shapes this arch cannot run
    skip_reason: str = ""
    source: str = ""               # [arXiv/hf ref; verification tier]
    notes: str = ""

    def micro_for(self, shape_name: str) -> int:
        return dict(self.num_micro).get(shape_name, 1)

    def runs(self, shape_name: str) -> bool:
        return shape_name not in self.skip_shapes

    def optimizer_kwargs(self) -> dict:
        return dict(self.optimizer_kw)

    def cells(self):
        return [s for s in ALL_SHAPES if self.runs(s.name)]
