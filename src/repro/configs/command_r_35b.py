"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528.

Cohere c4ai-command-r-v01: LayerNorm (no bias), PARALLEL attn+FFN blocks
(single input norm), no biases anywhere, tied embeddings with logit_scale
0.0625, vocab 256000, rope_theta 8e6."""
from repro.configs.base import ArchSpec
from repro.models.lm import LayerSpec, LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.ffn import FFNConfig


def config() -> ArchSpec:
    model = LMConfig(
        name="command-r-35b", vocab=256_000, d_model=8192,
        layers=tuple(LayerSpec("attn", "dense", 0) for _ in range(40)),
        attn=AttnConfig(d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
                        rope_theta=8e6),
        ffn=FFNConfig(8192, 22_528, act="silu", gated=True),
        norm="layernorm", parallel_block=True, tie_embeddings=True,
        logit_scale=0.0625)
    return ArchSpec(
        arch_id="command-r-35b", kind="lm", model=model,
        optimizer="adamw", optimizer_kw=(("state_dtype", "bfloat16"),),
        lr=2.5e-4,
        num_micro=(("train_4k", 4),),
        skip_shapes=("long_500k",),
        skip_reason="full attention: 512k dense KV cache has no "
                    "sub-quadratic lowering (DESIGN.md §shape-skips)",
        source="[hf:CohereForAI/c4ai-command-r-v01; unverified]",
        notes="parallel residual block; 256k vocab shards over 'model' "
              "(16k rows/chip) for embed+logits.")


def reduced() -> ArchSpec:
    model = LMConfig(
        name="command-r-reduced", vocab=277, d_model=64,
        layers=tuple(LayerSpec("attn", "dense", 0) for _ in range(3)),
        attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16),
        ffn=FFNConfig(64, 128, act="silu", gated=True),
        norm="layernorm", parallel_block=True, tie_embeddings=True,
        logit_scale=0.0625, param_dtype="float32", remat=False)
    return ArchSpec(arch_id="command-r-35b", kind="lm", model=model,
                    optimizer="adamw", lr=1e-3)
