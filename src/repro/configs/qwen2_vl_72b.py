"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568.

arXiv:2409.12191.  Transformer BACKBONE only per assignment: the vision
frontend (dynamic-resolution ViT) is a STUB — input_specs() provides
precomputed patch embeddings (B, S, d_model).  M-RoPE with sections
(16, 24, 24) over the 64 head_dim/2 frequency bands; qkv biases (qwen2),
vocab 152064, untied."""
from repro.configs.base import ArchSpec
from repro.models.lm import LayerSpec, LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.ffn import FFNConfig


def config() -> ArchSpec:
    model = LMConfig(
        name="qwen2-vl-72b", vocab=152_064, d_model=8192,
        layers=tuple(LayerSpec("attn", "dense", 0) for _ in range(80)),
        attn=AttnConfig(d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
                        qkv_bias=True, rope_kind="mrope", rope_theta=1e6,
                        mrope_sections=(16, 24, 24)),
        ffn=FFNConfig(8192, 29_568, act="silu", gated=True),
        norm="rmsnorm", frontend="embeds")
    return ArchSpec(
        arch_id="qwen2-vl-72b", kind="lm", model=model,
        optimizer="adamw", optimizer_kw=(("state_dtype", "bfloat16"),),
        lr=2e-4,
        num_micro=(("train_4k", 8),),
        skip_shapes=("long_500k",),
        skip_reason="full attention: 512k dense KV cache has no "
                    "sub-quadratic lowering (DESIGN.md §shape-skips)",
        source="[arXiv:2409.12191; hf]",
        notes="patch-embedding frontend stub; M-RoPE streams degenerate to "
              "text positions in the stub (equality with RoPE tested).")


def reduced() -> ArchSpec:
    model = LMConfig(
        name="qwen2-vl-reduced", vocab=331, d_model=64,
        layers=tuple(LayerSpec("attn", "dense", 0) for _ in range(3)),
        attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                        qkv_bias=True, rope_kind="mrope",
                        mrope_sections=(2, 3, 3)),
        ffn=FFNConfig(64, 128, act="silu", gated=True),
        norm="rmsnorm", frontend="embeds", param_dtype="float32",
        remat=False)
    return ArchSpec(arch_id="qwen2-vl-72b", kind="lm", model=model,
                    optimizer="adamw", lr=1e-3)
