"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) vocab=32768.

8 experts top-2 (renormalised gates), d_expert=16384; SWA per assignment
(window 4096).  arXiv:2401.04088.  8 experts < 16-way mesh → 'tp' expert
sharding (expert inner dim over 'model'), the E<mesh dual of EP."""
from repro.configs.base import ArchSpec
from repro.models.lm import LayerSpec, LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.ffn import MoEConfig

SWA = 4096


def config() -> ArchSpec:
    model = LMConfig(
        name="mixtral-8x22b", vocab=32_768, d_model=6144,
        layers=tuple(LayerSpec("attn", "moe", SWA) for _ in range(56)),
        attn=AttnConfig(d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
                        sliding_window=SWA, rope_theta=1e6),
        moe=MoEConfig(d_model=6144, d_expert=16_384, num_experts=8, top_k=2,
                      num_shared=0, renorm_topk=True, capacity_factor=1.25,
                      sharding="tp"),
        norm="rmsnorm", moe_impl="shard_map")
    return ArchSpec(
        arch_id="mixtral-8x22b", kind="lm", model=model,
        optimizer="adamw", optimizer_kw=(("state_dtype", "bfloat16"),),
        lr=2e-4,
        num_micro=(("train_4k", 8),),
        source="[arXiv:2401.04088; hf]",
        notes="TP-experts (8 < mesh 16): expert d_ff over 'model'; SWA makes "
              "long_500k legal (4096-slot ring KV).")


def reduced() -> ArchSpec:
    model = LMConfig(
        name="mixtral-reduced", vocab=263, d_model=64,
        layers=tuple(LayerSpec("attn", "moe", 16) for _ in range(3)),
        attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                        sliding_window=16),
        moe=MoEConfig(d_model=64, d_expert=64, num_experts=4, top_k=2,
                      renorm_topk=True, sharding="tp"),
        norm="rmsnorm", moe_impl="dense", param_dtype="float32", remat=False)
    return ArchSpec(arch_id="mixtral-8x22b", kind="lm", model=model,
                    optimizer="adamw", lr=1e-3)
