"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA, kv=16) vocab=102400.

Fine-grained MoE: 64 routed experts top-6 + 2 shared, d_expert=1408;
layer 0 uses a dense FFN (d_ff=10944, per HF config).  arXiv:2401.06066.
EP: 64 experts shard over the 16-way 'model' axis (all-to-all dispatch) —
the paper's M3/grouped-GEMM trick is this layer's compute core."""
from repro.configs.base import ArchSpec
from repro.models.lm import LayerSpec, LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.ffn import FFNConfig, MoEConfig


def config() -> ArchSpec:
    model = LMConfig(
        name="deepseek-moe-16b", vocab=102_400, d_model=2048,
        layers=(LayerSpec("attn", "dense", 0),)
        + tuple(LayerSpec("attn", "moe", 0) for _ in range(27)),
        attn=AttnConfig(d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
                        rope_theta=1e4),
        dense_ffn0=FFNConfig(2048, 10_944, act="silu", gated=True),
        moe=MoEConfig(d_model=2048, d_expert=1408, num_experts=64, top_k=6,
                      num_shared=2, renorm_topk=False, capacity_factor=1.25,
                      aux_loss_coef=0.001, sharding="ep"),
        norm="rmsnorm", moe_impl="shard_map")
    return ArchSpec(
        arch_id="deepseek-moe-16b", kind="lm", model=model,
        optimizer="adamw", lr=4.2e-4,
        num_micro=(("train_4k", 2),),
        skip_shapes=("long_500k",),
        skip_reason="full attention: 512k dense KV cache has no "
                    "sub-quadratic lowering (DESIGN.md §shape-skips)",
        source="[arXiv:2401.06066; hf]",
        notes="EP=16 all-to-all MoE (paper's M3 row-segment dual); "
              "2 shared experts TP via shared FFN.")


def reduced() -> ArchSpec:
    model = LMConfig(
        name="deepseek-moe-reduced", vocab=269, d_model=64,
        layers=(LayerSpec("attn", "dense", 0),)
        + tuple(LayerSpec("attn", "moe", 0) for _ in range(2)),
        attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=4, d_head=16),
        dense_ffn0=FFNConfig(64, 128, act="silu", gated=True),
        moe=MoEConfig(d_model=64, d_expert=32, num_experts=8, top_k=2,
                      num_shared=2, renorm_topk=False, sharding="ep"),
        norm="rmsnorm", moe_impl="dense", param_dtype="float32", remat=False)
    return ArchSpec(arch_id="deepseek-moe-16b", kind="lm", model=model,
                    optimizer="adamw", lr=1e-3)
