"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504,
ssm_state=16.

arXiv:2411.13676: every layer runs attention heads AND Mamba heads in
PARALLEL on the same input (the paper's two-independent-subnetworks fusion —
DESIGN.md §4.3).  Window pattern per Hymba: global attention at layers
0/15/31, SWA 1024 elsewhere.  d_head=64; SSM: expand 2 → d_inner 3200,
50 SSD heads, state 16.  Meta-tokens omitted (noted in DESIGN.md).
long_500k runs: SSM state is O(1) and attention KV is ring-bounded
(global layers fall back to the 32k ring for the dry-run; see config)."""
from repro.configs.base import ArchSpec
from repro.models.lm import LayerSpec, LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.ffn import FFNConfig
from repro.nn.ssm import SSMConfig

SWA = 1024
GLOBAL_LAYERS = (0, 15, 31)


def config() -> ArchSpec:
    layers = tuple(
        LayerSpec("hybrid", "dense", 0 if i in GLOBAL_LAYERS else SWA)
        for i in range(32))
    model = LMConfig(
        name="hymba-1.5b", vocab=32_001, d_model=1600,
        layers=layers,
        attn=AttnConfig(d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
                        rope_theta=1e4),
        ssm=SSMConfig(d_model=1600, d_state=16, d_conv=4, expand=2,
                      head_dim=64, n_groups=1, chunk=256),
        ffn=FFNConfig(1600, 5504, act="silu", gated=True),
        norm="rmsnorm", tie_embeddings=True)
    return ArchSpec(
        arch_id="hymba-1.5b", kind="lm", model=model,
        optimizer="adamw", lr=5e-4,
        num_micro=(("train_4k", 2), ("long_500k", 1)),
        source="[arXiv:2411.13676; hf]",
        notes="paper's fusion inside one layer (attn ∥ SSM heads); 3 global "
              "layers dominate the long_500k cache; 25 heads do not divide "
              "the 16-way 'model' axis → attention shards on KV length "
              "instead (DESIGN.md §Arch-applicability).")


def reduced() -> ArchSpec:
    layers = tuple(LayerSpec("hybrid", "dense", 0 if i == 0 else 16)
                   for i in range(3))
    model = LMConfig(
        name="hymba-reduced", vocab=313, d_model=64,
        layers=layers,
        attn=AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16),
        ssm=SSMConfig(d_model=64, d_state=16, d_conv=4, expand=2,
                      head_dim=16, n_groups=1, chunk=16),
        ffn=FFNConfig(64, 128, act="silu", gated=True),
        norm="rmsnorm", tie_embeddings=True, param_dtype="float32",
        remat=False)
    return ArchSpec(arch_id="hymba-1.5b", kind="lm", model=model,
                    optimizer="adamw", lr=1e-3)
