"""On-device ensemble reductions over the member axis (DESIGN.md §10).

The paper's product is a trained *population*; serving it means reducing the
(B, P, O) per-member outputs of ``deep.forward(infer=True)`` on device into
one answer per request — plus an uncertainty signal that only a population
can give (the "Instant Learning: Parallel DNNs and Convolutional
Bootstrapping" framing, PAPERS.md):

  best_member       one member's probabilities (leaderboard rank-0 routing)
  soft_vote         mean of member softmaxes over a published member set
                    (optionally weighted) — the top-k / all-members ensemble
  disagreement      mixture entropy, mean member entropy, their gap (the
                    mutual information = epistemic uncertainty), and the
                    fraction of members voting with the ensemble

All reductions accept raw logits OR log-probabilities interchangeably:
``softmax`` is shift-invariant per row, so ``softmax(log_softmax(x)) ==
softmax(x)`` and the fused infer head may emit either.

Filler exclusion (the shard-pad invariant): ``LayeredPopulation.shard_pad``
appends identity filler members so the member axis divides the mesh.  Those
slots hold REAL arrays — the fused kernels compute them like any member —
but they are NOT models, and a mean/argmax that sees them is silently
wrong.  Every reduction here therefore (a) slices the member axis to
``num_real`` (fillers are guaranteed trailing) before reducing, and (b)
validates any explicit member-id set against the real range, failing
loudly rather than gathering a filler.  Regression-tested with a poisoned
padded population in tests/test_infer_path.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def real_slots(pop) -> int:
    """Number of REAL members in a (possibly shard-padded) layout."""
    return int(getattr(pop, "num_real", pop.num_members))


def _real_logits(logits: jax.Array, pop):
    """Slice the member axis to the real prefix — fillers are trailing by
    the ``shard_pad`` contract, so the slice IS the exclusion mask."""
    nr = real_slots(pop)
    if logits.shape[1] < nr:
        raise ValueError(f"member axis {logits.shape[1]} smaller than the "
                         f"layout's {nr} real members")
    return logits[:, :nr, :], nr


def _validate_slots(member_ids, num_real: int) -> np.ndarray:
    """Explicit member sets must name real members only (loud-fail side of
    the filler-exclusion invariant)."""
    ids = np.asarray(member_ids, np.int64).reshape(-1)
    if ids.size == 0:
        raise ValueError("empty ensemble member set")
    bad = ids[(ids < 0) | (ids >= num_real)]
    if bad.size:
        raise ValueError(
            f"member ids {sorted(set(bad.tolist()))} outside the real-member "
            f"range [0, {num_real}) — shard_pad identity fillers must never "
            "reach an ensemble reduction")
    return ids.astype(np.int32)


def member_log_probs(logits: jax.Array) -> jax.Array:
    """Per-member log-probabilities (idempotent on log-prob input)."""
    return jax.nn.log_softmax(logits, axis=-1)


def best_member(logits: jax.Array, pop, member_id: int) -> jax.Array:
    """(B, P, O) → one member's probabilities (B, O) — leaderboard rank-0
    routing.  ``member_id`` indexes the CURRENT layout's member axis."""
    lg, nr = _real_logits(logits, pop)
    (mid,) = _validate_slots([member_id], nr)
    return jax.nn.softmax(lg[:, int(mid), :], axis=-1)


def soft_vote(logits: jax.Array, pop, member_ids=None,
              weights=None) -> jax.Array:
    """(B, P, O) → ensemble probabilities (B, O): mean (or ``weights``-
    weighted mean, normalised here) of member softmaxes over ``member_ids``
    (default: every real member)."""
    lg, nr = _real_logits(logits, pop)
    ids = (np.arange(nr, dtype=np.int32) if member_ids is None
           else _validate_slots(member_ids, nr))
    probs = jax.nn.softmax(lg[:, ids, :], axis=-1)      # (B, K, O)
    if weights is None:
        return probs.mean(axis=1)
    w = jnp.asarray(weights, jnp.float32).reshape(-1)
    if w.shape[0] != ids.shape[0]:
        raise ValueError(f"{w.shape[0]} weights for {ids.shape[0]} members")
    return jnp.einsum("bko,k->bo", probs, w / w.sum())


def disagreement(logits: jax.Array, pop, member_ids=None) -> dict:
    """Population-disagreement uncertainty over ``member_ids`` (default all
    real members).  Returns (B,) arrays:

      mixture_entropy      H(mean member distribution) — total uncertainty
      mean_member_entropy  E_m H(member m) — aleatoric part
      mutual_information   their gap — epistemic part, ~0 when members agree
      vote_agreement       fraction of members whose argmax matches the
                           ensemble's
    """
    lg, nr = _real_logits(logits, pop)
    ids = (np.arange(nr, dtype=np.int32) if member_ids is None
           else _validate_slots(member_ids, nr))
    logp = jax.nn.log_softmax(lg[:, ids, :], axis=-1)   # (B, K, O)
    p = jnp.exp(logp)
    mix = p.mean(axis=1)                                # (B, O)
    mixture_entropy = -jnp.sum(
        mix * jnp.log(jnp.clip(mix, 1e-20, None)), axis=-1)
    mean_member_entropy = -jnp.sum(p * logp, axis=-1).mean(axis=1)
    pred = jnp.argmax(mix, axis=-1)
    votes = jnp.argmax(logp, axis=-1)                   # (B, K)
    return {
        "mixture_entropy": mixture_entropy,
        "mean_member_entropy": mean_member_entropy,
        "mutual_information": mixture_entropy - mean_member_entropy,
        "vote_agreement": (votes == pred[:, None]).mean(axis=1),
    }


ENSEMBLE_MODES = ("best1", "topk", "all")


def ensemble_predict(logits: jax.Array, pop, mode: str = "all",
                     member_ids=None, weights=None,
                     with_uncertainty: bool = False) -> dict:
    """One dispatcher for the three serving reductions.

    ``mode="best1"`` routes to ``member_ids[0]`` (leaderboard rank 0);
    ``"topk"`` soft-votes over the published ``member_ids``; ``"all"``
    soft-votes over every real member.  Returns ``{"probs": (B, O),
    "pred": (B,)}`` plus the ``disagreement`` arrays (computed over the
    same member set) when ``with_uncertainty`` is set."""
    if mode not in ENSEMBLE_MODES:
        raise ValueError(f"unknown ensemble mode {mode!r} "
                         f"(have {ENSEMBLE_MODES})")
    if mode == "best1":
        if member_ids is None:
            raise ValueError("mode='best1' needs member_ids (leaderboard)")
        mid = int(np.asarray(member_ids).reshape(-1)[0])
        probs = best_member(logits, pop, mid)
        ids = [mid]
    elif mode == "topk":
        if member_ids is None:
            raise ValueError("mode='topk' needs member_ids (leaderboard)")
        probs = soft_vote(logits, pop, member_ids, weights)
        ids = member_ids
    else:
        probs = soft_vote(logits, pop, None, weights)
        ids = None
    out = {"probs": probs, "pred": jnp.argmax(probs, axis=-1)}
    if with_uncertainty:
        out.update(disagreement(logits, pop, ids))
    return out
