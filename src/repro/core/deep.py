"""Layered ParallelMLPs — the paper's §7/Figure 3 headline extension, as the
repo's ONE population engine.

The paper trains populations with ONE hidden layer because only the first
projection (input→hidden) is trivially fusable: every later projection must
not reduce across members.  Figure 3 sketches the fix; this module builds it
on top of the layered layout (``repro.core.population.LayeredPopulation``):

  * layer 0:            ordinary fused matmul  (H1_tot × F)       — as paper
  * layers 1..L-1:      BLOCK-DIAGONAL segment matmul: member m's units in
                        layer l+1 contract ONLY member m's units in layer l.
                        Two registered implementations (``BD_IMPLS``):
                          einsum — per-bucket batched einsum
                                   (B, n, h_in) × (n, h_out, h_in) → (B, n, h_out)
                          pallas — ONE dense segment-blocked matmul
                                   (kernels/block_diag.py, custom VJP), the
                                   moe_gemm weight-tile-selection trick with
                                   member-id = "expert"-id (DESIGN.md §3)
  * output layer:       the paper's M3 (repro.core.m3).

Members may have DIFFERENT depths: a shallow member's final activations ride
through later layers as exact identity pass-throughs (no weight, no bias, no
activation), so mixed-depth fused training still equals standalone training —
verified in tests/test_layered.py.  Per-member learning rates are free under
this layout (every parameter belongs to exactly one member): pass a (P,)
vector to ``sgd_step``/``opt_step`` or build an optimizer scale tree with
``member_lr_tree`` — and the same expansion carries ANY per-member
hyperparameter (momentum, weight decay) into the stateful optimizers, so a
population races heterogeneous training recipes, not just architectures
(``opt_step`` / ``make_population_train_step(optimizer=...)``, DESIGN.md §8).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.activations import ACTIVATIONS, apply_activations_sliced
from repro.core.m3 import m3 as _m3_apply
from repro.core.population import LayeredPopulation, Population

# The unified engine: DeepPopulation (uniform depth, one activation per
# member) is just the degenerate LayeredPopulation.
DeepPopulation = LayeredPopulation


# ---------------------------------------------------------------------- #
# block-diagonal mid-layer projection (registry, like m3.M3_IMPLS)       #
# ---------------------------------------------------------------------- #

def block_diag_einsum(h: jax.Array, w_buckets, lp: LayeredPopulation,
                      l: int) -> jax.Array:
    """h (B, H_l_tot) → (B, H_{l+1}_tot) as a loop of per-bucket batched
    einsums; pass-through buckets are slice copies.  Accumulates in f32
    whatever the operand dtype (the bf16 mixed-precision policy) and
    returns the operand dtype."""
    b = h.shape[0]
    outs = []
    wi = 0
    for (m0, n, hin, hout, off_in, off_out, real) in lp.proj_buckets(l):
        if real:
            hh = h[:, off_in: off_in + n * hin].reshape(b, n, hin)
            outs.append(jnp.einsum("bnh,noh->bno", hh, w_buckets[wi],
                                   preferred_element_type=jnp.float32)
                        .astype(h.dtype).reshape(b, n * hout))
            wi += 1
        else:
            outs.append(h[:, off_in: off_in + n * hin])
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)


def pack_weight_tiles(w_buckets, lp: LayeredPopulation, l: int) -> jax.Array:
    """Per-bucket (n, hout, hin) arrays → the flat (n_param_blocks, blk, blk)
    tile array consumed by the Pallas kernel (member-major, row-major over
    each member's tile grid — matching ``LayeredPopulation.bd_layout``).
    Pure reshapes/transposes, so gradients flow back to the bucket arrays."""
    blk = lp.block
    tiles = []
    wi = 0
    for (m0, n, hin, hout, off_in, off_out, real) in lp.proj_buckets(l):
        if not real:
            continue
        w = w_buckets[wi]
        wi += 1
        ob, ib = hout // blk, hin // blk
        tiles.append(w.reshape(n, ob, blk, ib, blk)
                     .transpose(0, 1, 3, 2, 4)
                     .reshape(n * ob * ib, blk, blk))
    return jnp.concatenate(tiles, axis=0)


def block_diag_pallas(h: jax.Array, w_buckets, lp: LayeredPopulation, l: int,
                      *, interpret: bool | None = None,
                      block_b: int = 128) -> jax.Array:
    from repro.kernels.ops import block_diag_gemm  # lazy: kernels import pallas
    wb = pack_weight_tiles(w_buckets, lp, l)
    return block_diag_gemm(h, wb.astype(h.dtype), lp.bd_layout(l),
                           block_b=block_b, interpret=interpret)


def block_diag_fused(h: jax.Array, w_buckets, lp: LayeredPopulation, l: int,
                     *, bias: jax.Array, interpret: bool | None = None,
                     block_b: int = 128) -> jax.Array:
    """FUSED mid layer: projection + pass-through-gated bias + per-segment
    activation + padding mask in one Pallas pass (kernels/fused_layer.py,
    DESIGN.md §7) — returns layer l+1's ACTIVATIONS, so callers skip the
    separate bias add and ``_act``.  The bias stays f32 (added to the f32
    accumulator in the epilogue); operand tiles follow ``h``'s dtype."""
    from repro.kernels.ops import fused_layer  # lazy: kernels import pallas
    wb = pack_weight_tiles(w_buckets, lp, l)
    pout = lp.layer_pop(l + 1)
    b_eff = (bias.astype(jnp.float32)
             * jnp.asarray(lp.active_unit_mask(l + 1), jnp.float32))
    return fused_layer(h, wb.astype(h.dtype), b_eff, lp.bd_layout(l),
                       pout.block_act_ids, pout.hidden_mask,
                       block_b=block_b, interpret=interpret)


def block_diag_fused_infer(h: jax.Array, w_buckets, lp: LayeredPopulation,
                           l: int, *, bias: jax.Array,
                           interpret: bool | None = None,
                           block_b: int | None = None) -> jax.Array:
    """Forward-only ``block_diag_fused``: same epilogue fusion, but through
    ``ops.fused_layer_infer`` — no custom_vjp, ``with_deriv=False``, and the
    bigger inference batch tile (DESIGN.md §10)."""
    from repro.kernels.ops import INFER_BLOCK_B, fused_layer_infer  # lazy
    wb = pack_weight_tiles(w_buckets, lp, l)
    pout = lp.layer_pop(l + 1)
    b_eff = (bias.astype(jnp.float32)
             * jnp.asarray(lp.active_unit_mask(l + 1), jnp.float32))
    return fused_layer_infer(
        h, wb.astype(h.dtype), b_eff, lp.bd_layout(l),
        pout.block_act_ids, pout.hidden_mask,
        block_b=INFER_BLOCK_B if block_b is None else block_b,
        interpret=interpret)


def block_diag_fused_infer_int8(h: jax.Array, qlayer: dict,
                                lp: LayeredPopulation, l: int, *,
                                interpret: bool | None = None,
                                block_b: int | None = None) -> jax.Array:
    """``block_diag_fused_infer`` over the int8 serve copy (DESIGN.md §12).
    ``qlayer`` is one ``quantize_population`` mid entry — the PRE-PACKED,
    identity-augmented int8 tile array, its per-member-per-tile f32 scales,
    and the f32 bias — so unlike the f32/bf16 path there is no per-call
    ``pack_weight_tiles``/augment: weight bytes go straight from the int8
    store into the kernel, which dequantizes inside the tile loop."""
    from repro.kernels.ops import INFER_BLOCK_B, fused_layer_infer_int8
    pout = lp.layer_pop(l + 1)
    b_eff = (qlayer["b"].astype(jnp.float32)
             * jnp.asarray(lp.active_unit_mask(l + 1), jnp.float32))
    return fused_layer_infer_int8(
        h, qlayer["wb"], qlayer["scale"], b_eff, lp.bd_layout(l),
        pout.block_act_ids, pout.hidden_mask,
        block_b=INFER_BLOCK_B if block_b is None else block_b,
        interpret=interpret)


BD_IMPLS = {
    "einsum": block_diag_einsum,
    "pallas": block_diag_pallas,
    "fused": block_diag_fused,
}

# the ``infer=True`` registry: XLA impls are already residual-free, the
# fused impl swaps in its forward-only twin.  The ``fused_int8`` entry is
# the ``weights_dtype="int8"`` route — NOT selectable via ``bd_impl``
# (its signature consumes the quantized layer dict, not bucket arrays).
BD_INFER_IMPLS = {
    "einsum": block_diag_einsum,
    "pallas": block_diag_pallas,
    "fused": block_diag_fused_infer,
    "fused_int8": block_diag_fused_infer_int8,
}

# impls whose kernel epilogue already applies bias + activation + mask —
# ``forward`` must hand them the bias and skip its own ``_act``
FUSED_BD_IMPLS = frozenset(["fused"])


def block_diag_matmul(h: jax.Array, w_buckets, lp: LayeredPopulation, l: int,
                      impl: str = "einsum", **kw) -> jax.Array:
    """Member-block-diagonal projection of layer l → l+1.  ``impl="fused"``
    additionally needs ``bias=`` and returns the ACTIVATED layer (epilogue
    fusion), not the raw projection."""
    return BD_IMPLS[impl](h, w_buckets, lp, l, **kw)


# ---------------------------------------------------------------------- #
# input-layer projection (registry, like BD_IMPLS)                       #
# ---------------------------------------------------------------------- #

def input_xla(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
              lp: LayeredPopulation, act_impl: str = "sliced") -> jax.Array:
    """Input projection as an XLA dot (f32 accumulate) + bias + the
    per-layer ``_act`` pass — the pre-§9 path."""
    z0 = jax.lax.dot_general(x, w_in,
                             dimension_numbers=(((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return _act(lp, 0, z0 + b_in, act_impl)


def input_fused(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
                lp: LayeredPopulation, act_impl: str = "sliced", *,
                interpret: bool | None = None,
                block_b: int = 128) -> jax.Array:
    """FUSED input layer: dense GEMM + bias + per-segment activation +
    padding mask in one Pallas pass (kernels/fused_input.py, DESIGN.md §9)
    — no standalone seg_act pass, z0 never in HBM.  ``act_impl`` is
    ignored: the epilogue IS the activation."""
    from repro.kernels.ops import fused_input  # lazy: kernels import pallas
    p0 = lp.layer_pop(0)
    return fused_input(x, w_in, b_in.astype(jnp.float32), p0.block_act_ids,
                       p0.hidden_mask, block=lp.block, block_b=block_b,
                       interpret=interpret)


def input_fused_infer(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
                      lp: LayeredPopulation, act_impl: str = "sliced", *,
                      interpret: bool | None = None,
                      block_b: int | None = None) -> jax.Array:
    """Forward-only ``input_fused`` through ``ops.fused_input_infer`` — no
    custom_vjp, no g' residual, bigger inference batch tile."""
    from repro.kernels.ops import INFER_BLOCK_B, fused_input_infer  # lazy
    p0 = lp.layer_pop(0)
    return fused_input_infer(
        x, w_in, b_in.astype(jnp.float32), p0.block_act_ids, p0.hidden_mask,
        block=lp.block, block_b=INFER_BLOCK_B if block_b is None else block_b,
        interpret=interpret)


def input_fused_infer_int8(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                           b_in: jax.Array, lp: LayeredPopulation,
                           act_impl: str = "sliced", *,
                           interpret: bool | None = None,
                           block_b: int | None = None) -> jax.Array:
    """``input_fused_infer`` over the int8 serve copy: the pre-padded int8
    input weight + per-row-block scales (quantize_population), dequantized
    inside the kernel's feature loop."""
    from repro.kernels.ops import INFER_BLOCK_B, fused_input_infer_int8
    p0 = lp.layer_pop(0)
    return fused_input_infer_int8(
        x, w_q, w_scale, b_in.astype(jnp.float32), p0.block_act_ids,
        p0.hidden_mask, block=lp.block,
        block_b=INFER_BLOCK_B if block_b is None else block_b,
        interpret=interpret)


IN_IMPLS = {
    "xla": input_xla,
    "fused": input_fused,
}

# ``infer=True`` twins of IN_IMPLS (same rule as BD_INFER_IMPLS);
# ``fused_int8`` is the ``weights_dtype="int8"`` route, not an ``in_impl``
IN_INFER_IMPLS = {
    "xla": input_xla,
    "fused": input_fused_infer,
    "fused_int8": input_fused_infer_int8,
}

# input impls whose kernel epilogue already applies bias + activation + mask
FUSED_IN_IMPLS = frozenset(["fused"])


def _resolve_in_impl(in_impl, bd_impl: str) -> str:
    """``None`` follows the mid layers: a fused ``bd_impl`` gets the fused
    input kernel, anything else the XLA dot."""
    if in_impl is None:
        return "fused" if bd_impl in FUSED_BD_IMPLS else "xla"
    if in_impl not in IN_IMPLS:
        raise ValueError(f"unknown in_impl {in_impl!r} "
                         f"(have {sorted(IN_IMPLS)})")
    return in_impl


# ---------------------------------------------------------------------- #
# parameters                                                             #
# ---------------------------------------------------------------------- #

def init_params(key, lp: LayeredPopulation, dtype=jnp.float32) -> dict:
    """torch.nn.Linear-style init (U(±1/√fan_in), per-member fan-in), every
    parameter drawn from its OWN key.  Pass-through bias slices start (and
    stay — their gradient is masked) at zero."""
    n_mid = lp.depth - 1
    keys = jax.random.split(key, 2 * n_mid + 4)
    p0 = lp.layer_pop(0)
    bound = 1.0 / np.sqrt(lp.in_features)
    params = {
        "w_in": jax.random.uniform(keys[0], (p0.total_hidden, lp.in_features),
                                   dtype, -bound, bound),
        "b_in": jax.random.uniform(keys[1], (p0.total_hidden,), dtype,
                                   -bound, bound),
        "mid": [],
    }
    for l in range(n_mid):
        kw_, kb_ = keys[2 + 2 * l], keys[3 + 2 * l]
        pout = lp.layer_pop(l + 1)
        real_buckets = [bk for bk in lp.proj_buckets(l) if bk[6]]
        kl = jax.random.split(kw_, max(len(real_buckets), 1))
        wl = []
        for bi, (m0, n, hin, hout, off_in, off_out, real) in \
                enumerate(real_buckets):
            fan = np.array([lp.layer_width(m, l) for m in range(m0, m0 + n)],
                           np.float32)
            wl.append(jax.random.uniform(kl[bi], (n, hout, hin), dtype, -1, 1)
                      * jnp.asarray(1.0 / np.sqrt(fan), dtype)[:, None, None])
        fan_unit = np.repeat(
            np.array([lp.layer_width(m, l) for m in range(lp.num_members)],
                     np.float32),
            pout.padded_sizes)
        mask = lp.active_unit_mask(l + 1)
        params["mid"].append({
            "w": wl,
            "b": jax.random.uniform(kb_, (pout.total_hidden,), dtype, -1, 1)
            * jnp.asarray(mask / np.sqrt(fan_unit), dtype)})
    plast = lp.layer_pop(lp.depth - 1)
    fan_last = np.repeat(np.array([w[-1] for w in lp.widths], np.float32),
                         plast.padded_sizes)
    params["w_out"] = (jax.random.uniform(
        keys[-2], (lp.out_features, plast.total_hidden), dtype, -1, 1)
        * jnp.asarray(1.0 / np.sqrt(fan_last), dtype)[None, :])
    params["b_out"] = (jax.random.uniform(
        keys[-1], (lp.num_members, lp.out_features), dtype, -1, 1)
        * jnp.asarray(1.0 / np.sqrt(
            np.array([w[-1] for w in lp.widths], np.float32)), dtype)[:, None])
    return params


def abstract_params(lp: LayeredPopulation, dtype=jnp.float32):
    """Shape/dtype tree of ``init_params`` without allocating (checkpoint
    restore, dry-run costing)."""
    return jax.eval_shape(lambda k: init_params(k, lp, dtype),
                          jax.random.PRNGKey(0))


def _fill_layout(lp: LayeredPopulation,
                 lp_pad: LayeredPopulation) -> LayeredPopulation:
    """The filler-members-only layout of a ``lp.shard_pad(n)`` extension
    (validated: pads are trailing and the real prefix is untouched)."""
    if (lp_pad.num_real != lp.num_members
            or lp_pad.widths[:lp.num_members] != lp.widths
            or lp_pad.depth != lp.depth):
        raise ValueError("lp_pad is not a shard-padded extension of lp")
    return LayeredPopulation(
        lp.in_features, lp.out_features,
        lp_pad.widths[lp_pad.num_real:],
        lp_pad.activations[lp_pad.num_real:], block=lp.block)


def _concat_pad(params: dict, fp: dict, depth: int) -> dict:
    """Append a filler-members tree ``fp`` behind ``params`` on every
    member-major axis (the trailing-pad embedding shared by ``pad_params``
    and ``pad_state``)."""
    return {
        "w_in": jnp.concatenate([params["w_in"], fp["w_in"]], axis=0),
        "b_in": jnp.concatenate([params["b_in"], fp["b_in"]], axis=0),
        "mid": [{"w": list(params["mid"][l]["w"]) + list(fp["mid"][l]["w"]),
                 "b": jnp.concatenate([params["mid"][l]["b"],
                                       fp["mid"][l]["b"]], axis=0)}
                for l in range(depth - 1)],
        "w_out": jnp.concatenate([params["w_out"], fp["w_out"]], axis=1),
        "b_out": jnp.concatenate([params["b_out"], fp["b_out"]], axis=0),
    }


def pad_params(params, lp: LayeredPopulation, lp_pad: LayeredPopulation,
               key, dtype=jnp.float32) -> dict:
    """Embed ``params`` (initialised for ``lp``) into the shard-padded
    layout ``lp_pad = lp.shard_pad(n)``; filler-member parameters are drawn
    from ``key``.  Because fillers are TRAILING in every member-major axis
    and never share a bucket with real members (``proj_buckets`` pad flag),
    the real region of the result is BIT-IDENTICAL to ``params`` — a
    sharded run initialises exactly like the single-device run."""
    if lp_pad == lp:
        return params
    fill = _fill_layout(lp, lp_pad)
    return _concat_pad(params, init_params(key, fill, dtype), lp.depth)


def map_params_subtrees(tree, ref, fn, op: str = "map"):
    """Apply ``fn`` to every params-shaped subtree of an optimizer-state
    pytree — structure AND leaf shapes matching ``ref`` (a live or abstract
    ``init_params`` tree) — passing scalar leaves (step counts) through
    untouched.  This is THE structural rule for moving optimizer state
    through layout changes (``lifecycle.compact`` gathers survivors with
    it, ``pad_state`` re-embeds them), kept in one place so the two sides
    cannot drift.  Anything else fails loudly: factored moments (adafactor
    ``v_row``/``v_col``) are not member-major along a gatherable axis."""
    p_def = jax.tree_util.tree_structure(ref)
    p_shapes = [tuple(x.shape) for x in jax.tree.leaves(ref)]

    def params_like(node):
        try:
            return (jax.tree_util.tree_structure(node) == p_def
                    and [tuple(x.shape)
                         for x in jax.tree.leaves(node)] == p_shapes)
        except Exception:
            return False

    def walk(node, path):
        if params_like(node):
            return fn(node)
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path + (i,))
                              for i, v in enumerate(node))
        if getattr(node, "ndim", None) == 0 or np.isscalar(node):
            return node
        raise ValueError(
            f"{op}: optimizer-state leaf {'/'.join(map(str, path))} is "
            "neither a scalar nor part of a params-shaped subtree (factored "
            "moments, e.g. adafactor's v_row/v_col, are not compactable "
            "member-major)")

    return walk(tree, ())


def pad_state(opt_state, lp: LayeredPopulation,
              lp_pad: LayeredPopulation):
    """Embed a (typically just-compacted) optimizer state into the
    shard-padded layout: every params-shaped subtree (SGD ``mu``, AdamW
    ``m``/``v``) gains ZERO moments for the filler members — exactly what a
    fresh ``opt.init`` of the padded params would give them, so the real
    members' trajectory is unchanged by padding — and scalar leaves (step
    counts) pass through.  Moment dtype (e.g. the bf16 state policy) is
    preserved per subtree."""
    if lp_pad == lp:
        return opt_state
    fill_abs = abstract_params(_fill_layout(lp, lp_pad))

    def pad_sub(node):
        dtype = jax.tree.leaves(node)[0].dtype
        zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, dtype), fill_abs)
        return _concat_pad(node, zeros, lp.depth)

    return map_params_subtrees(opt_state, abstract_params(lp), pad_sub,
                               op="pad_state")


def grow_state(opt_state, lp: LayeredPopulation,
               lp_new: LayeredPopulation, positions,
               gather: str = "device"):
    """Splice an optimizer state into a GROWN layout (``lp_new ==
    lp.grow(...)``): survivors' moments ride through bit-exact via the
    same static-index splice as ``lifecycle.grow_params``, while the new
    members at ``positions`` get ZERO moments — exactly what a fresh
    ``opt.init`` gives a newborn, so an exploit clone restarts its
    moment estimates rather than inheriting a stale parent trajectory.
    Scalar leaves (step counts) pass through; moment dtype is preserved
    per subtree (factored adafactor states fail loudly, as everywhere)."""
    from repro.core.lifecycle import grow_params
    positions = tuple(int(p) for p in positions)
    fresh_abs = abstract_params(lp_new.subset(tuple(sorted(positions))))

    def grow_sub(node):
        dtype = jax.tree.leaves(node)[0].dtype
        zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, dtype), fresh_abs)
        return grow_params(lp, lp_new, node, positions, zeros, gather=gather)

    return map_params_subtrees(opt_state, abstract_params(lp), grow_sub,
                               op="grow_state")


# ---------------------------------------------------------------------- #
# forward / loss / step                                                  #
# ---------------------------------------------------------------------- #

def _act(lp: LayeredPopulation, l: int, h: jax.Array,
         act_impl: str = "sliced") -> jax.Array:
    """Per-layer activation + padding mask: ``sliced`` (one XLA pass per
    contiguous activation run), ``masked`` (branchless select oracle), or
    ``pallas`` (kernels/seg_act: one tile-wise lax.switch pass, activation
    id scalar-prefetched, mask fused — the ROADMAP follow-up)."""
    pop = lp.layer_pop(l)
    if act_impl == "sliced":
        h = apply_activations_sliced(h, pop.act_runs)
    elif act_impl == "masked":
        from repro.core.activations import apply_activations_masked
        h = apply_activations_masked(h, pop.act_ids)
    elif act_impl == "pallas":
        from repro.kernels.ops import seg_act  # lazy: kernels import pallas
        return seg_act(h, pop.block_act_ids, pop.hidden_mask,
                       block_h=lp.block)
    else:
        raise ValueError(f"unknown act_impl {act_impl!r}")
    return h * jnp.asarray(pop.hidden_mask, h.dtype)


def _resolve_compute_dtype(compute_dtype):
    """``None``/``"float32"`` → None (the pure-f32 fast path); anything else
    (``"bfloat16"``) → the numpy dtype operands are cast to.  Parameters,
    accumulators, loss and eval stay f32 regardless (DESIGN.md §7)."""
    if compute_dtype is None:
        return None
    cd = jnp.dtype(compute_dtype)
    return None if cd == jnp.dtype(jnp.float32) else cd


def _resolve_weights_dtype(weights_dtype):
    """``None``/``"float32"`` → None (weights consumed as stored);
    ``"int8"`` → the quantized serve-copy route (params must be a
    ``quant.quantize_population`` tree).  Anything else fails loudly —
    only int8 has fused-dequant serving kernels; a bf16 weight STORE is
    just ``tree_map(astype)`` on the params and needs no routing."""
    if weights_dtype is None:
        return None
    wd = jnp.dtype(weights_dtype)
    if wd == jnp.dtype(jnp.float32):
        return None
    if wd == jnp.dtype(jnp.int8):
        return wd
    raise ValueError(f"unsupported weights_dtype {weights_dtype!r} — only "
                     "'int8' has fused-dequant serving kernels "
                     "(DESIGN.md §12)")


def _hidden(params, x, lp: LayeredPopulation, bd_impl: str = "einsum",
            act_impl: str = "sliced", bd_kwargs: dict | None = None,
            compute_dtype=None, in_impl=None, infer: bool = False,
            weights_dtype=None):
    """Input layer + every mid layer → the last hidden activations
    (B, H_last_tot).  The shared trunk of ``forward`` and the fused loss
    head; ``in_impl`` routing as in ``forward``.  ``infer=True`` swaps the
    fused impls for their forward-only twins (``*_INFER_IMPLS``): no
    custom_vjp attached, no residual emitted, bigger batch tiles.
    ``weights_dtype="int8"`` (serving only) routes through the
    fused-dequant twins over a ``quantize_population`` tree."""
    cd = _resolve_compute_dtype(compute_dtype)
    cast = (lambda a: a) if cd is None else (lambda a: a.astype(cd))
    wd = _resolve_weights_dtype(weights_dtype)
    if bd_impl.endswith("_int8"):
        raise ValueError(f"bd_impl {bd_impl!r} is the weights_dtype='int8' "
                         "route — request it via weights_dtype, not bd_impl")
    if wd is not None:
        if not infer:
            raise ValueError(
                "weights_dtype='int8' is a serving-only path — the "
                "quantized copy is not differentiable; pass infer=True")
        in_impl = _resolve_in_impl(in_impl, bd_impl)
        if bd_impl not in FUSED_BD_IMPLS or in_impl not in FUSED_IN_IMPLS:
            raise ValueError(
                "weights_dtype='int8' needs the fused serving kernels "
                f"(bd_impl='fused'), got bd_impl={bd_impl!r}, "
                f"in_impl={in_impl!r}")
        h = IN_INFER_IMPLS[in_impl + "_int8"](
            cast(x), params["w_in"], params["w_in_scale"], params["b_in"],
            lp, act_impl)
        for l in range(lp.depth - 1):
            h = BD_INFER_IMPLS[bd_impl + "_int8"](
                cast(h), params["mid"][l], lp, l, **(bd_kwargs or {}))
        return h
    in_impl = _resolve_in_impl(in_impl, bd_impl)
    bd_impls = BD_INFER_IMPLS if infer else BD_IMPLS
    in_impls = IN_INFER_IMPLS if infer else IN_IMPLS
    if bd_impl not in bd_impls:
        raise ValueError(f"unknown bd_impl {bd_impl!r} "
                         f"(have {sorted(bd_impls)})")
    h = in_impls[in_impl](cast(x), cast(params["w_in"]), params["b_in"],
                          lp, act_impl)
    for l in range(lp.depth - 1):
        hb = cast(h)
        wl = [cast(w) for w in params["mid"][l]["w"]]
        if bd_impl in FUSED_BD_IMPLS:
            # bias + activation + mask live in the kernel epilogue; the
            # output is layer l+1's (operand-dtype) activations
            h = bd_impls[bd_impl](hb, wl, lp, l,
                                  bias=params["mid"][l]["b"],
                                  **(bd_kwargs or {}))
            continue
        z = bd_impls[bd_impl](hb, wl, lp, l, **(bd_kwargs or {}))
        h = z + params["mid"][l]["b"] * jnp.asarray(
            lp.active_unit_mask(l + 1), jnp.float32)
        h = _act(lp, l + 1, h, act_impl)
    return h


def forward(params, x, lp: LayeredPopulation, m3_impl: str = "bucketed",
            bd_impl: str = "einsum", act_impl: str = "sliced",
            bd_kwargs: dict | None = None, m3_kwargs: dict | None = None,
            compute_dtype=None, in_impl=None, infer: bool = False,
            head_impl=None, log_probs: bool = False, weights_dtype=None):
    """x (B, F) → logits (B, P, O) — every member an independent deep MLP.

    ``compute_dtype="bfloat16"`` applies the mixed-precision policy: matmul
    OPERANDS (activations and weights) are cast to bf16 at every projection
    boundary while accumulators run f32 (``preferred_element_type`` / f32
    VMEM scratch in the kernels), biases and the logits stay f32, and the
    f32 master parameters are untouched — gradients arrive f32.

    ``bd_impl="fused"`` routes every mid layer through the fused Pallas
    kernel (projection + bias + activation + mask in one pass, DESIGN.md
    §7).  ``in_impl`` picks the input-layer path (``IN_IMPLS``); the
    default ``None`` follows ``bd_impl`` — a fused run gets the fused
    input kernel (DESIGN.md §9) so no standalone seg_act pass survives
    anywhere in the forward.

    ``infer=True`` is the serving hot path (DESIGN.md §10): every fused
    impl is swapped for its forward-only twin (no custom_vjp, no residual
    emission, INFER_BLOCK_B batch tiles) and the output projection runs
    through ``head_impl`` (``HEAD_IMPLS``; default ``None`` follows
    ``bd_impl``) — ``"fused"`` is the one-launch infer-head kernel with the
    per-member bias (and, under ``log_probs=True``, the log-softmax) in its
    epilogue, making the whole forward exactly depth+1 launches
    (``launch_count.fused_infer_budget``).  Numerics match the training
    forward to f32 tolerance; the program is NOT differentiable.

    ``weights_dtype="int8"`` (serving only, DESIGN.md §12): ``params``
    must be a ``quant.quantize_population`` tree; every projection runs
    its fused-dequant int8 twin — int8 weight tiles + f32 scales are the
    ONLY weight bytes the program touches, at the same depth+1 launch
    budget.  Requires ``infer=True`` and the fused impls."""
    cd = _resolve_compute_dtype(compute_dtype)
    cast = (lambda a: a) if cd is None else (lambda a: a.astype(cd))
    wd = _resolve_weights_dtype(weights_dtype)
    h = _hidden(params, x, lp, bd_impl, act_impl, bd_kwargs, compute_dtype,
                in_impl, infer, weights_dtype)
    if infer:
        from repro.core.m3 import (HEAD_IMPLS, m3_infer_head,
                                   m3_infer_head_int8)
        if head_impl is None:
            head_impl = (("fused_int8" if wd is not None else "fused")
                         if bd_impl in FUSED_BD_IMPLS else "xla")
        if head_impl not in HEAD_IMPLS:
            raise ValueError(f"unknown head_impl {head_impl!r} "
                             f"(have {sorted(HEAD_IMPLS)})")
        if wd is not None and head_impl != "fused_int8":
            raise ValueError(
                f"weights_dtype='int8' serves through head_impl="
                f"'fused_int8' (the int8 head store has no f32 twin), "
                f"got {head_impl!r}")
        if head_impl == "fused_int8":
            if wd is None:
                raise ValueError("head_impl='fused_int8' needs "
                                 "weights_dtype='int8'")
            return m3_infer_head_int8(
                cast(h), params["w_out"], params["w_out_scale"],
                params["b_out"], lp.layer_pop(lp.depth - 1),
                log_probs=log_probs, **(m3_kwargs or {}))
        if head_impl == "fused":
            # bias (and optional log-softmax) live in the kernel epilogue
            return m3_infer_head(cast(h), cast(params["w_out"]),
                                 params["b_out"],
                                 lp.layer_pop(lp.depth - 1),
                                 log_probs=log_probs, **(m3_kwargs or {}))
    y = _m3_apply(cast(h), cast(params["w_out"]),
                  lp.layer_pop(lp.depth - 1), impl=m3_impl,
                  **(m3_kwargs or {}))
    y = y.astype(jnp.float32) + params["b_out"][None]
    if log_probs:
        y = jax.nn.log_softmax(y, axis=-1)
    return y


def fused_loss(params, x, targets, lp: LayeredPopulation,
               m3_impl: str = "bucketed", bd_impl: str = "einsum",
               act_impl: str = "sliced", compute_dtype=None,
               in_impl=None, loss_impl=None):
    """Summed per-member softmax cross-entropy → ``(loss, per)`` with
    ``per`` (P,) the per-member mean NLL.

    ``loss_impl`` picks the head: ``"xla"`` materialises logits via
    ``forward`` and runs log_softmax in XLA; ``"fused"`` skips ``m3``
    entirely and runs projection + softmax-XE + dlogits in one Pallas
    launch per direction (``core.m3.m3_loss_head``, DESIGN.md §9).  The
    default ``None`` follows ``bd_impl``, so a fused run's whole
    forward+backward is a fixed number of launches per layer at any batch
    size."""
    from repro.core.m3 import LOSS_IMPLS, m3_loss_head
    if loss_impl is None:
        loss_impl = "fused" if bd_impl in FUSED_BD_IMPLS else "xla"
    if loss_impl not in LOSS_IMPLS:
        raise ValueError(f"unknown loss_impl {loss_impl!r} "
                         f"(have {sorted(LOSS_IMPLS)})")
    if loss_impl == "fused":
        cd = _resolve_compute_dtype(compute_dtype)
        cast = (lambda a: a) if cd is None else (lambda a: a.astype(cd))
        h = _hidden(params, x, lp, bd_impl, act_impl, None, compute_dtype,
                    in_impl)
        per = m3_loss_head(cast(h), cast(params["w_out"]), params["b_out"],
                           targets, lp.layer_pop(lp.depth - 1))
        return per.sum(), per
    logits = forward(params, x, lp, m3_impl=m3_impl, bd_impl=bd_impl,
                     act_impl=act_impl, compute_dtype=compute_dtype,
                     in_impl=in_impl)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(
        logp, targets[:, None, None].astype(jnp.int32), axis=-1)[..., 0]
    per = nll.mean(axis=0)
    return per.sum(), per


def member_lr_tree(lp: LayeredPopulation, lr) -> dict:
    """Per-member learning rates (P,) → a scale tree matching ``init_params``
    (every parameter belongs to exactly one member, so per-member LRs are a
    broadcast, not a loop — the paper's §7 'parallelise the learning rate').
    The same expansion serves any per-member optimizer hyperparameter: the
    result is what ``sgd(momentum=...)`` / ``adamw(weight_decay=...)``
    accept as scale trees."""
    lr = jnp.asarray(lr, jnp.float32)
    p0 = lp.layer_pop(0)
    u0 = lr[jnp.asarray(p0.segment_ids)]
    tree = {"w_in": u0[:, None], "b_in": u0, "mid": []}
    for l in range(lp.depth - 1):
        pout = lp.layer_pop(l + 1)
        wl = [lr[m0:m0 + n][:, None, None]
              for (m0, n, *_rest, real) in lp.proj_buckets(l) if real]
        tree["mid"].append({
            "w": wl, "b": lr[jnp.asarray(pout.segment_ids)]})
    plast = lp.layer_pop(lp.depth - 1)
    tree["w_out"] = lr[jnp.asarray(plast.segment_ids)][None, :]
    tree["b_out"] = lr[:, None]
    return tree


def _sgd_update(params, x, targets, lr, lp: LayeredPopulation,
                m3_impl: str = "bucketed", bd_impl: str = "einsum",
                act_impl: str = "sliced", compute_dtype=None):
    """The un-jitted SGD step body (shared by ``sgd_step`` and the scanned
    ``make_population_train_step``).  ``lr`` may be a scalar or a
    per-member (P,) vector.  Under ``compute_dtype="bfloat16"`` the forward
    operands run bf16 but the loss is f32, so against f32 master params the
    gradients (and the update) stay f32 — mixed precision never touches the
    optimizer math."""
    (loss, per), grads = jax.value_and_grad(fused_loss, has_aux=True)(
        params, x, targets, lp, m3_impl, bd_impl, act_impl, compute_dtype)
    lr = jnp.asarray(lr)
    if lr.ndim == 0:
        new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    else:
        scales = member_lr_tree(lp, lr)
        new = jax.tree.map(lambda p, g, s: p - s * g, params, grads, scales)
    return new, loss, per


@partial(jax.jit, static_argnames=("lp", "m3_impl", "bd_impl", "act_impl",
                                   "compute_dtype"))
def sgd_step(params, x, targets, lr, lp: LayeredPopulation,
             m3_impl: str = "bucketed", bd_impl: str = "einsum",
             act_impl: str = "sliced", compute_dtype=None):
    """One fused SGD step.  ``lr`` may be a scalar or a per-member (P,)
    vector."""
    return _sgd_update(params, x, targets, lr, lp, m3_impl, bd_impl,
                       act_impl, compute_dtype)


def _opt_update(params, opt_state, x, targets, lr, opt,
                lp: LayeredPopulation, m3_impl: str = "bucketed",
                bd_impl: str = "einsum", act_impl: str = "sliced",
                compute_dtype=None, grad_clip=None):
    """The optimizer-generic step body (``_sgd_update``'s successor):
    fused loss + grads → optional global-norm clip → ``opt.update`` →
    ``apply_updates``, carrying the optimizer state through.

    ``opt`` is a ``repro.optim.Optimizer``; ``lr`` may be a scalar, a
    per-member (P,) vector (expanded through ``member_lr_tree`` here), or
    an already-expanded per-leaf scale tree.  With ``opt=sgd()`` (scalar
    momentum 0) the parameter update is BIT-IDENTICAL to ``_sgd_update``'s
    ``p - lr·g``: the optimizer path computes ``p + (-lr)·g``, and IEEE
    negate/multiply/subtract make the two exactly equal — regression-tested
    in tests/test_population_optim.py, which is what lets the driver run
    every optimizer through ONE engine without perturbing the plain-SGD
    baselines (BENCH_*.json, halving invariants)."""
    from repro.optim.optimizers import apply_updates, clip_by_global_norm
    (loss, per), grads = jax.value_and_grad(fused_loss, has_aux=True)(
        params, x, targets, lp, m3_impl, bd_impl, act_impl, compute_dtype)
    gnorm = None
    if grad_clip:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    if not isinstance(lr, (dict, list, tuple)):
        lr = jnp.asarray(lr)
        if lr.ndim == 1:
            lr = member_lr_tree(lp, lr)
    upd, opt_state = opt.update(grads, opt_state, params, lr)
    return apply_updates(params, upd), opt_state, loss, per, gnorm


@partial(jax.jit, static_argnames=("opt", "lp", "m3_impl", "bd_impl",
                                   "act_impl", "compute_dtype", "grad_clip"))
def opt_step(params, opt_state, x, targets, lr, opt, lp: LayeredPopulation,
             m3_impl: str = "bucketed", bd_impl: str = "einsum",
             act_impl: str = "sliced", compute_dtype=None, grad_clip=None):
    """One fused optimizer step with state (``sgd_step``'s successor) →
    ``(params, opt_state, loss, per_member_losses, grad_norm)``;
    ``grad_norm`` is None unless ``grad_clip`` is set."""
    return _opt_update(params, opt_state, x, targets, lr, opt, lp, m3_impl,
                       bd_impl, act_impl, compute_dtype, grad_clip)


def make_population_train_step(lp: LayeredPopulation, *,
                               optimizer=None,
                               grad_clip=None,
                               m3_impl: str = "bucketed",
                               bd_impl: str = "einsum",
                               act_impl: str = "sliced",
                               scan_steps: int = 1,
                               donate: bool = True,
                               donate_batch: bool = False,
                               compute_dtype=None,
                               lr_schedule=None):
    """Build the jitted multi-step population train chunk.

    Without ``optimizer`` this is the stateless plain-SGD chunk:
    ``chunk(params, xs, ys, lr) -> (params, losses, pers)``.  With an
    ``optimizer`` (a ``repro.optim.Optimizer``) the chunk carries the
    optimizer state through the scan —

      ``chunk(params, opt_state, xs, ys, lr)
          -> (params, opt_state, losses, pers, gnorms)``

    where ``gnorms`` (scan_steps,) holds each inner step's pre-clip global
    gradient norm when ``grad_clip`` is set (None otherwise).  Both params
    AND opt state are donated: at 10k members the moment trees double the
    dominant HBM resident, so reusing their buffers in place matters twice
    as much as it did for params alone.

    ``lr_schedule`` (a ``step -> multiplier`` callable, e.g.
    ``repro.optim.warmup_cosine(1.0, ...)``) threads the GLOBAL step
    through the scan as a carry: each chunk signature gains a trailing
    ``step0`` argument (the global step of the chunk's first batch —
    resume-correct because the driver passes its segment cursor) and inner
    step k trains at ``lr · lr_schedule(step0 + k)``.  ``lr`` keeps its
    scalar-or-(P,) semantics — the multiplier broadcasts, so per-member
    LRs and the schedule compose, and filler members simply ride the same
    multiplier (they are excluded from selection regardless).  With
    ``lr_schedule=None`` the signatures and the emitted program are
    EXACTLY the pre-schedule ones: the plain-SGD chunk stays bit-identical
    to the committed baselines.

    ``donate_batch`` additionally donates the ``xs``/``ys`` slabs (only
    meaningful with ``donate``): the streaming data plane
    (``data/pipeline.py``) hands each chunk a freshly ``device_put`` slab
    that nothing else references, so XLA may reuse its buffer — at
    scan_steps×B×F float32 per chunk this keeps the double-buffered
    pipeline's device footprint at exactly two slabs.

    ``xs``/``ys`` carry a leading ``scan_steps`` axis and ``losses``
    (scan_steps,) / ``pers`` (scan_steps, P) hold every inner step's
    metrics.  The inner steps run under ONE ``lax.scan``, so the chunk
    dispatches to the device once per ``scan_steps`` optimizer steps and
    state never round-trips to host between them.  Under a mesh, sharded
    inputs keep their sharding through the scan: member-major layouts are
    collective-free, so XLA propagates the population axis end to end —
    optimizer moments included (``LayeredPopulation.opt_specs``)."""
    if scan_steps < 1:
        raise ValueError(f"scan_steps must be >= 1, got {scan_steps}")

    if optimizer is None:
        if grad_clip:
            raise ValueError(
                "grad_clip runs through the optimizer engine — pass "
                "optimizer= (e.g. repro.optim.sgd()) alongside it")

        if lr_schedule is None:
            def chunk(params, xs, ys, lr):
                def body(p, batch):
                    x, y = batch
                    p, loss, per = _sgd_update(p, x, y, lr, lp, m3_impl,
                                               bd_impl, act_impl,
                                               compute_dtype)
                    return p, (loss, per)
                params, (losses, pers) = jax.lax.scan(body, params, (xs, ys))
                return params, losses, pers
        else:
            def chunk(params, xs, ys, lr, step0):
                def body(carry, batch):
                    p, g = carry
                    x, y = batch
                    lr_t = jnp.asarray(lr) * lr_schedule(g)
                    p, loss, per = _sgd_update(p, x, y, lr_t, lp, m3_impl,
                                               bd_impl, act_impl,
                                               compute_dtype)
                    return (p, g + 1), (loss, per)
                (params, _), (losses, pers) = jax.lax.scan(
                    body, (params, jnp.asarray(step0, jnp.int32)), (xs, ys))
                return params, losses, pers

        dn = ((0, 1, 2) if donate_batch else (0,)) if donate else ()
        return jax.jit(chunk, donate_argnums=dn)

    if lr_schedule is None:
        def chunk(params, opt_state, xs, ys, lr):
            def body(carry, batch):
                p, st = carry
                x, y = batch
                p, st, loss, per, gnorm = _opt_update(
                    p, st, x, y, lr, optimizer, lp, m3_impl, bd_impl,
                    act_impl, compute_dtype, grad_clip)
                return (p, st), (loss, per, gnorm)
            (params, opt_state), (losses, pers, gnorms) = jax.lax.scan(
                body, (params, opt_state), (xs, ys))
            return params, opt_state, losses, pers, gnorms
    else:
        def chunk(params, opt_state, xs, ys, lr, step0):
            def body(carry, batch):
                p, st, g = carry
                x, y = batch
                mult = lr_schedule(g)
                if isinstance(lr, (dict, list, tuple)):  # scale tree
                    lr_t = jax.tree.map(lambda s: s * mult, lr)
                else:
                    lr_t = jnp.asarray(lr) * mult
                p, st, loss, per, gnorm = _opt_update(
                    p, st, x, y, lr_t, optimizer, lp, m3_impl, bd_impl,
                    act_impl, compute_dtype, grad_clip)
                return (p, st, g + 1), (loss, per, gnorm)
            (params, opt_state, _), (losses, pers, gnorms) = jax.lax.scan(
                body, (params, opt_state, jnp.asarray(step0, jnp.int32)),
                (xs, ys))
            return params, opt_state, losses, pers, gnorms

    dn = ((0, 1, 2, 3) if donate_batch else (0, 1)) if donate else ()
    return jax.jit(chunk, donate_argnums=dn)


# ---------------------------------------------------------------------- #
# member extraction (standalone baseline)                                #
# ---------------------------------------------------------------------- #

def extract_member(params, lp: LayeredPopulation, m: int) -> dict:
    """Standalone deep MLP of member m (REAL units and layers only)."""
    d = lp.member_depths[m]
    p0 = lp.layer_pop(0)
    out = {"w_in": params["w_in"][p0.member_slice(m)],
           "b_in": params["b_in"][p0.member_slice(m)],
           "mid": [],
           "activations": lp.activations[m],
           "activation": lp.activations[m][0]}
    for l in range(d - 1):
        wi = 0
        for (m0, n, hin, hout, off_in, off_out, real) in lp.proj_buckets(l):
            if m0 <= m < m0 + n:
                assert real, f"member {m} has no real projection at layer {l}"
                wm = params["mid"][l]["w"][wi][m - m0][
                    : lp.widths[m][l + 1], : lp.widths[m][l]]
                break
            if real:
                wi += 1
        bm = params["mid"][l]["b"][lp.layer_pop(l + 1).member_slice(m)]
        out["mid"].append({"w": wm, "b": bm})
    plast = lp.layer_pop(lp.depth - 1)
    out["w_out"] = params["w_out"][:, plast.member_slice(m)]
    out["b_out"] = params["b_out"][m]
    return out


def member_forward(member: dict, x):
    """Forward of one extracted member, honouring per-layer activations."""
    acts = member.get("activations") or (member["activation"],) * (
        len(member["mid"]) + 1)
    h = ACTIVATIONS[acts[0]](x @ member["w_in"].T + member["b_in"])
    for l, lay in enumerate(member["mid"]):
        h = ACTIVATIONS[acts[l + 1]](h @ lay["w"].T + lay["b"])
    return h @ member["w_out"].T + member["b_out"]
