"""Deep ParallelMLPs — the paper's §7/Figure 3 future work, implemented.

The paper trains populations with ONE hidden layer because only the first
projection (input→hidden) is trivially fusable: every later projection must
not reduce across members.  Figure 3 sketches the fix; this module builds
it:

  * layer 0:            ordinary fused matmul  (H1_tot × F)       — as paper
  * layers 1..L-1:      BLOCK-DIAGONAL segment matmul: member m's units in
                        layer l+1 contract ONLY member m's units in layer l.
                        With members sorted into runs of equal padded widths
                        this is a per-bucket batched einsum
                        (B, n, h_in) × (n, h_out, h_in) → (B, n, h_out) —
                        dense MXU work, no scatter, gradients independent by
                        construction (same argument as M3; the Pallas analogue
                        is kernels/moe_gemm with member-id = "expert"-id).
  * output layer:       the paper's M3 (repro.core.m3).

Independence is asserted against standalone two-hidden-layer training in
tests/test_deep.py — the paper's §7 conjecture, verified.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.activations import ACTIVATIONS
from repro.core.m3 import m3 as _m3_apply
from repro.core.population import Population


@dataclasses.dataclass(frozen=True)
class DeepPopulation:
    """P members, member m having hidden widths ``widths[m]`` (one entry per
    hidden layer; all members share the same DEPTH) and one activation."""

    in_features: int
    out_features: int
    widths: tuple          # tuple[tuple[int, ...]] — per member, per layer
    activations: tuple     # per member
    block: int = 8

    def __post_init__(self):
        depths = {len(w) for w in self.widths}
        if len(depths) != 1:
            raise ValueError(f"all members need the same depth, got {depths}")
        object.__setattr__(self, "widths", tuple(tuple(w) for w in self.widths))

    @property
    def num_members(self) -> int:
        return len(self.widths)

    @property
    def depth(self) -> int:
        return len(self.widths[0])

    @dataclasses.dataclass(frozen=True)
    class _Key:
        pass

    def layer_pop(self, l: int) -> Population:
        """The fused layout of hidden layer l (member order preserved)."""
        return Population(self.in_features, self.out_features,
                          tuple(w[l] for w in self.widths),
                          self.activations, block=self.block)

    def buckets(self, l: int):
        """Contiguous runs of members with identical padded (in, out) widths
        for the l→l+1 block-diagonal projection.  Static python data."""
        pin, pout = self.layer_pop(l), self.layer_pop(l + 1)
        runs = []
        m = 0
        while m < self.num_members:
            n = 1
            key = (pin.padded_sizes[m], pout.padded_sizes[m])
            while m + n < self.num_members and \
                    (pin.padded_sizes[m + n], pout.padded_sizes[m + n]) == key:
                n += 1
            runs.append((m, n, int(key[0]), int(key[1]),
                         int(pin.offsets[m]), int(pout.offsets[m])))
            m += n
        return runs


def init_params(key, dp: DeepPopulation, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, dp.depth + 2)
    p0 = dp.layer_pop(0)
    bound = 1.0 / np.sqrt(dp.in_features)
    params = {
        "w_in": jax.random.uniform(keys[0], (p0.total_hidden, dp.in_features),
                                   dtype, -bound, bound),
        "b_in": jax.random.uniform(keys[0], (p0.total_hidden,), dtype,
                                   -bound, bound),
        "mid": [],
    }
    for l in range(dp.depth - 1):
        pin, pout = dp.layer_pop(l), dp.layer_pop(l + 1)
        wl = []
        fan_in = np.repeat(np.array([w[l] for w in dp.widths], np.float32),
                           pout.padded_sizes)
        kl = jax.random.split(keys[1 + l], len(dp.buckets(l)))
        for bi, (m0, n, hin, hout, off_in, off_out) in enumerate(dp.buckets(l)):
            b = 1.0 / np.sqrt(max(min(w[l] for w in dp.widths[m0:m0 + n]), 1))
            wl.append(jax.random.uniform(kl[bi], (n, hout, hin), dtype, -1, 1)
                      * jnp.asarray(
                          1.0 / np.sqrt(np.maximum(
                              [w[l] for w in dp.widths[m0:m0 + n]], 1)),
                          dtype)[:, None, None])
        pl = dp.layer_pop(l + 1)
        params["mid"].append({
            "w": wl,
            "b": jax.random.uniform(keys[1 + l], (pl.total_hidden,), dtype,
                                    -1, 1) * jnp.asarray(
                1.0 / np.sqrt(fan_in), dtype)})
    plast = dp.layer_pop(dp.depth - 1)
    fan_last = np.repeat(np.array([w[-1] for w in dp.widths], np.float32),
                         plast.padded_sizes)
    params["w_out"] = (jax.random.uniform(
        keys[-1], (dp.out_features, plast.total_hidden), dtype, -1, 1)
        * jnp.asarray(1.0 / np.sqrt(fan_last), dtype)[None, :])
    params["b_out"] = (jax.random.uniform(
        keys[-1], (dp.num_members, dp.out_features), dtype, -1, 1)
        * jnp.asarray(1.0 / np.sqrt(
            np.array([w[-1] for w in dp.widths], np.float32)), dtype)[:, None])
    return params


def block_diag_matmul(h, w_buckets, dp: DeepPopulation, l: int):
    """h (B, H_l_tot) → (B, H_{l+1}_tot): member-block-diagonal projection."""
    b = h.shape[0]
    outs = []
    for (m0, n, hin, hout, off_in, off_out), w in zip(dp.buckets(l),
                                                      w_buckets):
        hh = h[:, off_in: off_in + n * hin].reshape(b, n, hin)
        outs.append(jnp.einsum("bnh,noh->bno", hh, w).reshape(b, n * hout))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)


def _act(dp: DeepPopulation, pop: Population, h):
    from repro.core.activations import apply_activations_sliced
    h = apply_activations_sliced(h, pop.act_runs)
    return h * jnp.asarray(pop.hidden_mask, h.dtype)


def forward(params, x, dp: DeepPopulation, m3_impl: str = "bucketed"):
    """x (B, F) → logits (B, P, O) — every member an independent deep MLP."""
    h = _act(dp, dp.layer_pop(0), x @ params["w_in"].T + params["b_in"])
    for l in range(dp.depth - 1):
        h = block_diag_matmul(h, params["mid"][l]["w"], dp, l)
        h = _act(dp, dp.layer_pop(l + 1), h + params["mid"][l]["b"])
    y = _m3_apply(h, params["w_out"], dp.layer_pop(dp.depth - 1), impl=m3_impl)
    return y + params["b_out"][None]


def fused_loss(params, x, targets, dp: DeepPopulation):
    logits = forward(params, x, dp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(
        logp, targets[:, None, None].astype(jnp.int32), axis=-1)[..., 0]
    per = nll.mean(axis=0)
    return per.sum(), per


@partial(jax.jit, static_argnames=("dp",))
def sgd_step(params, x, targets, lr, dp: DeepPopulation):
    (loss, per), grads = jax.value_and_grad(fused_loss, has_aux=True)(
        params, x, targets, dp)
    new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return new, loss, per


def extract_member(params, dp: DeepPopulation, m: int) -> dict:
    """Standalone deep MLP of member m (REAL units only)."""
    p0 = dp.layer_pop(0)
    sl = p0.member_slice(m)
    out = {"w_in": params["w_in"][sl], "b_in": params["b_in"][sl],
           "mid": [], "activation": dp.activations[m]}
    for l in range(dp.depth - 1):
        pin, pout = dp.layer_pop(l), dp.layer_pop(l + 1)
        for (m0, n, hin, hout, off_in, off_out), w in zip(dp.buckets(l),
                                                          params["mid"][l]["w"]):
            if m0 <= m < m0 + n:
                wm = w[m - m0][: dp.widths[m][l + 1], : dp.widths[m][l]]
                break
        bm = params["mid"][l]["b"][pout.member_slice(m)]
        out["mid"].append({"w": wm, "b": bm})
    plast = dp.layer_pop(dp.depth - 1)
    out["w_out"] = params["w_out"][:, plast.member_slice(m)]
    out["b_out"] = params["b_out"][m]
    return out


def member_forward(member: dict, x):
    act = ACTIVATIONS[member["activation"]]
    h = act(x @ member["w_in"].T + member["b_in"])
    for lay in member["mid"]:
        h = act(h @ lay["w"].T + lay["b"])
    return h @ member["w_out"].T + member["b_out"]
