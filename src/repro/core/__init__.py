"""Core: the paper's ParallelMLPs — fused population training via M3 —
plus the paper's §7 future work as first-class citizens: layered (deep,
heterogeneous-depth) populations, feature selection, per-member learning
rates."""
from repro.core.activations import ACTIVATIONS, ACTIVATION_ORDER, PAPER_TEN
from repro.core.lifecycle import HalvingSchedule, compact, survivors
from repro.core.m3 import M3_IMPLS, m3, m3_bucketed, m3_onehot, m3_pallas, m3_scatter
from repro.core.parallel_mlp import (extract_member, forward, fused_loss, init_params,
                                     member_forward, member_losses, sgd_step)
from repro.core.population import LayeredPopulation, Population

__all__ = [
    "ACTIVATIONS", "ACTIVATION_ORDER", "PAPER_TEN", "M3_IMPLS", "m3",
    "m3_scatter", "m3_onehot", "m3_bucketed", "m3_pallas", "Population",
    "LayeredPopulation", "HalvingSchedule", "compact", "survivors",
    "init_params", "forward", "fused_loss", "member_losses", "sgd_step",
    "extract_member", "member_forward",
]
