"""Successive-halving population lifecycle: train → eval → prune → compact.

The paper trains its 10,000-member population to the full horizon and only
THEN selects; every member that is out of contention after a few hundred
steps still burns full FLOPs to the end.  A successive-halving schedule
(Jamieson & Talwalkar's rungs, applied to the fused layout) turns that
selection pressure into a direct speedup: at each rung boundary the
population is evaluated, the worst members are dropped, and the survivors
are COMPACTED into a freshly built, re-bucketed ``LayeredPopulation`` whose
fused hidden axis is physically smaller — the next rung's train step is
re-jitted against the shrunken layout, so member count and fused width
shrink ON DEVICE across rungs (DESIGN.md §6).

Two invariants make the lifecycle safe:

  * Compaction is a pure GATHER.  Members are independent by construction,
    so removing losers cannot change a survivor's computation: a survivor's
    post-compaction trajectory equals its no-pruning trajectory to float
    tolerance (tests/test_lifecycle.py).  ``compact`` copies each
    survivor's padded parameter slices bit-exactly — including per-member
    optimizer moments (SGD ``mu``, AdamW ``m``/``v``, bf16 or f32), which
    ride along through the same index maps; since the driver grew the
    stateful-optimizer engine (``run_population --optimizer``, DESIGN.md
    §8) this moment path runs in production at every rung, with
    ``deep.pad_state`` as its repack counterpart (zero filler moments).
  * Identity is preserved by bookkeeping, not layout.  Compaction renumbers
    members densely; the caller carries a survivor→original ``member_ids``
    vector (checkpointed in the lifecycle meta) so leaderboards and resumes
    always speak in ORIGINAL member ids.

Gathers run ON DEVICE by default (one jitted program of static-index
gathers — a 10k-member prune never round-trips the parameter tree through
host memory); ``gather="host"`` keeps the original ``device_get`` → numpy
fallback, bit-identical by construction.  Rung boundaries sit outside the
donated ``lax.scan`` chunk either way, and the caller ``device_put``s the
compacted tree born-sharded onto the new layout's specs (launch/train.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np

from repro.core.population import LayeredPopulation


def _host(x):
    return np.asarray(jax.device_get(x))


# ---------------------------------------------------------------------- #
# schedule                                                               #
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class HalvingSchedule:
    """Rungs of ``(end_step, keep_frac)``: after global step ``end_step``
    completes, keep the best ``keep_frac`` of the surviving members.

    ``"500:0.5,1000:0.5,2000:0.25"`` prunes to 50% at step 500, 50% of the
    survivors at 1000, and 25% of those at 2000.  Rungs at or beyond the
    run's total step count never fire (a short run is a prefix of the
    ladder — that is what makes mid-ladder checkpoints resumable with the
    SAME schedule string)."""

    rungs: tuple  # ((end_step, keep_frac), ...)

    def __post_init__(self):
        rungs = tuple((int(s), float(f)) for s, f in self.rungs)
        if not rungs:
            raise ValueError("halving schedule needs at least one rung")
        prev = 0
        for s, f in rungs:
            if s <= prev:
                raise ValueError(
                    f"rung steps must be strictly increasing and > 0, got "
                    f"{[r[0] for r in rungs]}")
            if not 0.0 < f <= 1.0:
                raise ValueError(f"keep_frac must be in (0, 1], got {f}")
            prev = s
        object.__setattr__(self, "rungs", rungs)

    @staticmethod
    def parse(spec: str) -> "HalvingSchedule":
        """``"500:0.5,1000:0.5,2000:0.25"`` → HalvingSchedule."""
        rungs = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            try:
                s, f = part.split(":")
                rungs.append((int(s), float(f)))
            except ValueError as e:
                raise ValueError(
                    f"bad halving rung {part!r} (want STEP:KEEP_FRAC, e.g. "
                    "'500:0.5,1000:0.25')") from e
        return HalvingSchedule(tuple(rungs))

    def segments(self, total_steps: int) -> tuple:
        """The run [0, total_steps) as ``(end_step, keep_frac|None)``
        training segments: one per rung boundary that falls INSIDE the run,
        plus the final un-pruned stretch.  Segment i trains global steps
        [prev_end, end) and then prunes iff keep_frac is not None."""
        if total_steps < 1:
            raise ValueError(f"total_steps must be >= 1, got {total_steps}")
        segs = [(s, f) for s, f in self.rungs if s < total_steps]
        segs.append((total_steps, None))
        return tuple(segs)

    @staticmethod
    def n_keep(n: int, keep_frac: float) -> int:
        """Survivor count for a rung: floor(n·frac), never below 1."""
        return max(1, int(n * keep_frac))


def survivors(losses, keep_frac: float) -> np.ndarray:
    """Indices of the best ``n_keep`` members by eval loss, SORTED ascending
    (compaction must preserve relative member order).  Ties break toward
    the lower index (stable argsort), so the selection is deterministic."""
    losses = np.asarray(losses)
    k = HalvingSchedule.n_keep(losses.shape[0], keep_frac)
    return np.sort(np.argsort(losses, kind="stable")[:k])


# ---------------------------------------------------------------------- #
# compaction                                                             #
# ---------------------------------------------------------------------- #

def _fused_keep_rows(pop_l, keep) -> np.ndarray:
    """Fused-axis indices of the survivors' PADDED slices in layer ``l``'s
    layout.  Padded (not just real) units are gathered so the compacted
    arrays are bit-identical to what a fresh layout of the survivors would
    address — block and per-member padded sizes are unchanged by subset."""
    off, pad = pop_l.offsets, pop_l.padded_sizes
    return np.concatenate(
        [np.arange(off[m], off[m] + pad[m]) for m in keep])


def _real_bucket_pos(lp: LayeredPopulation, l: int) -> dict:
    """member → (real-bucket index, position inside the bucket) for
    projection ``l`` — the inverse of the bucket packing that
    ``init_params`` used to build ``params['mid'][l]['w']``."""
    pos = {}
    wi = 0
    for (m0, n, hin, hout, off_in, off_out, real) in lp.proj_buckets(l):
        if not real:
            continue
        for i in range(n):
            pos[m0 + i] = (wi, i)
        wi += 1
    return pos


def _compact_tree(lp: LayeredPopulation, new_lp: LayeredPopulation,
                  params, keep, xp, fetch) -> dict:
    """The gather itself, over ``xp`` ∈ {numpy, jax.numpy}: every leaf is
    indexed member-major with STATIC index arrays, so the survivor slices
    come out bit-exact on either backend.  ``fetch`` materialises a leaf
    (cached ``device_get`` on the host path, identity under jit)."""
    rows0 = _fused_keep_rows(lp.layer_pop(0), keep)
    out = {"w_in": fetch(params["w_in"])[rows0],
           "b_in": fetch(params["b_in"])[rows0],
           "mid": []}
    for l in range(new_lp.depth - 1):
        pos = _real_bucket_pos(lp, l)
        old_w = params["mid"][l]["w"]
        wl = []
        for (m0, n, hin, hout, off_in, off_out, real) in \
                new_lp.proj_buckets(l):
            if not real:
                continue
            where = [pos[keep[m]] for m in range(m0, m0 + n)]
            parts, s = [], 0
            while s < n:      # maximal contiguous runs from one old bucket
                wi, i0 = where[s]
                e = s + 1
                while e < n and where[e] == (wi, i0 + (e - s)):
                    e += 1
                parts.append(fetch(old_w[wi])[i0: i0 + (e - s)])
                s = e
            wl.append(parts[0] if len(parts) == 1
                      else xp.concatenate(parts, axis=0))
        rows = _fused_keep_rows(lp.layer_pop(l + 1), keep)
        out["mid"].append({"w": wl,
                           "b": fetch(params["mid"][l]["b"])[rows]})
    rows_last = _fused_keep_rows(lp.layer_pop(lp.depth - 1), keep)
    out["w_out"] = fetch(params["w_out"])[:, rows_last]
    out["b_out"] = fetch(params["b_out"])[np.asarray(keep)]
    return out


@functools.lru_cache(maxsize=32)
def _device_gather_fn(lp, new_lp, keep):
    """Cached jitted gather per (layouts, keep): repeated compactions of
    the same prune (params, then each optimizer-moment subtree, and
    warm-then-time bench loops) reuse one compiled program."""
    import jax.numpy as jnp
    return jax.jit(lambda p: _compact_tree(lp, new_lp, p, list(keep), jnp,
                                           lambda a: a))


def compact_params(lp: LayeredPopulation, new_lp: LayeredPopulation,
                   params, keep, gather: str = "host") -> dict:
    """Gather one ``deep.init_params``-shaped tree down to the survivors.

    Works on parameters AND on any structurally identical tree (optimizer
    moments, gradients): every leaf is indexed member-major, so the
    survivor slices come out bit-exact.  Mid-layer bucket weights are
    re-grouped into ``new_lp``'s buckets — runs that were split by a pruned
    member merge, later layers that only pruned members reached are
    dropped (survivors were identity pass-throughs there).

    ``gather="device"`` runs the whole gather as ONE jitted program of
    static-index ``jnp.take``-style gathers — at 10k members the prune
    never round-trips the parameter tree through host memory (the ROADMAP
    PR-3 follow-up); ``gather="host"`` is the ``device_get`` → numpy
    fallback.  Both produce bit-identical trees (tests/test_lifecycle.py).
    """
    keep = [int(m) for m in keep]
    if gather == "device":
        return _device_gather_fn(lp, new_lp, tuple(keep))(params)
    if gather != "host":
        raise ValueError(f"gather must be 'device' or 'host', got {gather!r}")
    cache = {}

    def fetch(a):
        if id(a) not in cache:
            cache[id(a)] = _host(a)
        return cache[id(a)]

    return _compact_tree(lp, new_lp, params, keep, np, fetch)


def compact(pop: LayeredPopulation, params, opt_state, keep,
            gather: str = "device"):
    """Prune the fused population down to ``keep`` (strictly increasing
    REAL member indices) → ``(new_pop, new_params, new_opt_state)``.

    ``gather`` selects where the index maps run: ``"device"`` (default) is
    one jitted static-index gather program — no host round-trip, the
    compacted tree stays on device for the caller's born-sharded
    ``device_put``; ``"host"`` is the original ``device_get`` → numpy
    fallback (bit-identical results).

    ``new_pop`` is a freshly built, re-bucketed layout of the survivors
    (``LayeredPopulation.subset``): offsets, size/pair buckets, and kernel
    metadata are recomputed, so the fused hidden width physically shrinks.
    ``params`` (a ``deep.init_params`` tree) is gathered bit-exactly;
    ``opt_state`` may be ``None`` or any pytree whose params-shaped
    subtrees (SGD momentum ``mu``, Adam ``m``/``v``) are compacted through
    the same index maps — scalar leaves (step counts) pass through.
    Factored states (adafactor ``v_row``/``v_col``) are rejected: their
    leaves are not member-major along a gatherable axis — use
    :func:`compact_factored` for those.

    The caller owns re-padding (``new_pop.shard_pad``), re-deriving
    per-member learning rates (index the original vector by the survivor
    mapping), and device_put-ing the result born-sharded."""
    if not isinstance(pop, LayeredPopulation):
        raise TypeError(
            f"compact expects a LayeredPopulation, got {type(pop).__name__} "
            "(lift single-layer layouts with Population.layered() first)")
    new_pop = pop.subset(keep)
    new_params = compact_params(pop, new_pop, params, keep, gather=gather)
    if opt_state is None:
        return new_pop, new_params, None
    # the params-shaped-subtree rule lives in ONE place (deep.py) so the
    # gather side here and the pad_state repack side cannot drift
    from repro.core.deep import map_params_subtrees
    return new_pop, new_params, map_params_subtrees(
        opt_state, params,
        lambda node: compact_params(pop, new_pop, node, keep, gather=gather),
        op="compact")


def _is_factored_leaf(x) -> bool:
    """An adafactor per-param state dict: {"v"|"v_row"+"v_col"[, "m"]}."""
    return isinstance(x, dict) and ("v" in x or "v_row" in x)


def compact_factored(pop: LayeredPopulation, params, opt_state, keep,
                     gather: str = "device"):
    """Adafactor-aware rung compaction → ``(new_pop, new_params, carry)``.

    ``opt_state`` must be an adafactor state (``{"count", "leaves"}`` with
    per-param dicts holding ``v``/``v_row``+``v_col`` and optionally
    ``m``).  The factored second-moment statistics reduce over the fused
    hidden axis — ``v_row``/``v_col`` of a fused weight MIX members, so no
    member-major gather can recover a survivor's statistics — and are
    therefore DROPPED.  What survives the rung rides in ``carry``:

      * ``carry["m"]`` — the params-shaped momentum tree, gathered through
        the same index maps as the parameters (bit-exact, dtype preserved;
        ``None`` when the optimizer runs without momentum);
      * ``carry["count"]`` — the step count, passed through.

    The caller re-initialises fresh (zero) factored statistics on the new
    layout and merges the carry back in (launch/train.py): the second
    moment then re-warms in ~1/(1−b2) steps — the documented cost of
    riding adafactor through a halving ladder."""
    if not (isinstance(opt_state, dict) and "leaves" in opt_state):
        raise ValueError(
            "compact_factored expects an adafactor state "
            "({'count', 'leaves'}); use compact() for params-shaped states")
    new_pop = pop.subset(keep)
    new_params = compact_params(pop, new_pop, params, keep, gather=gather)
    leaves = opt_state["leaves"]
    flat = jax.tree.leaves(leaves, is_leaf=_is_factored_leaf)
    m = None
    if flat and all("m" in st for st in flat):
        m_tree = jax.tree.map(lambda st: st["m"], leaves,
                              is_leaf=_is_factored_leaf)
        m = compact_params(pop, new_pop, m_tree, keep, gather=gather)
    return new_pop, new_params, {"count": opt_state["count"], "m": m}
