"""Successive-halving population lifecycle: train → eval → prune → compact.

The paper trains its 10,000-member population to the full horizon and only
THEN selects; every member that is out of contention after a few hundred
steps still burns full FLOPs to the end.  A successive-halving schedule
(Jamieson & Talwalkar's rungs, applied to the fused layout) turns that
selection pressure into a direct speedup: at each rung boundary the
population is evaluated, the worst members are dropped, and the survivors
are COMPACTED into a freshly built, re-bucketed ``LayeredPopulation`` whose
fused hidden axis is physically smaller — the next rung's train step is
re-jitted against the shrunken layout, so member count and fused width
shrink ON DEVICE across rungs (DESIGN.md §6).

Two invariants make the lifecycle safe:

  * Compaction is a pure GATHER.  Members are independent by construction,
    so removing losers cannot change a survivor's computation: a survivor's
    post-compaction trajectory equals its no-pruning trajectory to float
    tolerance (tests/test_lifecycle.py).  ``compact`` copies each
    survivor's padded parameter slices bit-exactly — including per-member
    optimizer moments (SGD ``mu``, AdamW ``m``/``v``, bf16 or f32), which
    ride along through the same index maps; since the driver grew the
    stateful-optimizer engine (``run_population --optimizer``, DESIGN.md
    §8) this moment path runs in production at every rung, with
    ``deep.pad_state`` as its repack counterpart (zero filler moments).
  * Identity is preserved by bookkeeping, not layout.  Compaction renumbers
    members densely; the caller carries a survivor→original ``member_ids``
    vector (checkpointed in the lifecycle meta) so leaderboards and resumes
    always speak in ORIGINAL member ids.

Gathers run ON DEVICE by default (one jitted program of static-index
gathers — a 10k-member prune never round-trips the parameter tree through
host memory); ``gather="host"`` keeps the original ``device_get`` → numpy
fallback, bit-identical by construction.  Rung boundaries sit outside the
donated ``lax.scan`` chunk either way, and the caller ``device_put``s the
compacted tree born-sharded onto the new layout's specs (launch/train.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np

from repro.core.population import LayeredPopulation


def _host(x):
    return np.asarray(jax.device_get(x))


# ---------------------------------------------------------------------- #
# schedule                                                               #
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class HalvingSchedule:
    """Rungs of ``(end_step, keep_frac)``: after global step ``end_step``
    completes, keep the best ``keep_frac`` of the surviving members.

    ``"500:0.5,1000:0.5,2000:0.25"`` prunes to 50% at step 500, 50% of the
    survivors at 1000, and 25% of those at 2000.  Rungs at or beyond the
    run's total step count never fire (a short run is a prefix of the
    ladder — that is what makes mid-ladder checkpoints resumable with the
    SAME schedule string)."""

    rungs: tuple  # ((end_step, keep_frac), ...)

    def __post_init__(self):
        rungs = tuple((int(s), float(f)) for s, f in self.rungs)
        if not rungs:
            raise ValueError("halving schedule needs at least one rung")
        prev = 0
        for s, f in rungs:
            if s <= prev:
                raise ValueError(
                    f"rung steps must be strictly increasing and > 0, got "
                    f"{[r[0] for r in rungs]}")
            if not 0.0 < f <= 1.0:
                raise ValueError(f"keep_frac must be in (0, 1], got {f}")
            prev = s
        object.__setattr__(self, "rungs", rungs)

    @staticmethod
    def parse(spec: str) -> "HalvingSchedule":
        """``"500:0.5,1000:0.5,2000:0.25"`` → HalvingSchedule."""
        rungs = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            try:
                s, f = part.split(":")
                rungs.append((int(s), float(f)))
            except ValueError as e:
                raise ValueError(
                    f"bad halving rung {part!r} (want STEP:KEEP_FRAC, e.g. "
                    "'500:0.5,1000:0.25')") from e
        return HalvingSchedule(tuple(rungs))

    def segments(self, total_steps: int) -> tuple:
        """The run [0, total_steps) as ``(end_step, keep_frac|None)``
        training segments: one per rung boundary that falls INSIDE the run,
        plus the final un-pruned stretch.  Segment i trains global steps
        [prev_end, end) and then prunes iff keep_frac is not None."""
        if total_steps < 1:
            raise ValueError(f"total_steps must be >= 1, got {total_steps}")
        segs = [(s, f) for s, f in self.rungs if s < total_steps]
        segs.append((total_steps, None))
        return tuple(segs)

    @staticmethod
    def n_keep(n: int, keep_frac: float) -> int:
        """Survivor count for a rung: floor(n·frac), never below 1."""
        return max(1, int(n * keep_frac))


def survivors(losses, keep_frac: float) -> np.ndarray:
    """Indices of the best ``n_keep`` members by eval loss, SORTED ascending
    (compaction must preserve relative member order).  Ties break toward
    the lower index (stable argsort), so the selection is deterministic."""
    losses = np.asarray(losses)
    k = HalvingSchedule.n_keep(losses.shape[0], keep_frac)
    return np.sort(np.argsort(losses, kind="stable")[:k])


# ---------------------------------------------------------------------- #
# compaction                                                             #
# ---------------------------------------------------------------------- #

def _fused_keep_rows(pop_l, keep) -> np.ndarray:
    """Fused-axis indices of the survivors' PADDED slices in layer ``l``'s
    layout.  Padded (not just real) units are gathered so the compacted
    arrays are bit-identical to what a fresh layout of the survivors would
    address — block and per-member padded sizes are unchanged by subset."""
    off, pad = pop_l.offsets, pop_l.padded_sizes
    return np.concatenate(
        [np.arange(off[m], off[m] + pad[m]) for m in keep])


def _real_bucket_pos(lp: LayeredPopulation, l: int) -> dict:
    """member → (real-bucket index, position inside the bucket) for
    projection ``l`` — the inverse of the bucket packing that
    ``init_params`` used to build ``params['mid'][l]['w']``."""
    pos = {}
    wi = 0
    for (m0, n, hin, hout, off_in, off_out, real) in lp.proj_buckets(l):
        if not real:
            continue
        for i in range(n):
            pos[m0 + i] = (wi, i)
        wi += 1
    return pos


def _compact_tree(lp: LayeredPopulation, new_lp: LayeredPopulation,
                  params, keep, xp, fetch) -> dict:
    """The gather itself, over ``xp`` ∈ {numpy, jax.numpy}: every leaf is
    indexed member-major with STATIC index arrays, so the survivor slices
    come out bit-exact on either backend.  ``fetch`` materialises a leaf
    (cached ``device_get`` on the host path, identity under jit)."""
    rows0 = _fused_keep_rows(lp.layer_pop(0), keep)
    out = {"w_in": fetch(params["w_in"])[rows0],
           "b_in": fetch(params["b_in"])[rows0],
           "mid": []}
    for l in range(new_lp.depth - 1):
        pos = _real_bucket_pos(lp, l)
        old_w = params["mid"][l]["w"]
        wl = []
        for (m0, n, hin, hout, off_in, off_out, real) in \
                new_lp.proj_buckets(l):
            if not real:
                continue
            where = [pos[keep[m]] for m in range(m0, m0 + n)]
            parts, s = [], 0
            while s < n:      # maximal contiguous runs from one old bucket
                wi, i0 = where[s]
                e = s + 1
                while e < n and where[e] == (wi, i0 + (e - s)):
                    e += 1
                parts.append(fetch(old_w[wi])[i0: i0 + (e - s)])
                s = e
            wl.append(parts[0] if len(parts) == 1
                      else xp.concatenate(parts, axis=0))
        rows = _fused_keep_rows(lp.layer_pop(l + 1), keep)
        out["mid"].append({"w": wl,
                           "b": fetch(params["mid"][l]["b"])[rows]})
    rows_last = _fused_keep_rows(lp.layer_pop(lp.depth - 1), keep)
    out["w_out"] = fetch(params["w_out"])[:, rows_last]
    out["b_out"] = fetch(params["b_out"])[np.asarray(keep)]
    return out


@functools.lru_cache(maxsize=32)
def _device_gather_fn(lp, new_lp, keep):
    """Cached jitted gather per (layouts, keep): repeated compactions of
    the same prune (params, then each optimizer-moment subtree, and
    warm-then-time bench loops) reuse one compiled program."""
    import jax.numpy as jnp
    return jax.jit(lambda p: _compact_tree(lp, new_lp, p, list(keep), jnp,
                                           lambda a: a))


def compact_params(lp: LayeredPopulation, new_lp: LayeredPopulation,
                   params, keep, gather: str = "host") -> dict:
    """Gather one ``deep.init_params``-shaped tree down to the survivors.

    Works on parameters AND on any structurally identical tree (optimizer
    moments, gradients): every leaf is indexed member-major, so the
    survivor slices come out bit-exact.  Mid-layer bucket weights are
    re-grouped into ``new_lp``'s buckets — runs that were split by a pruned
    member merge, later layers that only pruned members reached are
    dropped (survivors were identity pass-throughs there).

    ``gather="device"`` runs the whole gather as ONE jitted program of
    static-index ``jnp.take``-style gathers — at 10k members the prune
    never round-trips the parameter tree through host memory (the ROADMAP
    PR-3 follow-up); ``gather="host"`` is the ``device_get`` → numpy
    fallback.  Both produce bit-identical trees (tests/test_lifecycle.py).
    """
    keep = [int(m) for m in keep]
    if gather == "device":
        return _device_gather_fn(lp, new_lp, tuple(keep))(params)
    if gather != "host":
        raise ValueError(f"gather must be 'device' or 'host', got {gather!r}")
    cache = {}

    def fetch(a):
        if id(a) not in cache:
            cache[id(a)] = _host(a)
        return cache[id(a)]

    return _compact_tree(lp, new_lp, params, keep, np, fetch)


def compact(pop: LayeredPopulation, params, opt_state, keep,
            gather: str = "device"):
    """Prune the fused population down to ``keep`` (strictly increasing
    REAL member indices) → ``(new_pop, new_params, new_opt_state)``.

    ``gather`` selects where the index maps run: ``"device"`` (default) is
    one jitted static-index gather program — no host round-trip, the
    compacted tree stays on device for the caller's born-sharded
    ``device_put``; ``"host"`` is the original ``device_get`` → numpy
    fallback (bit-identical results).

    ``new_pop`` is a freshly built, re-bucketed layout of the survivors
    (``LayeredPopulation.subset``): offsets, size/pair buckets, and kernel
    metadata are recomputed, so the fused hidden width physically shrinks.
    ``params`` (a ``deep.init_params`` tree) is gathered bit-exactly;
    ``opt_state`` may be ``None`` or any pytree whose params-shaped
    subtrees (SGD momentum ``mu``, Adam ``m``/``v``) are compacted through
    the same index maps — scalar leaves (step counts) pass through.
    Factored states (adafactor ``v_row``/``v_col``) are rejected: their
    leaves are not member-major along a gatherable axis — use
    :func:`compact_factored` for those.

    The caller owns re-padding (``new_pop.shard_pad``), re-deriving
    per-member learning rates (index the original vector by the survivor
    mapping), and device_put-ing the result born-sharded."""
    if not isinstance(pop, LayeredPopulation):
        raise TypeError(
            f"compact expects a LayeredPopulation, got {type(pop).__name__} "
            "(lift single-layer layouts with Population.layered() first)")
    new_pop = pop.subset(keep)
    new_params = compact_params(pop, new_pop, params, keep, gather=gather)
    if opt_state is None:
        return new_pop, new_params, None
    # the params-shaped-subtree rule lives in ONE place (deep.py) so the
    # gather side here and the pad_state repack side cannot drift
    from repro.core.deep import map_params_subtrees
    return new_pop, new_params, map_params_subtrees(
        opt_state, params,
        lambda node: compact_params(pop, new_pop, node, keep, gather=gather),
        op="compact")


def _is_factored_leaf(x) -> bool:
    """An adafactor per-param state dict: {"v"|"v_row"+"v_col"[, "m"]}."""
    return isinstance(x, dict) and ("v" in x or "v_row" in x)


# ---------------------------------------------------------------------- #
# growth (the inverse of compaction; DESIGN.md §13)                      #
# ---------------------------------------------------------------------- #

def _grow_src(new_lp: LayeredPopulation, positions) -> list:
    """Per new-layout member: ``(tree, index)`` with tree 0 = the old
    (surviving) params tree, tree 1 = the fresh (born) members' tree."""
    # the fresh tree's members sit at sorted(positions) (it is built as
    # ``new_lp.subset(sorted(positions))``), so a position's fresh index is
    # its RANK among the positions, not its index in the positions tuple
    rank = {p: r for r, p in enumerate(sorted(positions))}
    src, oi = [], 0
    for m in range(new_lp.num_members):
        if m in rank:
            src.append((1, rank[m]))
        else:
            src.append((0, oi))
            oi += 1
    return src


def _grow_tree(lp: LayeredPopulation, new_lp: LayeredPopulation,
               fresh_lp: LayeredPopulation, params, fresh, positions,
               xp, fetch) -> dict:
    """The splice itself (mirror of ``_compact_tree``): every leaf of the
    grown tree is one static-index gather from the concatenation of the
    surviving tree and the fresh-members tree, so survivors come out
    bit-exact and born members carry exactly their fresh init.  Mid-layer
    bias rows of a source tree SHALLOWER than the grown depth gather from
    an appended zero row (those fused slices are identity pass-throughs —
    masked bias, zero forever), which is exactly what a from-scratch init
    of the grown layout would hold there."""
    src = _grow_src(new_lp, positions)
    srcs_lp = (lp, fresh_lp)

    def fused_splice(l, leaf_old, leaf_fresh, axis=0, carried=False):
        """Gather the grown layer-``l`` fused axis from (old ++ fresh
        [++ zeros]).  ``carried``: a source shallower than ``l`` reads its
        FINAL layer's slice (the pass-through carries the final width —
        w_in/w_out semantics); otherwise those rows read zeros (mid-layer
        bias semantics)."""
        leaves = [leaf_old, leaf_fresh]
        n = [leaf_old.shape[axis],
             0 if leaf_fresh is None else leaf_fresh.shape[axis]]
        pop_new = new_lp.layer_pop(l)
        idx, need_zero = [], False
        for m in range(new_lp.num_members):
            t, i = src[m]
            slp = srcs_lp[t]
            l_src = l if l < slp.depth else (slp.depth - 1 if carried
                                             else None)
            if l_src is None or leaves[t] is None:
                need_zero = True
                idx.append(np.full(pop_new.padded_sizes[m], n[0] + n[1]))
                continue
            sp = slp.layer_pop(l_src)
            base = 0 if t == 0 else n[0]
            idx.append(np.arange(sp.offsets[i],
                                 sp.offsets[i] + sp.padded_sizes[i]) + base)
        idx = np.concatenate(idx)
        parts = [leaf_old] if leaf_fresh is None else [leaf_old, leaf_fresh]
        if need_zero:
            shape = list(leaf_old.shape)
            shape[axis] = 1
            parts.append(xp.zeros(tuple(shape), leaf_old.dtype))
        combined = parts[0] if len(parts) == 1 \
            else xp.concatenate(parts, axis=axis)
        return xp.take(combined, idx, axis=axis)

    f = fetch
    out = {"w_in": fused_splice(0, f(params["w_in"]), f(fresh["w_in"])),
           "b_in": fused_splice(0, f(params["b_in"]), f(fresh["b_in"])),
           "mid": []}
    for l in range(new_lp.depth - 1):
        pos_src = [(_real_bucket_pos(slp, l) if l < slp.depth - 1 else {})
                   for slp in srcs_lp]
        w_src = [params["mid"][l]["w"] if l < lp.depth - 1 else None,
                 fresh["mid"][l]["w"] if l < fresh_lp.depth - 1 else None]
        wl = []
        for (m0, n, hin, hout, off_in, off_out, real) in \
                new_lp.proj_buckets(l):
            if not real:
                continue
            where = [(src[m][0],) + pos_src[src[m][0]][src[m][1]]
                     for m in range(m0, m0 + n)]
            parts, s = [], 0
            while s < n:      # maximal contiguous runs from one src bucket
                t, wi, i0 = where[s]
                e = s + 1
                while e < n and where[e] == (t, wi, i0 + (e - s)):
                    e += 1
                parts.append(f(w_src[t][wi])[i0: i0 + (e - s)])
                s = e
            wl.append(parts[0] if len(parts) == 1
                      else xp.concatenate(parts, axis=0))
        b_old = (f(params["mid"][l]["b"]) if l < lp.depth - 1
                 else f(params["b_in"])[:0])      # typed empty, same dtype
        b_fresh = (f(fresh["mid"][l]["b"]) if l < fresh_lp.depth - 1
                   else None)
        out["mid"].append({"w": wl,
                           "b": fused_splice(l + 1, b_old, b_fresh)})
    out["w_out"] = fused_splice(new_lp.depth - 1, f(params["w_out"]),
                                f(fresh["w_out"]), axis=1, carried=True)
    n_old = params["b_out"].shape[0]
    rows = np.array([i if t == 0 else n_old + i for (t, i) in src])
    out["b_out"] = xp.take(
        xp.concatenate([f(params["b_out"]), f(fresh["b_out"])], axis=0),
        rows, axis=0)
    return out


@functools.lru_cache(maxsize=32)
def _device_grow_fn(lp, new_lp, fresh_lp, positions):
    """Cached jitted splice per (layouts, positions) — the grow twin of
    ``_device_gather_fn``."""
    import jax.numpy as jnp
    return jax.jit(lambda p, fr: _grow_tree(lp, new_lp, fresh_lp, p, fr,
                                            positions, jnp, lambda a: a))


def grow_params(lp: LayeredPopulation, new_lp: LayeredPopulation,
                params, positions, fresh, gather: str = "device") -> dict:
    """Splice a fresh-members tree into a surviving tree — the exact
    inverse of :func:`compact_params` (grow-then-compact is bit-identical
    to never growing; tests/test_refill.py).

    ``new_lp`` must be ``lp.grow(...)`` with the same ``positions``;
    ``fresh`` is a ``deep.init_params``-shaped tree for the NEW members'
    own layout ``new_lp.subset(sorted(positions))`` — typically
    ``init_params(key, new_lp.subset(sorted(positions)))`` for parameters
    or an all-zero twin for optimizer moments.  Like compaction, the
    splice works on parameters and on any structurally identical tree,
    and ``gather="device"`` runs it as ONE jitted static-index program
    (no host round-trip; the result is ready for the caller's
    born-sharded ``device_put``)."""
    positions = tuple(int(p) for p in positions)
    fresh_lp = new_lp.subset(tuple(sorted(positions)))
    n_old = new_lp.num_real - len(positions)
    old_pos = tuple(m for m in range(new_lp.num_real)
                    if m not in set(positions))
    if len(old_pos) != n_old or new_lp.subset(old_pos) != lp:
        raise ValueError(
            "grow_params: new_lp is not lp.grow(...) at these positions "
            "(the survivors' widths/activations must read back as lp)")
    if gather == "device":
        return _device_grow_fn(lp, new_lp, fresh_lp, positions)(params,
                                                               fresh)
    if gather != "host":
        raise ValueError(f"gather must be 'device' or 'host', got {gather!r}")
    cache = {}

    def fetch(a):
        if id(a) not in cache:
            cache[id(a)] = _host(a)
        return cache[id(a)]

    return _grow_tree(lp, new_lp, fresh_lp, params, fresh, positions, np,
                      fetch)


def grow(pop: LayeredPopulation, params, opt_state, new_widths, new_acts,
         positions, key, gather: str = "device", dtype=None):
    """Refill a compacted population with NEW members →
    ``(new_pop, new_params, new_opt_state)`` — the rung-boundary inverse of
    :func:`compact` (DESIGN.md §13).

    New members' parameters are freshly initialised from ``key`` (their own
    ``init_params`` draw, independent of position); their optimizer moments
    are ZERO — exactly what ``opt.init`` gives a newborn — while survivors'
    params AND moments ride through bit-exact.  ``opt_state`` follows the
    same params-shaped-subtree rule as compaction (factored adafactor
    states are rejected; carry their momentum through
    :func:`compact_factored` and grow it as a plain tree)."""
    import jax.numpy as jnp

    from repro.core.deep import grow_state, init_params
    new_pop = pop.grow(new_widths, new_acts, positions)
    fresh_lp = new_pop.subset(tuple(sorted(int(p) for p in positions)))
    fresh = init_params(key, fresh_lp, dtype or jnp.float32)
    new_params = grow_params(pop, new_pop, params, positions, fresh,
                             gather=gather)
    if opt_state is None:
        return new_pop, new_params, None
    return new_pop, new_params, grow_state(opt_state, pop, new_pop,
                                           positions, gather=gather)


# ---------------------------------------------------------------------- #
# constant-size slot refill (zero re-jit; DESIGN.md §13)                 #
# ---------------------------------------------------------------------- #

def _refill_tree(lp: LayeredPopulation, assignments, fresh_lp, params,
                 fresh, xp) -> dict:
    """In-place scatter: write each refilled slot's member-major slices —
    from its clone parent's slices (same leaf) or from the fresh-init tree
    — leaving every surviving slot's bytes untouched.  All indices are
    static; one jitted program on the device path."""
    def scatter(arr, idx, vals, axis=0):
        idx = np.asarray(idx)
        if idx.size == 0:
            return arr
        if xp is np:
            out = np.array(arr)
            if axis == 0:
                out[idx] = vals
            else:
                out[:, idx] = vals
            return out
        return arr.at[idx].set(vals) if axis == 0 \
            else arr.at[:, idx].set(vals)

    fresh_of = {}                 # slot -> index into fresh_lp's members
    for slot, parent in assignments:
        if parent < 0:
            fresh_of[slot] = len(fresh_of)

    def rows(pop_l, m):
        return np.arange(pop_l.offsets[m],
                         pop_l.offsets[m] + pop_l.padded_sizes[m])

    def fused_scatter(l, leaf, fresh_leaf, axis=0, carried=False):
        pop_l = lp.layer_pop(l)
        dst_c, src_c, dst_f, src_f = [], [], [], []
        for slot, parent in assignments:
            if not carried and lp.member_depths[slot] <= l:
                continue          # pass-through rows: zero before and after
            if parent >= 0:
                dst_c.append(rows(pop_l, slot))
                src_c.append(rows(pop_l, parent))
            else:
                j = fresh_of[slot]
                l_src = min(l, fresh_lp.depth - 1) if carried else l
                sp = fresh_lp.layer_pop(l_src)
                dst_f.append(rows(pop_l, slot))
                src_f.append(rows(sp, j))
        if dst_c:
            dc, sc = np.concatenate(dst_c), np.concatenate(src_c)
            leaf = scatter(leaf, dc, xp.take(leaf, sc, axis=axis), axis)
        if dst_f:
            df, sf = np.concatenate(dst_f), np.concatenate(src_f)
            leaf = scatter(leaf, df, xp.take(fresh_leaf, sf, axis=axis),
                           axis)
        return leaf

    out = {"w_in": fused_scatter(0, params["w_in"],
                                 None if fresh is None else fresh["w_in"]),
           "b_in": fused_scatter(0, params["b_in"],
                                 None if fresh is None else fresh["b_in"]),
           "mid": []}
    for l in range(lp.depth - 1):
        pos = _real_bucket_pos(lp, l)
        pos_f = (_real_bucket_pos(fresh_lp, l)
                 if fresh_lp is not None and l < fresh_lp.depth - 1 else {})
        # group (dst bucket, src bucket) pairs so each pair is ONE
        # vectorised gather+scatter, whatever order the slots arrive in
        groups = {}
        for slot, parent in assignments:
            if not lp.proj_real(slot, l):
                continue
            wi_d, i_d = pos[slot]
            if parent >= 0:
                wi_s, i_s = pos[parent]
                groups.setdefault((wi_d, 0, wi_s), []).append((i_d, i_s))
            else:
                wi_s, i_s = pos_f[fresh_of[slot]]
                groups.setdefault((wi_d, 1, wi_s), []).append((i_d, i_s))
        wl = list(params["mid"][l]["w"])
        for (wi_d, t, wi_s), pairs in groups.items():
            i_d = np.array([p[0] for p in pairs])
            i_s = np.array([p[1] for p in pairs])
            src_arr = wl[wi_s] if t == 0 else fresh["mid"][l]["w"][wi_s]
            wl[wi_d] = scatter(wl[wi_d], i_d,
                               xp.take(src_arr, i_s, axis=0))
        out["mid"].append({
            "w": wl,
            "b": fused_scatter(l + 1, params["mid"][l]["b"],
                               fresh["mid"][l]["b"]
                               if fresh is not None
                               and fresh_lp.depth - 1 > l else None)})
    out["w_out"] = fused_scatter(lp.depth - 1, params["w_out"],
                                 None if fresh is None else fresh["w_out"],
                                 axis=1, carried=True)
    dst_b = np.array([slot for slot, _ in assignments])
    src_rows = []
    for slot, parent in assignments:
        if parent >= 0:
            src_rows.append(xp.take(params["b_out"],
                                    np.array([parent]), axis=0))
        else:
            src_rows.append(xp.take(fresh["b_out"],
                                    np.array([fresh_of[slot]]), axis=0))
    out["b_out"] = scatter(params["b_out"], dst_b,
                           xp.concatenate(src_rows, axis=0))
    return out


@functools.lru_cache(maxsize=32)
def _device_refill_fn(lp, assignments, fresh_lp, has_fresh):
    import jax.numpy as jnp
    if has_fresh:
        return jax.jit(lambda p, fr: _refill_tree(lp, assignments, fresh_lp,
                                                  p, fr, jnp))
    return jax.jit(lambda p: _refill_tree(lp, assignments, None, p, None,
                                          jnp))


def refill_params(lp: LayeredPopulation, params, assignments,
                  fresh=None, gather: str = "device") -> dict:
    """Constant-size slot refill: overwrite pruned slots IN PLACE with
    PBT-style exploit clones of survivors and/or freshly initialised
    members, keeping the layout — and therefore every jitted program
    compiled against it — unchanged (DESIGN.md §13).

    ``assignments`` is a tuple of ``(slot, parent)`` pairs: ``slot`` is a
    pruned REAL slot to refill, ``parent`` a surviving REAL slot to clone
    (its (widths, activations) must equal the slot's — refills ADOPT the
    slot's architecture, that is what keeps the layout equal), or ``-1``
    to fresh-init the slot from ``fresh`` (a ``deep.init_params`` tree for
    the fresh slots' own layout, in ascending slot order).  Survivor bytes
    are untouched; the whole rewrite is one jitted static-index
    gather/scatter on the default device path."""
    assignments = tuple((int(s), int(p)) for s, p in assignments)
    slots = [s for s, _ in assignments]
    if len(set(slots)) != len(slots):
        raise ValueError(f"refill_params: duplicate slots in {slots}")
    slot_set = set(slots)
    fresh_slots = []
    for slot, parent in assignments:
        if not 0 <= slot < lp.num_real:
            raise ValueError(f"refill_params: slot {slot} out of range "
                             f"[0, {lp.num_real}) (fillers cannot refill)")
        if parent >= 0:
            if parent in slot_set or not 0 <= parent < lp.num_real:
                raise ValueError(
                    f"refill_params: parent {parent} of slot {slot} must "
                    "be a surviving real slot")
            if (lp.widths[parent] != lp.widths[slot]
                    or lp.activations[parent] != lp.activations[slot]):
                raise ValueError(
                    f"refill_params: parent {parent} arch "
                    f"{lp.widths[parent]} does not match slot {slot} arch "
                    f"{lp.widths[slot]} — clones adopt the slot's "
                    "architecture")
        else:
            fresh_slots.append(slot)
    fresh_lp = None
    if fresh_slots:
        if fresh is None:
            raise ValueError("refill_params: fresh-init slots need a "
                             "`fresh` params tree")
        fresh_slots.sort()
        fresh_lp = LayeredPopulation(
            lp.in_features, lp.out_features,
            tuple(lp.widths[s] for s in fresh_slots),
            tuple(lp.activations[s] for s in fresh_slots), block=lp.block)
    # fresh members are consumed in ascending slot order — re-sort so the
    # fresh_of map inside _refill_tree matches fresh_lp's member order
    assignments = tuple(sorted(assignments))
    if gather == "device":
        fn = _device_refill_fn(lp, assignments, fresh_lp,
                               fresh_lp is not None)
        return fn(params, fresh) if fresh_lp is not None else fn(params)
    if gather != "host":
        raise ValueError(f"gather must be 'device' or 'host', got {gather!r}")
    return _refill_tree(lp, assignments, fresh_lp,
                        jax.tree.map(_host, params),
                        None if fresh is None else jax.tree.map(_host, fresh),
                        np)


def member_moment_mask(lp: LayeredPopulation, slots) -> dict:
    """Params-structured tree of BROADCASTABLE keep masks: 1.0 on every
    surviving member's slices, 0.0 on the refilled ``slots``.  Multiplying
    an optimizer-moment tree by this mask is the in-place twin of the
    grow path's zero-moment init (``optim.scale_member_moments`` applies
    it schema-aware across all four optimizers)."""
    slots = sorted(int(s) for s in slots)
    for s in slots:
        if not 0 <= s < lp.num_real:
            raise ValueError(f"member_moment_mask: slot {s} out of range")

    def fused_mask(l):
        pop_l = lp.layer_pop(l)
        m = np.ones(pop_l.total_hidden, np.float32)
        for s in slots:
            m[pop_l.offsets[s]: pop_l.offsets[s + 1]] = 0.0
        return m

    slot_set = set(slots)
    member_m = np.array([0.0 if m in slot_set else 1.0
                         for m in range(lp.num_members)], np.float32)
    out = {"w_in": fused_mask(0)[:, None], "b_in": fused_mask(0), "mid": []}
    for l in range(lp.depth - 1):
        wl = []
        for (m0, n, hin, hout, off_in, off_out, real) in lp.proj_buckets(l):
            if not real:
                continue
            wl.append(member_m[m0: m0 + n][:, None, None])
        out["mid"].append({"w": wl, "b": fused_mask(l + 1)})
    out["w_out"] = fused_mask(lp.depth - 1)[None, :]
    out["b_out"] = member_m[:, None]
    return out


def refill_state(opt_state, lp: LayeredPopulation, slots):
    """Zero the refilled slots' member-major optimizer moments in place —
    what ``opt.init`` would give the newborns — leaving survivors'
    moments bit-identical and scalar counts untouched.  Works for all
    four optimizers: sgd (stateless — count passes through), momentum
    (``mu``), adamw (``m``/``v``, dtype preserved), adafactor (``m`` and
    unfactored ``v`` leaves are zeroed; the factored ``v_row``/``v_col``
    statistics mix members along their reduced axis and pass through
    STALE — they re-warm in ~1/(1−b2) steps, the same documented cost as
    riding adafactor through a compacting rung)."""
    if opt_state is None or not slots:
        return opt_state
    from repro.core.deep import abstract_params
    from repro.optim.optimizers import scale_member_moments
    return scale_member_moments(opt_state, abstract_params(lp),
                                member_moment_mask(lp, slots))


def compact_factored(pop: LayeredPopulation, params, opt_state, keep,
                     gather: str = "device"):
    """Adafactor-aware rung compaction → ``(new_pop, new_params, carry)``.

    ``opt_state`` must be an adafactor state (``{"count", "leaves"}`` with
    per-param dicts holding ``v``/``v_row``+``v_col`` and optionally
    ``m``).  The factored second-moment statistics reduce over the fused
    hidden axis — ``v_row``/``v_col`` of a fused weight MIX members, so no
    member-major gather can recover a survivor's statistics — and are
    therefore DROPPED.  What survives the rung rides in ``carry``:

      * ``carry["m"]`` — the params-shaped momentum tree, gathered through
        the same index maps as the parameters (bit-exact, dtype preserved;
        ``None`` when the optimizer runs without momentum);
      * ``carry["count"]`` — the step count, passed through.

    The caller re-initialises fresh (zero) factored statistics on the new
    layout and merges the carry back in (launch/train.py): the second
    moment then re-warms in ~1/(1−b2) steps — the documented cost of
    riding adafactor through a halving ladder."""
    if not (isinstance(opt_state, dict) and "leaves" in opt_state):
        raise ValueError(
            "compact_factored expects an adafactor state "
            "({'count', 'leaves'}); use compact() for params-shaped states")
    new_pop = pop.subset(keep)
    new_params = compact_params(pop, new_pop, params, keep, gather=gather)
    leaves = opt_state["leaves"]
    flat = jax.tree.leaves(leaves, is_leaf=_is_factored_leaf)
    m = None
    if flat and all("m" in st for st in flat):
        m_tree = jax.tree.map(lambda st: st["m"], leaves,
                              is_leaf=_is_factored_leaf)
        m = compact_params(pop, new_pop, m_tree, keep, gather=gather)
    return new_pop, new_params, {"count": opt_state["count"], "m": m}
