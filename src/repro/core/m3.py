"""Modified Matrix Multiplication (M3) — the paper's core operation.

Computes, for a fused hidden tensor ``h`` (batch, total_hidden) and a fused
output weight ``w2`` (out, total_hidden) with per-unit member ids ``seg``:

    y[b, m, o] = sum_{j : seg[j] == m}  h[b, j] * w2[o, j]

i.e. a matmul whose reduction is *segmented* by member, so each member's
output (and therefore gradient) is computed from its own hidden slice only.

Four implementations, identical semantics (cross-checked in tests):

  m3_scatter   — paper-faithful GPU formulation: broadcast element-wise
                 product + scatter-add (jax.ops.segment_sum).  Materialises
                 the (B, O, H) intermediate; memory-bound.  This is the
                 *reproduction baseline* recorded in EXPERIMENTS.md.
  m3_onehot    — single einsum against a one-hot segment selector; dense and
                 MXU-friendly but does P× redundant compute.  Included for the
                 shoot-out benchmark.
  m3_bucketed  — members bucketed by padded hidden size → per-bucket batched
                 matmul ('bnh,noh->bno').  Dense, zero scatter, XLA-native;
                 the best non-Pallas TPU formulation.
  m3_pallas    — segment-blocked matmul Pallas kernel (kernels/m3_matmul.py):
                 one dense (Bt×k)·(k×O) MXU matmul per hidden tile accumulated
                 in VMEM into the output block chosen by a scalar-prefetched
                 segment id.  TPU-native adaptation (DESIGN.md §2).

All take the static ``Population`` layout for segment metadata and an optional
``precision``.  Shapes: h (B, H), w2 (O, H) → y (B, P, O).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.population import Population


# ---------------------------------------------------------------------- #
# 1. paper-faithful: broadcast multiply + scatter-add                     #
# ---------------------------------------------------------------------- #

def m3_scatter(h: jax.Array, w2: jax.Array, pop: Population) -> jax.Array:
    """The paper's M3: S[b,o,j] = h[b,j]·w2[o,j]; scatter-add over j by member.

    ``jax.ops.segment_sum`` reduces over the *leading* axis, so we transpose the
    broadcast product to (H, B, O).  num_segments is static → jit-safe.
    """
    s = h[:, None, :] * w2[None, :, :]            # (B, O, H)  — the paper's S
    if s.dtype != jnp.float32:                    # bf16 operands: f32 reduce
        s = s.astype(jnp.float32)
    s = jnp.moveaxis(s, -1, 0)                     # (H, B, O)
    y = jax.ops.segment_sum(
        s, jnp.asarray(pop.segment_ids),
        num_segments=pop.num_members,
        indices_are_sorted=True)                   # (P, B, O)
    return jnp.moveaxis(y, 0, 1)                   # (B, P, O)


# ---------------------------------------------------------------------- #
# 2. one-hot einsum                                                      #
# ---------------------------------------------------------------------- #

def m3_onehot(h: jax.Array, w2: jax.Array, pop: Population) -> jax.Array:
    sel = jax.nn.one_hot(jnp.asarray(pop.segment_ids), pop.num_members,
                         dtype=h.dtype)            # (H, P)
    # y[b,m,o] = sum_j h[b,j] w2[o,j] sel[j,m]
    return jnp.einsum("bj,oj,jm->bmo", h, w2, sel,
                      optimize="greedy",
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------- #
# 3. bucketed batched matmul                                             #
# ---------------------------------------------------------------------- #

def _buckets(pop: Population):
    """Contiguous runs of members with identical *padded* size — now owned by
    the layout primitive itself (``Population.size_buckets``); kept as an
    alias for callers of the original private helper."""
    return pop.size_buckets()


def m3_bucketed(h: jax.Array, w2: jax.Array, pop: Population) -> jax.Array:
    """Reshape each equal-size run of members to (B, n, hs) and batched-matmul
    against (n, O, hs).  Pure dense compute; padding columns multiply zeros."""
    b = h.shape[0]
    o = w2.shape[0]
    pieces = []
    for (m0, n, hs, col0) in _buckets(pop):
        hh = h[:, col0: col0 + n * hs].reshape(b, n, hs)
        ww = w2[:, col0: col0 + n * hs].reshape(o, n, hs)
        pieces.append(jnp.einsum("bnh,onh->bno", hh, ww,
                                 preferred_element_type=jnp.float32))
    return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=1)


# ---------------------------------------------------------------------- #
# 4. Pallas segment-blocked matmul                                       #
# ---------------------------------------------------------------------- #

def m3_pallas(h: jax.Array, w2: jax.Array, pop: Population, *,
              interpret: bool | None = None, block_b: int = 128) -> jax.Array:
    from repro.kernels.ops import m3_matmul  # lazy: kernels import pallas
    return m3_matmul(h, w2,
                     block_seg_ids=np.asarray(pop.block_segment_ids),
                     num_members=pop.num_members,
                     block_h=pop.block, block_b=block_b,
                     interpret=interpret)


M3_IMPLS = {
    "scatter": m3_scatter,
    "onehot": m3_onehot,
    "bucketed": m3_bucketed,
    "pallas": m3_pallas,
}


def m3(h: jax.Array, w2: jax.Array, pop: Population,
       impl: str = "bucketed", **kw) -> jax.Array:
    return M3_IMPLS[impl](h, w2, pop, **kw)


# ---------------------------------------------------------------------- #
# 5. fused loss head: M3 projection + softmax-XE in one pass             #
# ---------------------------------------------------------------------- #

def m3_loss_head(h: jax.Array, w2: jax.Array, b2: jax.Array,
                 targets: jax.Array, pop: Population, *,
                 interpret: bool | None = None,
                 block_b: int = 128) -> jax.Array:
    """The training-time fusion of M3: projection + per-member bias +
    softmax cross-entropy + dlogits in one Pallas launch per direction
    (kernels/loss_head.py, DESIGN.md §9) — the logits never reach HBM.
    Returns the per-member mean NLL (P,) f32; paths that need actual
    logits use ``m3`` (training) or ``m3_infer_head`` (serving)."""
    from repro.kernels.ops import loss_head  # lazy: kernels import pallas
    return loss_head(h, w2, b2, targets,
                     np.asarray(pop.block_segment_ids),
                     block_h=pop.block, block_b=block_b,
                     interpret=interpret)


# loss-head impls that bypass logits materialisation entirely; the name
# mirrors FUSED_BD_IMPLS — deep.fused_loss routes through this registry
LOSS_IMPLS = {
    "xla": None,          # log_softmax over forward() logits (deep.fused_loss)
    "fused": m3_loss_head,
}
FUSED_LOSS_IMPLS = frozenset(["fused"])


# ---------------------------------------------------------------------- #
# 6. forward-only inference head: M3 + bias (+ log-softmax) in one pass  #
# ---------------------------------------------------------------------- #

def m3_infer_head(h: jax.Array, w2: jax.Array, b2: jax.Array,
                  pop: Population, *, log_probs: bool = False,
                  interpret: bool | None = None,
                  block_b: int | None = None) -> jax.Array:
    """The serving-time counterpart of ``m3_loss_head``: projection +
    per-member bias — and optionally the stable log-softmax — in ONE
    forward-only Pallas launch (kernels/infer_head.py, DESIGN.md §10),
    producing the (B, P, O) logits/log-probs the ensemble reductions
    consume.  Not differentiable: the inference hot path must not be able
    to emit residuals.  This retires the old caveat that eval paths
    needing actual logits fall back to ``m3`` + XLA bias/softmax."""
    from repro.kernels.ops import INFER_BLOCK_B, infer_head  # lazy
    return infer_head(h, w2, b2, np.asarray(pop.block_segment_ids),
                      block_h=pop.block,
                      block_b=INFER_BLOCK_B if block_b is None else block_b,
                      log_probs=log_probs, interpret=interpret)


def m3_infer_head_int8(h: jax.Array, w2_q: jax.Array, w2_scale: jax.Array,
                       b2: jax.Array, pop: Population, *,
                       log_probs: bool = False,
                       interpret: bool | None = None,
                       block_b: int | None = None) -> jax.Array:
    """``m3_infer_head`` over the int8 serve copy (DESIGN.md §12): the
    head weight stays int8 in HBM, one f32 scale per hidden tile is
    dequantized inside the projection loop."""
    from repro.kernels.ops import INFER_BLOCK_B, infer_head_int8  # lazy
    return infer_head_int8(
        h, w2_q, w2_scale, b2, np.asarray(pop.block_segment_ids),
        block_h=pop.block,
        block_b=INFER_BLOCK_B if block_b is None else block_b,
        log_probs=log_probs, interpret=interpret)


# inference head impls — deep.forward(infer=True) routes through this
HEAD_IMPLS = {
    "xla": None,          # m3 logits + XLA bias/log_softmax (deep.forward)
    "fused": m3_infer_head,
    "fused_int8": m3_infer_head_int8,   # int8 serve copy (weights_dtype)
}
