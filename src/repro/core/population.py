"""Population descriptor: a heterogeneous collection of independent MLPs fused
into a single tensor layout (the paper's ParallelMLPs).

A population of P members, member ``m`` having ``hidden_sizes[m]`` hidden units
and activation ``activations[m]``, is laid out as one fused hidden axis of
``total_hidden`` units.  Every member's slice is padded up to a multiple of
``block`` so that, on TPU, each 128-lane tile belongs to exactly one member —
this is what turns the paper's scatter-add into a segment-blocked matmul
(DESIGN.md §2).  Padded units are masked to zero after activation, so they
receive zero gradient and the fused network is mathematically identical to the
P independent networks.

All layout quantities are static Python data (computed at trace time), so jit
sees them as compile-time constants; only the parameter/activation tensors are
traced.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Sequence

import numpy as np

from repro.core.activations import ACTIVATION_NAMES


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class Population:
    """Static description of a fused population of independent MLPs.

    Members are stored in the order given; callers that want efficient sliced
    activation application should construct with ``sort_members=True`` (groups
    members by activation so each activation is applied to one contiguous
    slice).
    """

    in_features: int
    out_features: int
    hidden_sizes: tuple
    activations: tuple  # activation *names*, one per member
    block: int = 1      # hidden-slice alignment (128 for TPU kernels)

    def __post_init__(self):
        if len(self.hidden_sizes) != len(self.activations):
            raise ValueError(
                f"hidden_sizes ({len(self.hidden_sizes)}) and activations "
                f"({len(self.activations)}) must have the same length")
        for a in self.activations:
            if a not in ACTIVATION_NAMES:
                raise ValueError(f"unknown activation {a!r}; "
                                 f"known: {sorted(ACTIVATION_NAMES)}")
        for h in self.hidden_sizes:
            if h < 1:
                raise ValueError(f"hidden size must be >= 1, got {h}")
        if self.block < 1:
            raise ValueError("block must be >= 1")
        # normalise to tuples (allows list inputs)
        object.__setattr__(self, "hidden_sizes", tuple(int(h) for h in self.hidden_sizes))
        object.__setattr__(self, "activations", tuple(self.activations))

    # ------------------------------------------------------------------ #
    # constructors                                                       #
    # ------------------------------------------------------------------ #
    @staticmethod
    def grid(in_features: int, out_features: int,
             hidden_range: Sequence[int], activations: Sequence[str],
             repeats: int = 1, block: int = 1,
             sort_members: bool = True, sort_by: str = "act") -> "Population":
        """The paper's experimental design: every (hidden size × activation)
        pair, repeated ``repeats`` times.  hidden 1..100 × 10 activations ×
        10 repeats = the paper's 10,000 models."""
        sizes, acts = [], []
        for a in activations:
            for h in hidden_range:
                for _ in range(repeats):
                    sizes.append(h)
                    acts.append(a)
        pop = Population(in_features, out_features, tuple(sizes), tuple(acts),
                         block=block)
        return pop.sorted(sort_by) if sort_members else pop

    def sorted(self, by: str = "act") -> "Population":
        """Reorder members so fused ops touch contiguous slices.

        by="act"  — (activation, size): one activation run per function
                    (best when activation dispatch dominates; default).
        by="size" — (padded size, activation): one M3 bucket per size CLASS,
                    merging buckets across activations — at block=8 the
                    paper grid collapses 130 bucket einsums to 13 while
                    keeping tight padding (§Perf hillclimb, paper cell)."""
        if by == "act":
            key = lambda m: (self.activations[m], self.hidden_sizes[m])
        elif by == "size":
            key = lambda m: (_round_up(self.hidden_sizes[m], self.block),
                             self.activations[m], self.hidden_sizes[m])
        else:
            raise ValueError(by)
        order = sorted(range(self.num_members), key=key)
        return dataclasses.replace(
            self,
            hidden_sizes=tuple(self.hidden_sizes[m] for m in order),
            activations=tuple(self.activations[m] for m in order),
        )

    # ------------------------------------------------------------------ #
    # layout (all static numpy, computed once)                           #
    # ------------------------------------------------------------------ #
    @property
    def num_members(self) -> int:
        return len(self.hidden_sizes)

    @cached_property
    def padded_sizes(self) -> np.ndarray:
        """Per-member hidden size rounded up to ``block``. shape (P,)."""
        return np.array([_round_up(h, self.block) for h in self.hidden_sizes],
                        dtype=np.int32)

    @cached_property
    def offsets(self) -> np.ndarray:
        """Start offset of member m's slice in the fused hidden axis. (P+1,)."""
        return np.concatenate([[0], np.cumsum(self.padded_sizes)]).astype(np.int32)

    @property
    def total_hidden(self) -> int:
        return int(self.offsets[-1])

    @cached_property
    def segment_ids(self) -> np.ndarray:
        """Member id for every fused hidden unit. shape (total_hidden,)."""
        return np.repeat(np.arange(self.num_members, dtype=np.int32),
                         self.padded_sizes)

    @cached_property
    def hidden_mask(self) -> np.ndarray:
        """1.0 for real hidden units, 0.0 for alignment padding. (total_hidden,)."""
        mask = np.zeros(self.total_hidden, dtype=np.float32)
        for m in range(self.num_members):
            mask[self.offsets[m]: self.offsets[m] + self.hidden_sizes[m]] = 1.0
        return mask

    @cached_property
    def act_ids(self) -> np.ndarray:
        """Activation id (index into ACTIVATION_NAMES order used by
        activations.apply_*) for every fused hidden unit. (total_hidden,)."""
        names = sorted(ACTIVATION_NAMES)
        lut = {n: i for i, n in enumerate(names)}
        per_member = np.array([lut[a] for a in self.activations], dtype=np.int32)
        return np.repeat(per_member, self.padded_sizes)

    @cached_property
    def act_runs(self):
        """Contiguous runs of identical activation: list of
        (act_name, start, stop) covering [0, total_hidden).  One run per
        activation if the population is sorted."""
        runs = []
        seg_acts = [self.activations[m] for m in range(self.num_members)]
        start = 0
        m = 0
        while m < self.num_members:
            a = seg_acts[m]
            stop_m = m
            while stop_m + 1 < self.num_members and seg_acts[stop_m + 1] == a:
                stop_m += 1
            stop = int(self.offsets[stop_m + 1])
            runs.append((a, start, stop))
            start = stop
            m = stop_m + 1
        return runs

    @cached_property
    def member_fan_in(self) -> np.ndarray:
        """Fan-in of the output layer per fused hidden unit (= its member's
        true hidden size); used for per-member init scaling. (total_hidden,)."""
        return np.repeat(np.array(self.hidden_sizes, dtype=np.float32),
                         self.padded_sizes)

    @cached_property
    def block_segment_ids(self) -> np.ndarray:
        """Member id per hidden *block* (total_hidden // block,).  Well defined
        because every member slice is block-aligned; this is the scalar-prefetch
        input of the Pallas segment-blocked matmul."""
        assert self.total_hidden % self.block == 0
        return self.segment_ids[:: self.block].copy()

    @cached_property
    def block_act_ids(self) -> np.ndarray:
        """Activation id per hidden block (scalar prefetch for seg_act)."""
        assert self.total_hidden % self.block == 0
        return self.act_ids[:: self.block].copy()

    def member_slice(self, m: int) -> slice:
        """Slice of member m's REAL units (excludes padding)."""
        return slice(int(self.offsets[m]), int(self.offsets[m]) + self.hidden_sizes[m])

    def describe(self) -> str:
        import collections
        by_act = collections.Counter(self.activations)
        return (f"Population(P={self.num_members}, total_hidden={self.total_hidden}, "
                f"block={self.block}, in={self.in_features}, out={self.out_features}, "
                f"acts={dict(by_act)})")
