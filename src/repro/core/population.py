"""Population descriptor: a heterogeneous collection of independent MLPs fused
into a single tensor layout (the paper's ParallelMLPs).

A population of P members, member ``m`` having ``hidden_sizes[m]`` hidden units
and activation ``activations[m]``, is laid out as one fused hidden axis of
``total_hidden`` units.  Every member's slice is padded up to a multiple of
``block`` so that, on TPU, each 128-lane tile belongs to exactly one member —
this is what turns the paper's scatter-add into a segment-blocked matmul
(DESIGN.md §2).  Padded units are masked to zero after activation, so they
receive zero gradient and the fused network is mathematically identical to the
P independent networks.

``Population`` is the PER-LAYER layout primitive: it owns the bucketing logic
(``size_buckets`` for the M3 output projection, ``pair_buckets`` for
block-diagonal layer→layer projections).  ``LayeredPopulation`` composes one
``Population`` per hidden layer into a deep population with HETEROGENEOUS
member depths (shallow members ride through later layers as exact identity
pass-throughs) and per-layer activations (DESIGN.md §3).

All layout quantities are static Python data (computed at trace time), so jit
sees them as compile-time constants; only the parameter/activation tensors are
traced.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import cached_property
from typing import Sequence

import numpy as np

from repro.core.activations import ACTIVATION_NAMES


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _instance_cache(method):
    """Memoise a method on the instance (``__dict__``, like cached_property —
    works on frozen dataclasses and dies with the instance; a process-global
    lru_cache would pin every layout ever constructed)."""
    name = method.__name__

    @functools.wraps(method)
    def wrapper(self, *args):
        cache = self.__dict__.setdefault("_method_cache", {})
        key = (name, args)
        if key not in cache:
            cache[key] = method(self, *args)
        return cache[key]
    return wrapper


@dataclasses.dataclass(frozen=True)
class Population:
    """Static description of a fused population of independent MLPs.

    Members are stored in the order given; callers that want efficient sliced
    activation application should construct with ``sort_members=True`` (groups
    members by activation so each activation is applied to one contiguous
    slice).
    """

    in_features: int
    out_features: int
    hidden_sizes: tuple
    activations: tuple  # activation *names*, one per member
    block: int = 1      # hidden-slice alignment (128 for TPU kernels)

    def __post_init__(self):
        if len(self.hidden_sizes) != len(self.activations):
            raise ValueError(
                f"hidden_sizes ({len(self.hidden_sizes)}) and activations "
                f"({len(self.activations)}) must have the same length")
        for a in self.activations:
            if a not in ACTIVATION_NAMES:
                raise ValueError(f"unknown activation {a!r}; "
                                 f"known: {sorted(ACTIVATION_NAMES)}")
        for h in self.hidden_sizes:
            if h < 1:
                raise ValueError(f"hidden size must be >= 1, got {h}")
        if self.block < 1:
            raise ValueError("block must be >= 1")
        # normalise to tuples (allows list inputs)
        object.__setattr__(self, "hidden_sizes", tuple(int(h) for h in self.hidden_sizes))
        object.__setattr__(self, "activations", tuple(self.activations))

    # ------------------------------------------------------------------ #
    # constructors                                                       #
    # ------------------------------------------------------------------ #
    @staticmethod
    def grid(in_features: int, out_features: int,
             hidden_range: Sequence[int], activations: Sequence[str],
             repeats: int = 1, block: int = 1,
             sort_members: bool = True, sort_by: str = "act") -> "Population":
        """The paper's experimental design: every (hidden size × activation)
        pair, repeated ``repeats`` times.  hidden 1..100 × 10 activations ×
        10 repeats = the paper's 10,000 models."""
        sizes, acts = [], []
        for a in activations:
            for h in hidden_range:
                for _ in range(repeats):
                    sizes.append(h)
                    acts.append(a)
        pop = Population(in_features, out_features, tuple(sizes), tuple(acts),
                         block=block)
        return pop.sorted(sort_by) if sort_members else pop

    def sorted(self, by: str = "act") -> "Population":
        """Reorder members so fused ops touch contiguous slices.

        by="act"  — (activation, size): one activation run per function
                    (best when activation dispatch dominates; default).
        by="size" — (padded size, activation): one M3 bucket per size CLASS,
                    merging buckets across activations — at block=8 the
                    paper grid collapses 130 bucket einsums to 13 while
                    keeping tight padding (§Perf hillclimb, paper cell)."""
        if by == "act":
            key = lambda m: (self.activations[m], self.hidden_sizes[m])
        elif by == "size":
            key = lambda m: (_round_up(self.hidden_sizes[m], self.block),
                             self.activations[m], self.hidden_sizes[m])
        else:
            raise ValueError(by)
        order = sorted(range(self.num_members), key=key)
        return dataclasses.replace(
            self,
            hidden_sizes=tuple(self.hidden_sizes[m] for m in order),
            activations=tuple(self.activations[m] for m in order),
        )

    # ------------------------------------------------------------------ #
    # layout (all static numpy, computed once)                           #
    # ------------------------------------------------------------------ #
    @property
    def num_members(self) -> int:
        return len(self.hidden_sizes)

    @cached_property
    def padded_sizes(self) -> np.ndarray:
        """Per-member hidden size rounded up to ``block``. shape (P,)."""
        return np.array([_round_up(h, self.block) for h in self.hidden_sizes],
                        dtype=np.int32)

    @cached_property
    def offsets(self) -> np.ndarray:
        """Start offset of member m's slice in the fused hidden axis. (P+1,)."""
        return np.concatenate([[0], np.cumsum(self.padded_sizes)]).astype(np.int32)

    @property
    def total_hidden(self) -> int:
        return int(self.offsets[-1])

    @cached_property
    def segment_ids(self) -> np.ndarray:
        """Member id for every fused hidden unit. shape (total_hidden,)."""
        return np.repeat(np.arange(self.num_members, dtype=np.int32),
                         self.padded_sizes)

    @cached_property
    def hidden_mask(self) -> np.ndarray:
        """1.0 for real hidden units, 0.0 for alignment padding. (total_hidden,)."""
        mask = np.zeros(self.total_hidden, dtype=np.float32)
        for m in range(self.num_members):
            mask[self.offsets[m]: self.offsets[m] + self.hidden_sizes[m]] = 1.0
        return mask

    @cached_property
    def act_ids(self) -> np.ndarray:
        """Activation id (index into ACTIVATION_NAMES order used by
        activations.apply_*) for every fused hidden unit. (total_hidden,)."""
        names = sorted(ACTIVATION_NAMES)
        lut = {n: i for i, n in enumerate(names)}
        per_member = np.array([lut[a] for a in self.activations], dtype=np.int32)
        return np.repeat(per_member, self.padded_sizes)

    @cached_property
    def act_runs(self):
        """Contiguous runs of identical activation: list of
        (act_name, start, stop) covering [0, total_hidden).  One run per
        activation if the population is sorted."""
        runs = []
        seg_acts = [self.activations[m] for m in range(self.num_members)]
        start = 0
        m = 0
        while m < self.num_members:
            a = seg_acts[m]
            stop_m = m
            while stop_m + 1 < self.num_members and seg_acts[stop_m + 1] == a:
                stop_m += 1
            stop = int(self.offsets[stop_m + 1])
            runs.append((a, start, stop))
            start = stop
            m = stop_m + 1
        return runs

    @cached_property
    def member_fan_in(self) -> np.ndarray:
        """Fan-in of the output layer per fused hidden unit (= its member's
        true hidden size); used for per-member init scaling. (total_hidden,)."""
        return np.repeat(np.array(self.hidden_sizes, dtype=np.float32),
                         self.padded_sizes)

    @cached_property
    def block_segment_ids(self) -> np.ndarray:
        """Member id per hidden *block* (total_hidden // block,).  Well defined
        because every member slice is block-aligned; this is the scalar-prefetch
        input of the Pallas segment-blocked matmul."""
        assert self.total_hidden % self.block == 0
        return self.segment_ids[:: self.block].copy()

    @cached_property
    def block_act_ids(self) -> np.ndarray:
        """Activation id per hidden block (scalar prefetch for seg_act)."""
        assert self.total_hidden % self.block == 0
        return self.act_ids[:: self.block].copy()

    # ------------------------------------------------------------------ #
    # bucketing primitives (shared by M3 and the block-diagonal layers)  #
    # ------------------------------------------------------------------ #
    @_instance_cache
    def size_buckets(self):
        """Contiguous runs of members with identical *padded* size.

        The M3 bucketed implementation reshapes each run to (B, n, hs) and
        batched-matmuls it.  ``Population.grid`` sorts by (activation, size),
        so runs are short; the general case still works, just with more
        buckets.  Returns static (start_member, n_members, padded_size,
        start_col) tuples.
        """
        out = []
        sizes = self.padded_sizes
        m = 0
        while m < self.num_members:
            n = 1
            while m + n < self.num_members and sizes[m + n] == sizes[m]:
                n += 1
            out.append((m, n, int(sizes[m]), int(self.offsets[m])))
            m += n
        return tuple(out)

    def pair_buckets(self, out_pop: "Population", keys: Sequence = None):
        """Contiguous runs of members with identical padded (in, out) widths
        for a block-diagonal ``self``→``out_pop`` projection (member m's units
        in ``out_pop`` contract ONLY member m's units in ``self``).

        ``keys`` (optional, one hashable per member) further splits runs —
        LayeredPopulation uses it to separate real projections from identity
        pass-throughs.  Returns static (start_member, n_members, padded_in,
        padded_out, in_offset, out_offset) tuples.
        """
        if out_pop.num_members != self.num_members:
            raise ValueError("pair_buckets: member count mismatch "
                             f"({self.num_members} vs {out_pop.num_members})")
        runs = []
        m = 0
        while m < self.num_members:
            n = 1
            key = (self.padded_sizes[m], out_pop.padded_sizes[m],
                   None if keys is None else keys[m])
            while m + n < self.num_members and \
                    (self.padded_sizes[m + n], out_pop.padded_sizes[m + n],
                     None if keys is None else keys[m + n]) == key:
                n += 1
            runs.append((m, n, int(key[0]), int(key[1]),
                         int(self.offsets[m]), int(out_pop.offsets[m])))
            m += n
        return tuple(runs)

    def member_slice(self, m: int) -> slice:
        """Slice of member m's REAL units (excludes padding)."""
        return slice(int(self.offsets[m]), int(self.offsets[m]) + self.hidden_sizes[m])

    def param_specs(self):
        """PartitionSpec tree matching ``parallel_mlp.init_params`` — every
        member-major axis (fused hidden, member) shards over the population
        axis; feature/class axes replicate.  Axes that the ambient mesh lacks
        or that don't divide degrade to replication via ``filter_spec``."""
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import POP_AXIS
        return {"w1": P(POP_AXIS, None), "b1": P(POP_AXIS),
                "w2": P(None, POP_AXIS), "b2": P(POP_AXIS, None)}

    def describe(self) -> str:
        import collections
        by_act = collections.Counter(self.activations)
        return (f"Population(P={self.num_members}, total_hidden={self.total_hidden}, "
                f"block={self.block}, in={self.in_features}, out={self.out_features}, "
                f"acts={dict(by_act)})")

    def layered(self) -> "LayeredPopulation":
        """This population as a depth-1 LayeredPopulation (same layout)."""
        return LayeredPopulation(
            self.in_features, self.out_features,
            tuple((h,) for h in self.hidden_sizes),
            tuple((a,) for a in self.activations), block=self.block)


# ---------------------------------------------------------------------- #
# layered populations (heterogeneous depths, per-layer activations)      #
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class BlockDiagLayout:
    """Static scalar-prefetch metadata for one block-diagonal l→l+1
    projection run as a single Pallas segment-blocked matmul
    (kernels/block_diag.py; DESIGN.md §3/§7).

    The fused weight is a flat array of (block × block) tiles, member-major,
    row-major over each member's (out_tile, in_tile) grid, with ONE shared
    identity tile appended at index ``n_param_blocks`` (used by pass-through
    members; it is not a parameter).

    The reduction is RAGGED (members have different fan-ins), so instead of
    a dense (out_tiles × k_max) grid — which wastes a clamped re-read on
    every tile whose fan-in is below the maximum — the kernel runs one grid
    step per REAL (output tile, reduction k) pair: step ``s`` reads input
    tile ``s_in[s]`` against weight tile ``s_w[s]`` and accumulates into
    output tile ``s_out[s]``; ``s_first/s_last`` flag the accumulator
    init/flush edges of each output tile's (consecutive) run.  ``n_steps``
    is exactly the number of MXU tiles of work — no dead grid points.
    The ``*_t`` fields describe the TRANSPOSED projection (used for dh in
    the custom VJP), and ``wb_out_tile/wb_in_tile`` map each parameter tile
    to its (dy, h) tile pair for the dw kernel.
    """
    block: int
    n_in_tiles: int
    n_out_tiles: int
    n_param_blocks: int
    n_steps: int
    s_in: tuple
    s_w: tuple
    s_out: tuple
    s_first: tuple
    s_last: tuple
    n_steps_t: int
    s_in_t: tuple
    s_w_t: tuple
    s_out_t: tuple
    s_first_t: tuple
    s_last_t: tuple
    s_q_t: tuple         # param tile touched at each transposed step (the
                         # dw target of the one-pass fused backward;
                         # n_param_blocks = the discarded dummy slot)
    perm_t: tuple        # WB_aug permutation building the transposed tiles
    wb_out_tile: tuple   # per parameter tile
    wb_in_tile: tuple


def _normalise_member_acts(acts, depth_m: int, member: int):
    if isinstance(acts, str):
        acts = (acts,) * depth_m
    acts = tuple(acts)
    if len(acts) != depth_m:
        raise ValueError(
            f"member {member}: {len(acts)} activations for depth {depth_m}")
    for a in acts:
        if a not in ACTIVATION_NAMES:
            raise ValueError(f"unknown activation {a!r}; "
                             f"known: {sorted(ACTIVATION_NAMES)}")
    return acts


@dataclasses.dataclass(frozen=True)
class LayeredPopulation:
    """P independent deep MLPs with HETEROGENEOUS depths fused into one
    layered layout.

    ``widths[m]`` is member m's per-hidden-layer width tuple (any length ≥ 1);
    ``activations[m]`` is either one name (used for every layer) or a tuple of
    names, one per hidden layer.  The population depth is the maximum member
    depth; a member of depth d < depth occupies, in every layer l ≥ d, a slice
    of its FINAL width that is carried through unchanged (identity weight, no
    bias, identity activation) — an exact structural pass-through, so fused
    training of mixed-depth members equals standalone training (DESIGN.md §3).
    """

    in_features: int
    out_features: int
    widths: tuple          # tuple[tuple[int, ...]] — per member, per layer
    activations: tuple     # tuple[tuple[str, ...]] — per member, per layer
    block: int = 8
    n_pad: int = 0         # trailing shard-pad members (see shard_pad)

    def __post_init__(self):
        if len(self.widths) != len(self.activations):
            raise ValueError(
                f"widths ({len(self.widths)}) and activations "
                f"({len(self.activations)}) must have the same length")
        if not self.widths:
            raise ValueError("empty population")
        if not 0 <= self.n_pad < len(self.widths):
            raise ValueError(f"n_pad {self.n_pad} out of range "
                             f"[0, {len(self.widths)})")
        widths = tuple(tuple(int(h) for h in w) for w in self.widths)
        for m, w in enumerate(widths):
            if len(w) < 1:
                raise ValueError(f"member {m}: needs at least one hidden layer")
            for h in w:
                if h < 1:
                    raise ValueError(f"member {m}: hidden size must be >= 1")
        acts = tuple(_normalise_member_acts(a, len(w), m)
                     for m, (a, w) in enumerate(zip(self.activations, widths)))
        object.__setattr__(self, "widths", widths)
        object.__setattr__(self, "activations", acts)

    # ------------------------------------------------------------------ #
    # constructors                                                       #
    # ------------------------------------------------------------------ #
    @staticmethod
    def grid(in_features: int, out_features: int,
             layer_widths: Sequence[Sequence[int]],
             activations: Sequence[str], repeats: int = 1, block: int = 8,
             sort_members: bool = True) -> "LayeredPopulation":
        """Architecture-search grid: every widths-tuple × activation pair,
        repeated — the deep generalisation of ``Population.grid`` (the paper's
        §7 pool of deep candidates).  ``layer_widths`` entries may have
        different lengths (heterogeneous depths)."""
        widths, acts = [], []
        for a in activations:
            for w in layer_widths:
                for _ in range(repeats):
                    widths.append(tuple(int(h) for h in w))
                    acts.append(a)
        lp = LayeredPopulation(in_features, out_features, tuple(widths),
                               tuple(acts), block=block)
        return lp.sorted() if sort_members else lp

    def sorted(self) -> "LayeredPopulation":
        """Reorder members so equal-shape members are contiguous: buckets per
        projection collapse to one run per (depth, padded widths, acts)
        class.  Shard-pad members stay trailing (their position is part of
        the sharding contract — callers exclude them by slicing [-n_pad:])."""
        def key(m):
            return (len(self.widths[m]),
                    tuple(_round_up(h, self.block) for h in self.widths[m]),
                    self.activations[m], self.widths[m])
        n_real = self.num_members - self.n_pad
        order = sorted(range(n_real), key=key) + list(
            range(n_real, self.num_members))
        return dataclasses.replace(
            self,
            widths=tuple(self.widths[m] for m in order),
            activations=tuple(self.activations[m] for m in order))

    # ------------------------------------------------------------------ #
    # per-layer layouts                                                  #
    # ------------------------------------------------------------------ #
    @property
    def num_members(self) -> int:
        return len(self.widths)

    @property
    def num_real(self) -> int:
        """Members that exist in the user's population (excludes trailing
        shard-pad filler members)."""
        return self.num_members - self.n_pad

    @cached_property
    def member_depths(self) -> tuple:
        return tuple(len(w) for w in self.widths)

    @property
    def depth(self) -> int:
        return max(self.member_depths)

    def layer_width(self, m: int, l: int) -> int:
        """Member m's width at layer l (its final width once passed-through)."""
        return self.widths[m][min(l, self.member_depths[m] - 1)]

    def layer_act(self, m: int, l: int) -> str:
        """Member m's activation at layer l (identity once passed-through)."""
        return self.activations[m][l] if l < self.member_depths[m] else "identity"

    @_instance_cache
    def layer_pop(self, l: int) -> Population:
        """The fused per-layer layout of hidden layer l (member order
        preserved; pass-through members keep their final-layer slot)."""
        if not 0 <= l < self.depth:
            raise ValueError(f"layer {l} out of range [0, {self.depth})")
        return Population(self.in_features, self.out_features,
                          tuple(self.layer_width(m, l)
                                for m in range(self.num_members)),
                          tuple(self.layer_act(m, l)
                                for m in range(self.num_members)),
                          block=self.block)

    def proj_real(self, m: int, l: int) -> bool:
        """True iff member m has a REAL weight in projection l (layer l→l+1)."""
        return l + 1 < self.member_depths[m]

    @_instance_cache
    def proj_buckets(self, l: int):
        """Buckets of projection l: (m0, n, hin, hout, off_in, off_out, real)
        runs, where ``real`` marks trained weight blocks vs identity
        pass-throughs (hin == hout there by construction).  Shard-pad
        members never merge into a real member's bucket (the pad flag is
        part of the run key), so the REAL buckets — runs, shapes, order —
        are identical with and without padding: ``pad_params`` can embed an
        unpadded parameter tree leaf-for-leaf."""
        pin, pout = self.layer_pop(l), self.layer_pop(l + 1)
        flags = tuple((self.proj_real(m, l), m >= self.num_real)
                      for m in range(self.num_members))
        return tuple(run + (flags[run[0]][0],)
                     for run in pin.pair_buckets(pout, keys=flags))

    @_instance_cache
    def active_unit_mask(self, l: int) -> np.ndarray:
        """1.0 for fused units of layer l belonging to members whose layer l
        is REAL (depth > l), 0.0 for pass-through slices.  Gates the mid-layer
        bias so pass-through members receive no bias (and no bias gradient)."""
        pop = self.layer_pop(l)
        mask = np.zeros(pop.total_hidden, dtype=np.float32)
        for m in range(self.num_members):
            if self.member_depths[m] > l:
                mask[pop.offsets[m]: pop.offsets[m + 1]] = 1.0
        return mask

    @_instance_cache
    def bd_layout(self, l: int) -> BlockDiagLayout:
        """Scalar-prefetch metadata for running projection l as ONE Pallas
        segment-blocked matmul (see BlockDiagLayout)."""
        pin, pout = self.layer_pop(l), self.layer_pop(l + 1)
        blk = self.block
        P = self.num_members
        ib = (pin.padded_sizes // blk).astype(int)
        ob = (pout.padded_sizes // blk).astype(int)
        in_t0 = (pin.offsets // blk).astype(int)
        out_t0 = (pout.offsets // blk).astype(int)
        real = [self.proj_real(m, l) for m in range(P)]

        base = np.zeros(P, dtype=int)
        acc = 0
        for m in range(P):
            base[m] = acc
            if real[m]:
                acc += ob[m] * ib[m]
        n_param = acc
        ident = n_param                       # shared identity tile (appended)

        n_out_tiles = int(out_t0[P])
        n_in_tiles = int(in_t0[P])

        def ragged_steps(transposed: bool):
            """Flattened (output tile, reduction k) step arrays: one grid
            step per REAL MXU tile of work (the ragged-grid fix — no dead
            k steps for narrow members or pass-through tiles).  ``qs`` maps
            each step to the PARAM tile whose (du, x) pair is live at that
            step (ident = the discarded dummy slot for pass-through) — the
            transposed orientation's qs is what lets the fused backward
            emit dw in the same pass as dx."""
            s_in, s_w, s_out, first, last, qs = [], [], [], [], [], []
            for m in range(P):
                n_o, n_i = (ib[m], ob[m]) if transposed else (ob[m], ib[m])
                rd0 = (out_t0 if transposed else in_t0)[m]
                wr0 = (in_t0 if transposed else out_t0)[m]
                for r in range(n_o):
                    t = wr0 + r
                    if real[m]:
                        for k in range(n_i):
                            s_in.append(rd0 + k)
                            s_w.append(base[m] + r * n_i + k)
                            s_out.append(t)
                            first.append(1 if k == 0 else 0)
                            last.append(1 if k == n_i - 1 else 0)
                            qs.append(base[m] + (k * n_o + r if transposed
                                                 else r * n_i + k))
                    else:
                        s_in.append(rd0 + r)
                        s_w.append(ident)
                        s_out.append(t)
                        first.append(1)
                        last.append(1)
                        qs.append(ident)
            return s_in, s_w, s_out, first, last, qs

        s_in, s_w, s_out, s_first, s_last, _ = ragged_steps(False)
        (s_in_t, s_w_t, s_out_t, s_first_t, s_last_t,
         s_q_t) = ragged_steps(True)

        perm = np.zeros(n_param + 1, int)
        perm[n_param] = n_param
        wb_out_tile = np.zeros(n_param, int)
        wb_in_tile = np.zeros(n_param, int)
        for m in range(P):
            if real[m]:
                for r in range(ob[m]):
                    for c in range(ib[m]):
                        q = base[m] + r * ib[m] + c
                        perm[base[m] + c * ob[m] + r] = q
                        wb_out_tile[q] = out_t0[m] + r
                        wb_in_tile[q] = in_t0[m] + c

        ints = lambda a: tuple(int(v) for v in a)
        return BlockDiagLayout(
            block=blk, n_in_tiles=n_in_tiles, n_out_tiles=n_out_tiles,
            n_param_blocks=n_param,
            n_steps=len(s_out), s_in=ints(s_in), s_w=ints(s_w),
            s_out=ints(s_out), s_first=ints(s_first), s_last=ints(s_last),
            n_steps_t=len(s_out_t), s_in_t=ints(s_in_t), s_w_t=ints(s_w_t),
            s_out_t=ints(s_out_t), s_first_t=ints(s_first_t),
            s_last_t=ints(s_last_t), s_q_t=ints(s_q_t),
            perm_t=ints(perm),
            wb_out_tile=ints(wb_out_tile), wb_in_tile=ints(wb_in_tile))

    # ------------------------------------------------------------------ #
    # sharding (DESIGN.md §5: the population axis IS the 'model' axis)   #
    # ------------------------------------------------------------------ #
    def shard_pad(self, n_shards: int) -> "LayeredPopulation":
        """Append filler members so the layout divides an ``n_shards``-way
        population axis: member count ≡ 0 (mod n_shards) and every layer's
        fused hidden axis ≡ 0 (mod n_shards·block), i.e. each shard holds
        whole member-aligned blocks.  Fillers are depth-``depth`` identity-
        activation members appended AFTER the real members (trailing, so
        member-major arrays slice them off with [:num_real]); they train but
        are excluded from selection.  Idempotent when already divisible.

        Per-bucket member counts are NOT forced to divide — a bucket whose
        run doesn't split evenly degrades to replication through
        ``filter_spec`` (the documented fallback)."""
        if n_shards <= 1:
            return self
        blk, L = self.block, self.depth
        hidden = [self.layer_pop(l).total_hidden for l in range(L)]
        mod = n_shards * blk
        d = (-self.num_members) % n_shards
        if d == 0 and all(h % mod == 0 for h in hidden):
            return self
        if d == 0:
            d = n_shards          # hidden axes still need fixing
        # d-1 minimal (width=block) fillers; the LAST filler's per-layer
        # width absorbs each layer's remaining misalignment.  Solvable
        # because every quantity involved is a multiple of block.
        base = ((blk,) * L,) * (d - 1)
        last = []
        for l in range(L):
            h = hidden[l] + (d - 1) * blk
            c = 1
            while (h + c * blk) % mod:
                c += 1
                assert c <= mod // blk + 1, "shard_pad: no aligning width"
            last.append(c * blk)
        widths = self.widths + base + (tuple(last),)
        acts = self.activations + (("identity",) * L,) * d
        return dataclasses.replace(self, widths=widths, activations=acts,
                                   n_pad=self.n_pad + d)

    def _sort_key(self, m: int):
        """The member-ordering key ``sorted()`` uses — exposed so growth
        can insert new members at their sorted-merge position."""
        return (len(self.widths[m]),
                tuple(_round_up(h, self.block) for h in self.widths[m]),
                self.activations[m], self.widths[m])

    def grow_positions(self, widths, activations) -> tuple:
        """Insert positions (strictly increasing indices into the GROWN
        layout) that place each new ``(widths, activations)`` member at its
        sorted-merge slot: after every existing member whose sort key is <=
        its own, so a sorted layout stays sorted after :meth:`grow` and
        equal-shape buckets merge instead of fragmenting.  Relative order
        among equal-key new members follows the given order (stable).  If
        the existing real members are NOT sorted, new members simply append
        at the end (still a valid grow — just more buckets)."""
        acts = tuple(_normalise_member_acts(a, len(tuple(w)), j)
                     for j, (w, a) in enumerate(zip(widths, activations)))
        widths = tuple(tuple(int(h) for h in w) for w in widths)
        old_keys = [self._sort_key(m) for m in range(self.num_real)]
        if any(old_keys[i] > old_keys[i + 1]
               for i in range(len(old_keys) - 1)):
            return tuple(self.num_real + j for j in range(len(widths)))

        def key(j):
            return (len(widths[j]),
                    tuple(_round_up(h, self.block) for h in widths[j]),
                    acts[j], widths[j])
        order = sorted(range(len(widths)), key=key)
        positions = [0] * len(widths)
        oi = 0                      # old members already passed
        placed = 0                  # new members already placed
        for j in order:
            while oi < len(old_keys) and old_keys[oi] <= key(j):
                oi += 1
            positions[j] = oi + placed
            placed += 1
        return tuple(positions)

    def grow(self, widths, activations, positions) -> "LayeredPopulation":
        """Fresh layout with new REAL members spliced in — the inverse of
        :meth:`subset` and the lifecycle's slot-refill primitive
        (core/lifecycle.py; DESIGN.md §13).

        ``positions[j]`` is the index INTO THE RESULT where new member ``j``
        lands (``grow_positions`` computes the sorted-merge placement);
        positions must be distinct but may pair new members in any order.
        Surviving members fill the complement in order, so
        ``grown.subset(complement) == self`` — grow-then-compact round-trips
        bit-exactly.  Growth happens on the REAL layout only (``n_pad`` must
        be 0 — compact first, grow, then re-``shard_pad``); the population
        depth extends automatically when a new member is deeper than every
        existing one (existing members ride the added layers as identity
        pass-throughs, exactly mirroring subset's depth shrink)."""
        if self.n_pad:
            raise ValueError(
                "grow: layout carries shard-pad fillers; grow the real "
                "layout (compact / subset first), then shard_pad the result")
        widths = tuple(tuple(int(h) for h in w) for w in widths)
        acts = tuple(_normalise_member_acts(a, len(w), j)
                     for j, (w, a) in enumerate(zip(widths, activations)))
        if len(widths) != len(acts) or not widths:
            raise ValueError("grow: need at least one new member, with one "
                             "activation spec per member")
        positions = tuple(int(p) for p in positions)
        if len(positions) != len(widths):
            raise ValueError(
                f"grow: {len(positions)} positions for {len(widths)} new "
                "members")
        n_total = self.num_real + len(widths)
        for p in positions:
            if not 0 <= p < n_total:
                raise ValueError(
                    f"grow: position {p} out of range [0, {n_total})")
        if len(set(positions)) != len(positions):
            raise ValueError(f"grow: duplicate positions in {positions}")
        pos_map = dict(zip(positions, range(len(widths))))
        out_w, out_a = [], []
        oi = 0
        for m in range(n_total):
            if m in pos_map:
                out_w.append(widths[pos_map[m]])
                out_a.append(acts[pos_map[m]])
            else:
                out_w.append(self.widths[oi])
                out_a.append(self.activations[oi])
                oi += 1
        return LayeredPopulation(self.in_features, self.out_features,
                                 tuple(out_w), tuple(out_a),
                                 block=self.block)

    def subset(self, keep) -> "LayeredPopulation":
        """Fresh layout of the given REAL members only — the lifecycle's
        compaction primitive (core/lifecycle.py; DESIGN.md §6).

        ``keep`` must be strictly increasing indices into the real members
        (shard-pad fillers cannot survive a rung; re-pad the result with
        ``shard_pad``).  Relative member order is preserved, so a sorted
        layout stays sorted and every derived quantity (offsets, buckets,
        bd_layout) is simply re-derived for the survivors: equal-shape runs
        that were split by a pruned member merge back into one bucket.  The
        population depth shrinks automatically when the deepest members are
        pruned (survivors were pass-through in the dropped layers, so the
        truncation is exact)."""
        keep = tuple(int(m) for m in keep)
        if not keep:
            raise ValueError("subset: empty keep set")
        prev = -1
        for m in keep:
            if not 0 <= m < self.num_real:
                raise ValueError(
                    f"subset: member {m} out of range [0, {self.num_real}) "
                    "(shard-pad fillers cannot survive)")
            if m <= prev:
                raise ValueError(
                    "subset: keep indices must be strictly increasing, got "
                    f"{keep}")
            prev = m
        return LayeredPopulation(
            self.in_features, self.out_features,
            tuple(self.widths[m] for m in keep),
            tuple(self.activations[m] for m in keep), block=self.block)

    def param_specs(self):
        """PartitionSpec tree matching ``deep.init_params``: every
        member-major axis shards over the population axis —

          w_in  (H0, F)        → P(pop, None)     b_in (H0,)   → P(pop)
          mid[l] w buckets (n, h_out, h_in) → P(pop, None, None) each
          mid[l] b (H_{l+1},)  → P(pop)
          w_out (O, H_last)    → P(None, pop)     b_out (P, O) → P(pop, None)

        Axes the ambient mesh lacks, or whose dim doesn't divide (e.g. a
        bucket run shorter than the axis), degrade to replication via
        ``filter_spec``; ``shard_pad`` makes the fused-hidden and member
        dims divide by construction."""
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import POP_AXIS
        mid = []
        for l in range(self.depth - 1):
            n_real_buckets = sum(1 for bk in self.proj_buckets(l) if bk[6])
            mid.append({"w": [P(POP_AXIS, None, None)] * n_real_buckets,
                        "b": P(POP_AXIS)})
        return {"w_in": P(POP_AXIS, None), "b_in": P(POP_AXIS), "mid": mid,
                "w_out": P(None, POP_AXIS), "b_out": P(POP_AXIS, None)}

    def opt_specs(self, opt, dtype=None):
        """Optimizer-state PartitionSpec tree for training this layout with
        ``opt`` (a ``repro.optim.Optimizer``): every state leaf inherits the
        sharding of the parameter it tracks."""
        import jax.numpy as jnp

        from repro.core.deep import abstract_params
        return opt.state_specs(
            self.param_specs(),
            abstract_params(self, dtype or jnp.float32))

    def describe(self) -> str:
        import collections
        by_depth = collections.Counter(self.member_depths)
        pad = f", pad={self.n_pad}" if self.n_pad else ""
        return (f"LayeredPopulation(P={self.num_members}{pad}, depth={self.depth}, "
                f"block={self.block}, in={self.in_features}, "
                f"out={self.out_features}, depths={dict(sorted(by_depth.items()))}, "
                f"fused_hidden={[self.layer_pop(l).total_hidden for l in range(self.depth)]})")
