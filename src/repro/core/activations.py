"""The paper's ten activation functions and three strategies for applying a
*different* activation to different column slices of a fused hidden tensor.

Strategies (cross-validated against each other in tests):
  * ``apply_activations_sliced``  — static contiguous slices, one pass per run
    (efficient when the population is sorted by activation; what XLA fuses best).
  * ``apply_activations_masked``  — branchless select over all 10 functions
    (the paper's masking strawman; used as oracle).
  * kernels/seg_act.py            — Pallas tile-wise ``lax.switch`` on a
    scalar-prefetched activation id (one read per tile; TPU-native).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------- #
# the 10 paper activations                                               #
# ---------------------------------------------------------------------- #

def _identity(x):
    return x

def _sigmoid(x):
    return jax.nn.sigmoid(x)

def _tanh(x):
    return jnp.tanh(x)

def _relu(x):
    return jax.nn.relu(x)

def _elu(x):
    return jax.nn.elu(x)

def _selu(x):
    return jax.nn.selu(x)

def _gelu(x):
    return jax.nn.gelu(x, approximate=False)

def _leaky_relu(x):
    return jax.nn.leaky_relu(x)  # slope 0.01, torch default

def _hardshrink(x, lambd: float = 0.5):
    return jnp.where((x > lambd) | (x < -lambd), x, jnp.zeros_like(x))

def _mish(x):
    return x * jnp.tanh(jax.nn.softplus(x))


ACTIVATIONS = {
    "identity": _identity,
    "sigmoid": _sigmoid,
    "tanh": _tanh,
    "relu": _relu,
    "elu": _elu,
    "selu": _selu,
    "gelu": _gelu,
    "leaky_relu": _leaky_relu,
    "hardshrink": _hardshrink,
    "mish": _mish,
}
ACTIVATION_NAMES = frozenset(ACTIVATIONS)
# canonical id order — shared with Population.act_ids and the Pallas kernel
ACTIVATION_ORDER = tuple(sorted(ACTIVATIONS))
ACTIVATION_FNS = tuple(ACTIVATIONS[n] for n in ACTIVATION_ORDER)
PAPER_TEN = ("identity", "sigmoid", "tanh", "relu", "elu", "selu", "gelu",
             "leaky_relu", "hardshrink", "mish")


# ---------------------------------------------------------------------- #
# segmented application                                                  #
# ---------------------------------------------------------------------- #

def apply_activations_sliced(h: jax.Array, runs) -> jax.Array:
    """Apply per-run activations to contiguous column slices.

    ``runs`` is ``Population.act_runs``: static (name, start, stop) triples.
    One elementwise pass per run; with a sorted population that's at most 10
    passes, each over a disjoint slice (total work = one pass over ``h``).
    """
    pieces = [ACTIVATIONS[name](h[..., start:stop]) for name, start, stop in runs]
    return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=-1)


def apply_activations_masked(h: jax.Array, act_ids: np.ndarray) -> jax.Array:
    """Branchless: evaluate all 10 activations everywhere, select by id.
    10x elementwise flops (cheap next to the matmuls) — serves as the oracle
    and as the fallback when the population is not sorted."""
    ids = jnp.asarray(act_ids)
    out = jnp.zeros_like(h)
    for i, fn in enumerate(ACTIVATION_FNS):
        out = jnp.where(ids == i, fn(h), out)
    return out
