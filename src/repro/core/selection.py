"""Model selection over a trained population (paper §5: "perform model
selection in the large pool of trained MLPs")."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.parallel_mlp import extract_member, forward, member_accuracy, member_losses
from repro.core.population import Population


def evaluate_population(params, pop: Population, x, targets,
                        task: str = "classification", batch_size: int = 4096,
                        **fw):
    """Per-member metric over a full eval split (batched to bound memory).

    Returns (losses (P,), accuracies (P,) or None)."""
    n = x.shape[0]
    loss_sum = jnp.zeros(pop.num_members)
    acc_sum = jnp.zeros(pop.num_members)
    seen = 0
    for i in range(0, n, batch_size):
        xb, tb = x[i:i + batch_size], targets[i:i + batch_size]
        logits = forward(params, xb, pop, **fw)
        loss_sum = loss_sum + member_losses(logits, tb, task) * xb.shape[0]
        if task == "classification":
            acc_sum = acc_sum + member_accuracy(logits, tb) * xb.shape[0]
        seen += xb.shape[0]
    losses = loss_sum / seen
    accs = acc_sum / seen if task == "classification" else None
    return losses, accs


def select_best(params, pop: Population, losses) -> tuple[int, dict]:
    """Best member by eval loss → (index, standalone params)."""
    m = int(jnp.argmin(losses))
    return m, extract_member(params, pop, m)


def leaderboard(pop: Population, losses, accs=None, k: int = 10):
    """Top-k members as (rank, member, hidden, activation, loss[, acc])."""
    import numpy as np
    order = np.argsort(np.asarray(losses))[:k]
    rows = []
    for r, m in enumerate(order):
        row = dict(rank=r + 1, member=int(m), hidden=pop.hidden_sizes[m],
                   activation=pop.activations[m], loss=float(losses[m]))
        if accs is not None:
            row["acc"] = float(accs[m])
        rows.append(row)
    return rows
