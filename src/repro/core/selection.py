"""Model selection over a trained population (paper §5: "perform model
selection in the large pool of trained MLPs").

Works over BOTH layouts — the single-layer ``Population`` and the layered
engine's ``LayeredPopulation`` — dispatching forward/extract to the matching
module, so architecture search over mixed-depth pools uses the same three
calls (evaluate → select → leaderboard) as the paper's single-layer grid.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import deep as _deep
from repro.core import parallel_mlp as _pmlp
from repro.core.parallel_mlp import member_accuracy, member_losses
from repro.core.population import LayeredPopulation, Population


def _forward(params, x, layout, **fw):
    if isinstance(layout, LayeredPopulation):
        return _deep.forward(params, x, layout, **fw)
    if fw.pop("infer", False):
        raise ValueError("infer=True eval routes through the layered "
                         "engine — single-layer Population has no "
                         "forward-only kernel path")
    return _pmlp.forward(params, x, layout, **fw)


def extract_member(params, layout, m: int) -> dict:
    """Standalone params of member m, whichever layout trained them."""
    if isinstance(layout, LayeredPopulation):
        return _deep.extract_member(params, layout, m)
    return _pmlp.extract_member(params, layout, m)


_DICT_TAG = "__dict__"


def _freeze_kwargs(fw: dict) -> tuple:
    """Forward kwargs → hashable jit-static key (dict values — bd_kwargs /
    m3_kwargs — become tagged item tuples)."""
    return tuple(sorted(
        (k, (_DICT_TAG, tuple(sorted(v.items())))
         if isinstance(v, dict) else v)
        for k, v in fw.items()))


def _thaw_kwargs(fw: tuple) -> dict:
    return {k: dict(v[1])
            if isinstance(v, tuple) and v and v[0] == _DICT_TAG else v
            for k, v in fw}


@partial(jax.jit, static_argnames=("pop", "task", "fw"))
def _eval_batch(params, xb, tb, pop, task, fw):
    """One jitted eval batch under the training sharding (cached across
    ``evaluate_population`` calls on the jit cache — layouts are static
    hashable dataclasses, exactly like ``deep.sgd_step``)."""
    from repro.distributed.sharding import POP_LOGITS, POP_MEMBER, constrain
    logits = constrain(_forward(params, xb, pop, **_thaw_kwargs(fw)),
                       POP_LOGITS)
    loss = constrain(member_losses(logits, tb, task), POP_MEMBER)
    acc = (constrain(member_accuracy(logits, tb), POP_MEMBER)
           if task == "classification" else jnp.zeros_like(loss))
    return loss, acc


def evaluate_population(params, pop, x, targets,
                        task: str = "classification", batch_size: int = 4096,
                        **fw):
    """Per-member metric over a full eval split (batched to bound memory).

    Runs under the TRAINING sharding: the jitted eval step consumes the
    sharded parameter tree as-is and constrains logits / per-member
    reductions to the population axis (no-op off-mesh), so selection over a
    mesh-sharded population never gathers the fused tensors to one device.

    Forward kwargs pass straight through to ``deep.forward`` — in
    particular ``infer=True`` (with ``bd_impl="fused"``) runs the whole
    eval on the forward-only serving kernels (DESIGN.md §10): no residual
    buffers, depth+1 launches per batch, identical metrics to f32
    tolerance.  That is how the serving engine scores members for its
    published set without ever touching the training kernels.

    Returns (losses (P,), accuracies (P,) or None)."""
    fw_key = _freeze_kwargs(fw)
    n = x.shape[0]
    loss_sum = jnp.zeros(pop.num_members)
    acc_sum = jnp.zeros(pop.num_members)
    seen = 0
    for i in range(0, n, batch_size):
        xb, tb = x[i:i + batch_size], targets[i:i + batch_size]
        loss, acc = _eval_batch(params, xb, tb, pop, task, fw_key)
        loss_sum = loss_sum + loss * xb.shape[0]
        acc_sum = acc_sum + acc * xb.shape[0]
        seen += xb.shape[0]
    losses = loss_sum / seen
    accs = acc_sum / seen if task == "classification" else None
    return losses, accs


def _num_real(pop) -> int:
    """Members eligible for selection (shard-pad fillers are excluded)."""
    return getattr(pop, "num_real", pop.num_members)


def select_best(params, pop, losses) -> tuple[int, dict]:
    """Best member by eval loss → (index, standalone params).  Shard-pad
    filler members (trailing, ``LayeredPopulation.n_pad``) never win."""
    m = int(jnp.argmin(losses[:_num_real(pop)]))
    return m, extract_member(params, pop, m)


def _member_arch(pop, m: int):
    if isinstance(pop, LayeredPopulation):
        return pop.widths[m], "/".join(dict.fromkeys(pop.activations[m]))
    return pop.hidden_sizes[m], pop.activations[m]


def _check_member_ids(member_ids, nr: int):
    """Validate a survivor→original id mapping: one entry per real member
    and NO duplicates — a member born at rung r must never alias a pruned
    seed's id (the refill driver issues fresh ids from a monotone counter;
    a duplicate here means that invariant broke upstream)."""
    import numpy as np
    if len(member_ids) != nr:
        raise ValueError(f"member_ids has {len(member_ids)} entries for "
                         f"{nr} real members")
    ids = np.asarray(member_ids)
    if len(np.unique(ids)) != len(ids):
        dup = sorted(int(i) for i in ids[
            np.isin(ids, ids[np.concatenate(
                ([False], np.diff(np.sort(ids)) == 0))])])
        raise ValueError(f"member_ids contains duplicate original ids "
                         f"{sorted(set(dup))} — a refilled member is "
                         "aliasing a pruned member's id")


def _lineage_entry(lineage, member_id: int):
    """``lineage``: optional {original id → (parent id, birth rung)} from
    the refill controller; seeds (absent keys) report parent -1, rung 0."""
    if lineage is None:
        return None
    parent, born = lineage.get(int(member_id), (-1, 0))
    return {"member": int(member_id), "parent": int(parent),
            "born_rung": int(born)}


def leaderboard(pop, losses, accs=None, k: int = 10, member_ids=None,
                sort_by: str = "loss", lineage=None):
    """Top-k members as (rank, member, hidden, activation, loss[, acc]).

    For layered populations ``hidden`` is the member's width tuple;
    shard-pad filler members are excluded from the ranking.
    ``sort_by="acc"`` ranks by accuracy (descending) instead of loss —
    the serving engine publishes its member set off whichever metric the
    deployment optimises for.

    ``member_ids``: optional survivor→ORIGINAL id mapping (one entry per
    real member) from the successive-halving lifecycle — after compaction
    the fused layout renumbers members densely, but selection must keep
    speaking in the ids the run STARTED with, so ``member`` reports
    ``member_ids[m]`` and the layout slot moves to ``slot``.  The mapping
    must be duplicate-free (refilled members get FRESH ids, never a pruned
    seed's).

    ``lineage``: optional {original id → (parent id, birth rung)} from the
    slot-refill controller; when given, every row gains a ``lineage``
    column ({member, parent, born_rung}; seeds report parent -1, rung 0)
    so refilled members are distinguishable from seeds."""
    import numpy as np
    if member_ids is not None:
        _check_member_ids(member_ids, _num_real(pop))
    if sort_by == "loss":
        key = np.asarray(losses)[:_num_real(pop)]
    elif sort_by == "acc":
        if accs is None:
            raise ValueError("sort_by='acc' needs accuracies")
        key = -np.asarray(accs)[:_num_real(pop)]
    else:
        raise ValueError(f"unknown sort_by {sort_by!r} (have loss, acc)")
    order = np.argsort(key, kind="stable")[:k]
    rows = []
    for r, m in enumerate(order):
        hidden, act = _member_arch(pop, int(m))
        mid = int(m) if member_ids is None else int(member_ids[int(m)])
        row = dict(rank=r + 1, member=mid,
                   slot=int(m), hidden=hidden,
                   activation=act, loss=float(losses[m]))
        if accs is not None:
            row["acc"] = float(accs[m])
        lin = _lineage_entry(lineage, mid)
        if lin is not None:
            row["lineage"] = lin
        rows.append(row)
    return rows


def member_metrics(pop, losses, accs=None, member_ids=None, lineage=None):
    """Structured per-member metric rows for EVERY real member, unranked —
    the first slice of the metrics module (ROADMAP direction 3).  Each row
    is ``{member, slot, hidden, activation, depth, loss[, acc][, lineage]}``;
    the leaderboard is a sorted top-k view of exactly this table (same
    ``member_ids`` duplicate check, same ``lineage`` column).  Shard-pad
    fillers are excluded (their arrays hold identities, not models)."""
    import numpy as np
    nr = _num_real(pop)
    if member_ids is not None:
        _check_member_ids(member_ids, nr)
    rows = []
    for m in range(nr):
        hidden, act = _member_arch(pop, m)
        mid = m if member_ids is None else int(member_ids[m])
        row = dict(member=mid,
                   slot=m, hidden=hidden, activation=act,
                   depth=len(hidden) if isinstance(hidden, tuple) else 1,
                   loss=float(np.asarray(losses)[m]))
        if accs is not None:
            row["acc"] = float(np.asarray(accs)[m])
        lin = _lineage_entry(lineage, mid)
        if lin is not None:
            row["lineage"] = lin
        rows.append(row)
    return rows
