"""Model selection over a trained population (paper §5: "perform model
selection in the large pool of trained MLPs").

Works over BOTH layouts — the single-layer ``Population`` and the layered
engine's ``LayeredPopulation`` — dispatching forward/extract to the matching
module, so architecture search over mixed-depth pools uses the same three
calls (evaluate → select → leaderboard) as the paper's single-layer grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import deep as _deep
from repro.core import parallel_mlp as _pmlp
from repro.core.parallel_mlp import member_accuracy, member_losses
from repro.core.population import LayeredPopulation, Population


def _forward(params, x, layout, **fw):
    if isinstance(layout, LayeredPopulation):
        return _deep.forward(params, x, layout, **fw)
    return _pmlp.forward(params, x, layout, **fw)


def extract_member(params, layout, m: int) -> dict:
    """Standalone params of member m, whichever layout trained them."""
    if isinstance(layout, LayeredPopulation):
        return _deep.extract_member(params, layout, m)
    return _pmlp.extract_member(params, layout, m)


def evaluate_population(params, pop, x, targets,
                        task: str = "classification", batch_size: int = 4096,
                        **fw):
    """Per-member metric over a full eval split (batched to bound memory).

    Returns (losses (P,), accuracies (P,) or None)."""
    n = x.shape[0]
    loss_sum = jnp.zeros(pop.num_members)
    acc_sum = jnp.zeros(pop.num_members)
    seen = 0
    for i in range(0, n, batch_size):
        xb, tb = x[i:i + batch_size], targets[i:i + batch_size]
        logits = _forward(params, xb, pop, **fw)
        loss_sum = loss_sum + member_losses(logits, tb, task) * xb.shape[0]
        if task == "classification":
            acc_sum = acc_sum + member_accuracy(logits, tb) * xb.shape[0]
        seen += xb.shape[0]
    losses = loss_sum / seen
    accs = acc_sum / seen if task == "classification" else None
    return losses, accs


def select_best(params, pop, losses) -> tuple[int, dict]:
    """Best member by eval loss → (index, standalone params)."""
    m = int(jnp.argmin(losses))
    return m, extract_member(params, pop, m)


def _member_arch(pop, m: int):
    if isinstance(pop, LayeredPopulation):
        return pop.widths[m], "/".join(dict.fromkeys(pop.activations[m]))
    return pop.hidden_sizes[m], pop.activations[m]


def leaderboard(pop, losses, accs=None, k: int = 10):
    """Top-k members as (rank, member, hidden, activation, loss[, acc]).

    For layered populations ``hidden`` is the member's width tuple."""
    import numpy as np
    order = np.argsort(np.asarray(losses))[:k]
    rows = []
    for r, m in enumerate(order):
        hidden, act = _member_arch(pop, int(m))
        row = dict(rank=r + 1, member=int(m), hidden=hidden,
                   activation=act, loss=float(losses[m]))
        if accs is not None:
            row["acc"] = float(accs[m])
        rows.append(row)
    return rows
