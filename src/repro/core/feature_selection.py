"""Feature selection with ParallelMLPs — the paper's §7 future work:

  "perform feature selection using ParallelMLPs by repeating the MLP
   architecture and creating a mask tensor to be applied to the inputs
   before the first input to hidden projection"

Masking the INPUT per member is equivalent to masking the ROWS of each
member's w1 slice — so the fused network stays ONE matmul: we multiply
``w1`` by a per-unit feature mask (H_tot × F) built from per-member masks
(P × F).  Gradients through masked weights are killed by re-masking after
each update (projected SGD), so a member literally cannot use its masked
features.  Model selection over (architecture × feature subset) then reads
feature importance out of the trained population for free — the paper's
speedup is what makes this search affordable."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.parallel_mlp import fused_loss
from repro.core.population import Population


def random_masks(key, num_members: int, n_features: int,
                 keep_prob: float = 0.7, always_full: int = 0):
    """(P, F) float mask; the first ``always_full`` members keep everything
    (baseline members for comparison)."""
    m = (jax.random.uniform(key, (num_members, n_features))
         < keep_prob).astype(jnp.float32)
    # never mask EVERYTHING: force at least one feature on
    fix = jnp.zeros((num_members, n_features)
                    ).at[:, 0].set(1.0)
    m = jnp.maximum(m, jnp.where(m.sum(-1, keepdims=True) == 0, fix, 0.0))
    if always_full:
        m = m.at[:always_full].set(1.0)
    return m


def unit_masks(pop: Population, member_masks) -> jax.Array:
    """(P, F) member masks → (H_tot, F) per-hidden-unit w1 row masks."""
    return jnp.asarray(member_masks)[jnp.asarray(pop.segment_ids)]


def apply_masks(params: dict, pop: Population, member_masks) -> dict:
    um = unit_masks(pop, member_masks)
    return dict(params, w1=params["w1"] * um.astype(params["w1"].dtype))


def masked_sgd_step(params, x, targets, lr, pop: Population, member_masks,
                    task: str = "classification"):
    """Projected SGD: mask → step → re-mask.  Members remain independent AND
    feature-restricted."""
    params = apply_masks(params, pop, member_masks)
    (loss, per), grads = jax.value_and_grad(fused_loss, has_aux=True)(
        params, x, targets, pop, task)
    new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return apply_masks(new, pop, member_masks), loss, per


def feature_importance(pop: Population, member_masks, losses,
                       baseline: float | None = None):
    """Mean-loss-gap attribution: for each feature f, how much better are
    members that SEE f than members that don't.  (F,) — higher = more
    important."""
    m = np.asarray(member_masks)                     # (P, F)
    l = np.asarray(losses)                           # (P,)
    with_f = (m * l[:, None]).sum(0) / np.maximum(m.sum(0), 1)
    without_f = ((1 - m) * l[:, None]).sum(0) / np.maximum((1 - m).sum(0), 1)
    return without_f - with_f
