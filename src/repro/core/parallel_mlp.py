"""ParallelMLP — the paper's fused population-of-MLPs as a JAX module.

Parameters (one fused set for the whole population of P members):
    w1 : (total_hidden, in_features)   — concatenated input→hidden weights
    b1 : (total_hidden,)
    w2 : (out_features, total_hidden)  — fused hidden→output weights (M3 operand)
    b2 : (P, out_features)

The forward pass is the paper's four steps (§3): matmul → segmented activation
→ M3.  ``loss_fn`` returns *per-member* losses; the fused scalar objective is
their SUM so that d(loss)/d(member-m-params) equals the gradient member m
would see if trained alone — the independence property tested in
tests/test_independence.py.

Init matches torch.nn.Linear defaults (U(±1/√fan_in)) with *per-member*
fan-in for the output layer, so every member initialises exactly as it would
standalone.
"""
from __future__ import annotations

from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.m3 import m3 as _m3_apply
from repro.core.activations import apply_activations_masked, apply_activations_sliced
from repro.core.population import Population

Task = Literal["classification", "regression"]


def init_params(key: jax.Array, pop: Population, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ht, fi, fo = pop.total_hidden, pop.in_features, pop.out_features
    bound1 = 1.0 / np.sqrt(fi)
    w1 = jax.random.uniform(k1, (ht, fi), dtype, -bound1, bound1)
    b1 = jax.random.uniform(k2, (ht,), dtype, -bound1, bound1)
    # per-member output fan-in: member's true hidden size
    bound2 = (1.0 / jnp.sqrt(jnp.asarray(pop.member_fan_in, dtype)))  # (ht,)
    w2 = jax.random.uniform(k3, (fo, ht), dtype, -1.0, 1.0) * bound2[None, :]
    bound2_m = 1.0 / jnp.sqrt(jnp.asarray(np.array(pop.hidden_sizes, np.float32), dtype))
    b2 = jax.random.uniform(k4, (pop.num_members, fo), dtype, -1.0, 1.0) * bound2_m[:, None]
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2}


def forward(params: dict, x: jax.Array, pop: Population, *,
            m3_impl: str = "bucketed", act_impl: str = "sliced",
            m3_kwargs: dict | None = None) -> jax.Array:
    """x (B, in) → logits (B, P, out).  The paper's steps 1–4."""
    h = x @ params["w1"].T + params["b1"]                     # 1. fused matmul
    if act_impl == "sliced":
        h = apply_activations_sliced(h, pop.act_runs)          # 2. per-member act
    elif act_impl == "masked":
        h = apply_activations_masked(h, pop.act_ids)
    else:
        raise ValueError(f"unknown act_impl {act_impl!r}")
    h = h * jnp.asarray(pop.hidden_mask, h.dtype)              # kill padding units
    y = _m3_apply(h, params["w2"], pop, impl=m3_impl,
                  **(m3_kwargs or {}))                         # 3+4. M3
    return y + params["b2"][None, :, :]


def member_losses(logits: jax.Array, targets: jax.Array, task: Task) -> jax.Array:
    """(B, P, O) × (B,) or (B, O) → per-member mean loss (P,)."""
    if task == "classification":
        logp = jax.nn.log_softmax(logits, axis=-1)             # (B, P, O)
        nll = -jnp.take_along_axis(
            logp, targets[:, None, None].astype(jnp.int32), axis=-1)[..., 0]
        return nll.mean(axis=0)                                # (P,)
    elif task == "regression":
        err = logits - targets[:, None, :]                     # broadcast over P
        return (err ** 2).mean(axis=(0, 2))
    raise ValueError(task)


def fused_loss(params, x, targets, pop: Population, task: Task = "classification",
               **fw) -> tuple[jax.Array, jax.Array]:
    """Scalar objective = SUM of member losses (keeps gradients independent
    and identical to standalone training).  Returns (scalar, per_member)."""
    per = member_losses(forward(params, x, pop, **fw), targets, task)
    return per.sum(), per


def member_accuracy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    pred = jnp.argmax(logits, axis=-1)                         # (B, P)
    return (pred == targets[:, None]).mean(axis=0)             # (P,)


# ---------------------------------------------------------------------- #
# plain SGD training step (the paper trains with vanilla backprop); the   #
# full framework optimisers live in repro/optim and are reused by         #
# examples/quickstart.py — this compact step keeps the core standalone.   #
# ---------------------------------------------------------------------- #

@partial(jax.jit, static_argnames=("pop", "task", "m3_impl", "act_impl"))
def sgd_step(params, x, targets, lr, pop: Population,
             task: Task = "classification",
             m3_impl: str = "bucketed", act_impl: str = "sliced"):
    """One fused SGD step over the whole population.

    ``lr`` may be a scalar (paper) or a per-member vector (P,) — the paper's
    §7 "parallelise the learning rate too", free under this layout because
    every parameter belongs to exactly one member.
    """
    (loss, per), grads = jax.value_and_grad(fused_loss, has_aux=True)(
        params, x, targets, pop, task, m3_impl=m3_impl, act_impl=act_impl)
    lr = jnp.asarray(lr)
    if lr.ndim == 0:
        scale = {"w1": lr, "b1": lr, "w2": lr, "b2": lr}
    else:  # per-member lr vector → expand along the fused axes
        per_unit = lr[jnp.asarray(pop.segment_ids)]            # (ht,)
        scale = {"w1": per_unit[:, None], "b1": per_unit,
                 "w2": per_unit[None, :], "b2": lr[:, None]}
    new = {k: params[k] - scale[k] * grads[k] for k in params}
    return new, loss, per


def extract_member(params: dict, pop: Population, m: int) -> dict:
    """Pull member m's standalone MLP out of the fused parameters."""
    sl = pop.member_slice(m)
    return {"w1": params["w1"][sl], "b1": params["b1"][sl],
            "w2": params["w2"][:, sl], "b2": params["b2"][m],
            "activation": pop.activations[m]}


def member_forward(member: dict, x: jax.Array) -> jax.Array:
    """Standalone forward of one extracted member (the sequential baseline)."""
    from repro.core.activations import ACTIVATIONS
    h = ACTIVATIONS[member["activation"]](x @ member["w1"].T + member["b1"])
    return h @ member["w2"].T + member["b2"]
