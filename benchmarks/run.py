"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One harness per paper table/figure, at CI-friendly scale by default:
  paper-tables   — Table 1/2 (fused vs sequential wall-clock, measured)
  m3-variants    — §5 M3 implementation shoot-out
  roofline       — §Roofline aggregation from the dry-run artifacts

Pass ``--only <name>`` to run one; ``--paper-scale`` for the full grids.
Every harness prints CSV/markdown rows; benchmarks never assert — they
measure (tests live in tests/).
"""
from __future__ import annotations

import argparse
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["paper-tables", "m3-variants", "roofline"])
    ap.add_argument("--paper-scale", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.time()
    if args.only in (None, "paper-tables"):
        print("=== bench: paper tables (fused vs sequential) ===")
        from benchmarks import bench_paper_tables
        if args.paper_scale:
            bench_paper_tables.main(["--full"])
        else:
            bench_paper_tables.main([
                "--models", "200", "--epochs", "3", "--seq-sample", "10",
                "--samples", "100", "1000",
                "--features", "10", "100",
                "--batches", "32", "128"])
    if args.only in (None, "m3-variants"):
        print("\n=== bench: M3 variants ===")
        from benchmarks import bench_m3_variants
        bench_m3_variants.main(
            [] if args.paper_scale else ["--members", "120", "--batch", "64"])
    if args.only in (None, "roofline"):
        print("\n=== bench: roofline table (from dry-run artifacts) ===")
        from benchmarks import roofline
        if os.path.isdir("results/dryrun"):
            baseline = ("results/dryrun_baseline"
                        if os.path.isdir("results/dryrun_baseline") else None)
            roofline.main(["--dir", "results/dryrun"]
                          + (["--baseline", baseline] if baseline else []))
        else:
            print("(no results/dryrun — run repro.launch.dryrun first)")
    print(f"\n[benchmarks done in {time.time() - t0:.1f}s]")


if __name__ == "__main__":
    main()
