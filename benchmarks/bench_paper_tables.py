"""Paper Tables 1–2: fused ParallelMLPs vs sequential training wall-clock.

The paper trains 10,000 MLPs (hidden 1..100 × 10 activations × 10 repeats)
on synthetic datasets with samples ∈ {100, 1k, 10k}, features ∈
{5, 10, 50, 100}, batch ∈ {32, 128, 256}, timing 10 epochs of train-split
work.  This container's CPU is real hardware for this experiment — the
speedup is MEASURED, not simulated.

Protocol notes (fidelity vs wall-clock budget):
  * default --models 1000 (hidden 1..100 × 10 acts × 1 repeat) and the
    full grid of (samples × features) at one batch size per run;
    --full reproduces the exact 10,000-model × 3-batch-size grid.
  * the sequential baseline times a stratified SAMPLE of members
    (--seq-sample, default 25) for one epoch and extrapolates
    time × (P / sample) × epochs — the paper's sequential arm is linear in
    P by construction, so the extrapolation is exact up to per-model
    variance (reported as ±σ).
  * both arms run the same jit'd SGD step; batches are identical.

Outputs CSV rows:
  samples,features,batch,parallel_s,sequential_s,ratio_pct,speedup
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Population, init_params, sgd_step
from repro.core.activations import ACTIVATIONS, PAPER_TEN
from repro.data import TabularTask


def parallel_time(pop, task, batch, epochs, lr=0.01, m3_impl="scatter"):
    """m3_impl='scatter' is the paper's own formulation (broadcast multiply
    + scatter-add, ONE fused op) — also the fastest CPU impl measured by
    bench_m3_variants; 'bucketed' at block=1 degenerates to P separate
    einsums and must not be used for the CPU table."""
    params = init_params(jax.random.PRNGKey(0), pop)
    n = task.n_samples
    steps_per_epoch = max(n // batch, 1)
    # warm-up (compile; the paper ignores 2 warm-up epochs)
    xb, yb = task.batch(0, batch)
    params, _, _ = sgd_step(params, jnp.asarray(xb), jnp.asarray(yb), lr, pop,
                            m3_impl=m3_impl)
    jax.block_until_ready(params["w1"])
    t0 = time.perf_counter()
    for step in range(steps_per_epoch * epochs):
        xb, yb = task.batch(step, batch)
        params, _, _ = sgd_step(params, jnp.asarray(xb), jnp.asarray(yb),
                                lr, pop, m3_impl=m3_impl)
    jax.block_until_ready(params["w1"])
    return time.perf_counter() - t0


def _member_step(act_name):
    act = ACTIVATIONS[act_name]

    @jax.jit
    def step(m, x, y, lr):
        def loss(mm):
            h = act(x @ mm["w1"].T + mm["b1"])
            logits = h @ mm["w2"].T + mm["b2"]
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

        g = jax.grad(loss)(m)
        return jax.tree.map(lambda p, gg: p - lr * gg, m, g)

    return step


def sequential_time(pop, task, batch, epochs, sample: int, lr=0.01):
    """Time `sample` members for one epoch each; extrapolate to P members ×
    epochs.  Returns (estimate_s, sigma_s)."""
    from repro.core.parallel_mlp import extract_member
    params = init_params(jax.random.PRNGKey(0), pop)
    idx = np.linspace(0, pop.num_members - 1, sample).astype(int)
    n = task.n_samples
    steps_per_epoch = max(n // batch, 1)
    per_model = []
    step_fns = {}
    for m in idx:
        member = extract_member(params, pop, int(m))
        act = member.pop("activation")
        if act not in step_fns:
            step_fns[act] = _member_step(act)
        fn = step_fns[act]
        xb, yb = task.batch(0, batch)
        member = fn(member, jnp.asarray(xb), jnp.asarray(yb), lr)  # compile
        jax.block_until_ready(member["w1"])
        t0 = time.perf_counter()
        for step in range(steps_per_epoch):
            xb, yb = task.batch(step, batch)
            member = fn(member, jnp.asarray(xb), jnp.asarray(yb), lr)
        jax.block_until_ready(member["w1"])
        per_model.append(time.perf_counter() - t0)
    per_model = np.asarray(per_model)
    est = per_model.mean() * pop.num_members * epochs
    sigma = per_model.std() * pop.num_members * epochs / np.sqrt(sample)
    return est, sigma


def run(samples_list, features_list, batches, models, repeats, epochs,
        seq_sample, block, m3_impl="scatter"):
    hidden = range(1, models // (10 * repeats) + 1)
    rows = []
    print("samples,features,batch,members,parallel_s,sequential_s,"
          "sequential_sigma,ratio_pct,speedup")
    for ns in samples_list:
        for nf in features_list:
            task = TabularTask(ns, nf, n_classes=2, seed=1)
            pop = Population.grid(nf, 2, hidden, PAPER_TEN,
                                  repeats=repeats, block=block)
            for b in batches:
                b_eff = min(b, ns)
                tp = parallel_time(pop, task, b_eff, epochs, m3_impl=m3_impl)
                ts, sig = sequential_time(pop, task, b_eff, epochs,
                                          seq_sample)
                row = (ns, nf, b, pop.num_members, tp, ts, sig,
                       100.0 * tp / ts, ts / tp)
                rows.append(row)
                print(",".join(f"{v:.4g}" if isinstance(v, float) else str(v)
                               for v in row), flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="the paper's exact 10,000-model grid (hours)")
    ap.add_argument("--models", type=int, default=1000)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--seq-sample", type=int, default=25)
    ap.add_argument("--samples", type=int, nargs="+",
                    default=[100, 1000, 10000])
    ap.add_argument("--features", type=int, nargs="+",
                    default=[5, 10, 50, 100])
    ap.add_argument("--batches", type=int, nargs="+", default=[32, 128, 256])
    ap.add_argument("--block", type=int, default=1,
                    help="1 = paper-exact layout (CPU); 128 = TPU layout")
    ap.add_argument("--m3-impl", default="scatter",
                    choices=["scatter", "bucketed", "onehot"])
    args = ap.parse_args(argv)
    if args.full:
        args.models, args.repeats = 10_000, 10
    run(args.samples, args.features, args.batches, args.models,
        args.repeats, args.epochs, args.seq_sample, args.block,
        m3_impl=args.m3_impl)


if __name__ == "__main__":
    main()
