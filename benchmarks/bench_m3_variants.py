"""M3 implementation shoot-out (paper §5 "we believe M3 can be optimized").

Compares the four semantically-identical M3 implementations on the paper's
population layout:

  scatter   — paper-faithful broadcast-multiply + scatter-add (the GPU
              formulation; materialises the (B,O,H) intermediate)
  onehot    — dense einsum against a one-hot selector (P× redundant work)
  bucketed  — per-bucket batched matmul (best XLA-native TPU form)
  pallas    — segment-blocked matmul kernel (interpret mode on CPU)

Reports CPU wall-clock (fwd+bwd) AND the lowered dot-flops / HBM-byte
profile from the static HLO cost model — the structural numbers are what
transfer to TPU.

``--deep`` benches the layered-population engine instead: full fwd+bwd of a
mixed-depth LayeredPopulation with the block-diagonal mid layers run as the
per-bucket einsum loop vs the Pallas block_diag_gemm kernel (interpret mode
on CPU — wall-clock is NOT indicative there, the HLO structural numbers
are), and writes the rows to BENCH_deep.json so kernel perf is tracked
per-PR.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LayeredPopulation, Population, init_params
from repro.core import deep as deep_mod
from repro.core.activations import PAPER_TEN
from repro.core.m3 import M3_IMPLS
from repro.launch.hlo_cost import analyze


def bench(pop, batch, impl, iters=5):
    params = init_params(jax.random.PRNGKey(0), pop)
    h = jax.random.normal(jax.random.PRNGKey(1), (batch, pop.total_hidden))
    w2 = params["w2"]
    fn = M3_IMPLS[impl]

    if impl == "pallas":
        def loss(hh, ww):
            return (fn(hh, ww, pop) ** 2).sum()
    else:
        def loss(hh, ww):
            return (fn(hh, ww, pop) ** 2).sum()

    step = jax.jit(jax.grad(loss, argnums=(0, 1)))
    out = step(h, w2)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step(h, w2)
    jax.block_until_ready(out)
    wall = (time.perf_counter() - t0) / iters
    stats = analyze(jax.jit(loss).lower(h, w2).compile().as_text())
    return wall, stats


def bench_deep(lp, batch, bd_impl, iters=3):
    params = deep_mod.init_params(jax.random.PRNGKey(0), lp)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, lp.in_features))
    y = jax.random.randint(jax.random.PRNGKey(2), (batch,), 0,
                           lp.out_features)

    def loss(p):
        return deep_mod.fused_loss(p, x, y, lp, "bucketed", bd_impl)[0]

    step = jax.jit(jax.grad(loss))
    out = step(params)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step(params)
    jax.block_until_ready(out)
    wall = (time.perf_counter() - t0) / iters
    # profile the SAME fwd+bwd computation the wall-clock measures, so the
    # tracked structural numbers catch backward-pass regressions too
    stats = analyze(step.lower(params).compile().as_text())
    return wall, stats


def run_deep(args):
    """Mixed-depth layered population: einsum bucket loop vs the Pallas
    block-diagonal kernel (interpret on CPU)."""
    base = [(24,), (13, 5), (17, 9), (32, 16, 8)]
    lp = LayeredPopulation.grid(
        20, 2, base, ("relu", "tanh"),
        repeats=max(args.members // (2 * len(base)), 1), block=args.block)
    print(f"# population: {lp.describe()}")
    print("bd_impl,wall_ms,dot_gflops,hbm_mb")
    rows = {}
    for impl in args.bd_impls:
        wall, stats = bench_deep(lp, args.batch, impl)
        rows[impl] = {"wall_ms": round(wall * 1e3, 2),
                      "dot_gflops": round(stats["flops"] / 1e9, 4),
                      "hbm_mb": round(stats["hbm_bytes"] / 1e6, 2)}
        print(f"{impl},{wall*1e3:.2f},{stats['flops']/1e9:.3f},"
              f"{stats['hbm_bytes']/1e6:.1f}", flush=True)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"bench": "deep_population",
                       "population": lp.describe(),
                       "batch": args.batch, "results": rows}, f, indent=2)
        print(f"# wrote {args.json_out}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--members", type=int, default=300)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--block", type=int, default=8)
    ap.add_argument("--impls", nargs="+", default=sorted(M3_IMPLS))
    ap.add_argument("--deep", action="store_true",
                    help="bench the layered engine (BD_IMPLS shoot-out) "
                         "instead of the single-layer M3 variants")
    ap.add_argument("--bd-impls", nargs="+", default=["einsum", "pallas"])
    ap.add_argument("--json-out", default=None,
                    help="write results as JSON (BENCH_*.json tracking)")
    args = ap.parse_args(argv)

    if args.deep:
        if args.json_out is None:
            args.json_out = "BENCH_deep.json"
        run_deep(args)
        return

    hidden = range(1, args.members // 10 + 1)
    pop = Population.grid(100, 2, hidden, PAPER_TEN, repeats=1,
                          block=args.block)
    print(f"# population: {pop.describe()}")
    print("impl,wall_ms,dot_gflops,hbm_mb")
    for impl in args.impls:
        wall, stats = bench(pop, args.batch, impl)
        print(f"{impl},{wall*1e3:.2f},{stats['flops']/1e9:.3f},"
              f"{stats['hbm_bytes']/1e6:.1f}", flush=True)


if __name__ == "__main__":
    main()
