"""M3 implementation shoot-out (paper §5 "we believe M3 can be optimized").

Compares the four semantically-identical M3 implementations on the paper's
population layout:

  scatter   — paper-faithful broadcast-multiply + scatter-add (the GPU
              formulation; materialises the (B,O,H) intermediate)
  onehot    — dense einsum against a one-hot selector (P× redundant work)
  bucketed  — per-bucket batched matmul (best XLA-native TPU form)
  pallas    — segment-blocked matmul kernel (interpret mode on CPU)

Reports CPU wall-clock (fwd+bwd) AND the lowered dot-flops / HBM-byte
profile from the static HLO cost model — the structural numbers are what
transfer to TPU.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Population, init_params
from repro.core.activations import PAPER_TEN
from repro.core.m3 import M3_IMPLS
from repro.launch.hlo_cost import analyze


def bench(pop, batch, impl, iters=5):
    params = init_params(jax.random.PRNGKey(0), pop)
    h = jax.random.normal(jax.random.PRNGKey(1), (batch, pop.total_hidden))
    w2 = params["w2"]
    fn = M3_IMPLS[impl]

    if impl == "pallas":
        def loss(hh, ww):
            return (fn(hh, ww, pop) ** 2).sum()
    else:
        def loss(hh, ww):
            return (fn(hh, ww, pop) ** 2).sum()

    step = jax.jit(jax.grad(loss, argnums=(0, 1)))
    out = step(h, w2)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step(h, w2)
    jax.block_until_ready(out)
    wall = (time.perf_counter() - t0) / iters
    stats = analyze(jax.jit(loss).lower(h, w2).compile().as_text())
    return wall, stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--members", type=int, default=300)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--block", type=int, default=8)
    ap.add_argument("--impls", nargs="+", default=sorted(M3_IMPLS))
    args = ap.parse_args(argv)

    hidden = range(1, args.members // 10 + 1)
    pop = Population.grid(100, 2, hidden, PAPER_TEN, repeats=1,
                          block=args.block)
    print(f"# population: {pop.describe()}")
    print("impl,wall_ms,dot_gflops,hbm_mb")
    for impl in args.impls:
        wall, stats = bench(pop, args.batch, impl)
        print(f"{impl},{wall*1e3:.2f},{stats['flops']/1e9:.3f},"
              f"{stats['hbm_bytes']/1e6:.1f}", flush=True)


if __name__ == "__main__":
    main()
